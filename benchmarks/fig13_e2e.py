"""Paper Fig 13/14: end-to-end throughput + energy on ResNet18 / BERT for
the three searched designs, via the cycle simulator + PPA models."""
from repro.dse.models import LutDlaPoint
from repro.dse.ppa import PPA_TABLE
from repro.simulator.cycle_sim import (BERT_BASE_LAYERS, RESNET18_LAYERS,
                                       simulate_network)

from .common import emit

DESIGNS = {
    "design1_tiny": (LutDlaPoint(v=3, c=16, tile_n=128, n_imm=2, n_ccu=4),
                     "LUT-DLA-1"),
    "design2_large": (LutDlaPoint(v=4, c=16, tile_n=256, n_imm=4, n_ccu=8),
                      "LUT-DLA-2"),
    "design3_fit": (LutDlaPoint(v=3, c=16, tile_n=768, n_imm=4, n_ccu=16),
                    "LUT-DLA-3"),
}

#: NVDLA-Large reference (official perf model ballpark, 2048 GOPS peak,
#: ~40% utilisation on these nets)
NVDLA_LARGE_MS = {"resnet18": 3.1, "bert": 310.0}
NVDLA_LARGE_MW = 766.0


def run() -> None:
    for net, layers in [("resnet18", RESNET18_LAYERS),
                        ("bert", BERT_BASE_LAYERS)]:
        for name, (pt, ppa_key) in DESIGNS.items():
            r = simulate_network(layers, pt)
            power = PPA_TABLE[ppa_key]["power"]
            energy_mj = power * r["time_s"]
            emit(f"fig13/{net}/{name}", r["time_s"] * 1e6,
                 f"time={r['time_s']*1e3:.2f}ms gops={r['gops']:.0f} "
                 f"energy={energy_mj:.1f}mJ stalls="
                 f"{r['stall_cycles']/max(r['cycles'],1):.1%}")
        ref_ms = NVDLA_LARGE_MS[net]
        ref_mj = NVDLA_LARGE_MW * ref_ms / 1e3
        emit(f"fig13/{net}/nvdla_large_ref", ref_ms * 1e3,
             f"time={ref_ms}ms energy={ref_mj:.1f}mJ (official perf model)")
