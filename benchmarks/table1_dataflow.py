"""Paper Table I: dataflow impact on on-chip memory (M=512, K=N=768,
v=4, c=32). Exact reproduction of the LS/KNM/KMN/MKN cells (int8 LUT
entries + int8 requantized psums, T_n=32 — the calibration that matches the
paper's own Table VII SRAM numbers)."""
from repro.dse.models import DataflowOrder, LutDlaPoint, dataflow_memory

from .common import emit

PAPER = {"MNK": 2064.1, "NMK": 2090.9, "MKN": 2064.8, "KMN": 408.0,
         "KNM": 385.3, "LUT-Stationary": 17.3}


def run() -> None:
    pt = LutDlaPoint(v=4, c=32, bits_lut=8, bits_out=8, tile_n=32)
    for order in DataflowOrder:
        r = dataflow_memory(512, 768, 768, pt, order)
        paper = PAPER[order.value]
        emit(f"table1/{order.value}_total_kb", 0.0,
             f"ours={r['total_kb']:.1f}KB paper={paper}KB "
             f"scratch={r['scratchpad_kb']:.2f} idx={r['indices_kb']:.2f} "
             f"lut={r['psum_lut_kb']:.1f}")
    ls = dataflow_memory(512, 768, 768, pt, DataflowOrder.LS)["total_kb"]
    mnk = dataflow_memory(512, 768, 768, pt, DataflowOrder.MNK)["total_kb"]
    emit("table1/ls_vs_mnk_reduction", 0.0, f"{mnk / ls:.0f}x smaller")
