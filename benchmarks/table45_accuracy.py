"""Paper Tables IV+V: LUT-model accuracy across similarity metrics,
quantisation modes, and equivalent bit-widths.

Scaled-down proxy: a small LM on the synthetic successor task, measuring CE
loss (lower = better, analogous to accuracy). Claims under test:
  * Table IV: L1 ≈ L2 (within ~1 pt), int8 LUT costs <1 pt extra.
  * Table V: accuracy improves with c and degrades with v (equiv-bit sweep).
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import QuantConfig
from repro.core.lutboost import LutBoostSchedule, convert
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.train import TrainConfig, Trainer

from .common import emit


def _convert_and_eval(v: int, c: int, metric: str,
                      lut_dtype: str = "float32", seed: int = 0):
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64, seed=seed)
    qc = QuantConfig(mode="lut_train", v=v, c=c, metric=metric,
                     recon_weight=0.05)
    params = m.init(jax.random.PRNGKey(seed), qc)
    dense_tc = TrainConfig(total_steps=120, lr=3e-3, warmup=10,
                           log_every=10**9)
    params, _, dh = Trainer(m, ds, qc.replace(mode="dense"), dense_tc).run(
        params)
    dense_loss = float(np.mean(dh["loss"][-10:]))

    params = convert(lambda p, b: m.forward(
        p, b, qc.replace(mode="dense"))[0], params, ds.batch(0), qc)
    sched = LutBoostSchedule(stage2_steps=30, stage3_steps=80)
    tc = TrainConfig(total_steps=110, lr=1e-3, warmup=0, log_every=10**9)
    params, _, hist = Trainer(m, ds, qc, tc, lutboost=sched).run(params)

    qi = qc.replace(mode="lut_infer", lut_dtype=lut_dtype, impl="ref")
    pi = precompute_model(params, qi)
    eval_loss = 0.0
    for i in range(4):
        eval_loss += float(m.loss(pi, ds.batch(100 + i), qi)[0])
    return dense_loss, eval_loss / 4


def run() -> None:
    # Table IV: metric × LUT dtype at fixed (v=4, c=16)
    for metric in ("l2", "l1", "chebyshev"):
        for dt in ("float32", "int8"):
            dense, lut = _convert_and_eval(4, 16, metric, dt)
            emit(f"table4/{metric}_{dt}", 0.0,
                 f"dense_ce={dense:.4f} lut_ce={lut:.4f} "
                 f"drop={lut - dense:+.4f}")
    # Table V: equivalent-bit sweep
    for (v, c) in [(8, 8), (8, 16), (4, 8), (4, 16), (2, 8), (2, 16)]:
        bits = np.ceil(np.log2(c)) / v
        _, lut = _convert_and_eval(v, c, "l2")
        emit(f"table5/v{v}_c{c}", 0.0,
             f"equiv_bits={bits:.2f} lut_ce={lut:.4f}")
