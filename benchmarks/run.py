"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--only <prefix>`` runs a
subset (e.g. ``--only table1``); accuracy benches (table2/table45) train
small proxies and take a few minutes each.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="prefix filter: table1|table2|table45|table789|"
                         "fig13|micro")
    ap.add_argument("--skip-training", action="store_true",
                    help="skip the training-based accuracy benches "
                         "(table2/table45)")
    args = ap.parse_args()

    from . import (fig13_e2e, kernels_micro, table1_dataflow, table2_lutboost,
                   table45_accuracy, table789_hardware)
    suites = [
        ("table1", table1_dataflow.run),
        ("table789", table789_hardware.run),
        ("fig13", fig13_e2e.run),
        ("micro", kernels_micro.run),
        ("table2", table2_lutboost.run),
        ("table45", table45_accuracy.run),
    ]
    training = {"table2", "table45"}
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and not name.startswith(args.only):
            continue
        if args.skip_training and name in training:
            print(f"{name}/SKIPPED,0.0,--skip-training")
            continue
        try:
            fn()
        except Exception as e:      # pragma: no cover
            print(f"{name}/ERROR,0.0,{e!r}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
