"""Serving A/B: continuous batching (paged KV) vs batch-to-completion.

Replays the same mixed-length workload through both engines and reports
wall-clock generation throughput. The workload interleaves one long
request with several short ones per batch-of-`slots` group — the
batch-to-completion engine head-of-line blocks on the long member of
every group, while the continuous engine refills freed slots mid-decode.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke]

``--smoke`` uses the CPU smoke config, asserts the continuous engine wins
by >= 1.3x tokens/s (the acceptance floor; typical margin is ~2x), and is
wired into CI so the serving A/B cannot bit-rot.

``--sharded`` adds a tensor-parallel row: the same workload through
``Engine(mesh=...)`` over all visible devices, token-checked against the
single-device continuous run. On a CPU host pass ``--devices N`` to
re-exec with N forced host devices (XLA host-platform override) — the row
then measures dispatch overhead, not real TP speedup (host "devices" share
the same cores; see docs/serving.md §Sharded serving).

The prefix-reuse row replays a shared-system-prompt workload (one warming
request, then N requests sharing its page-aligned prefix) through a cold
engine (``prefix_cache=False``) and a warm one, asserts the two produce
token-identical greedy output, and reports the prefill-token reduction
(``--smoke`` asserts >= 30%; typical is ~2x that, since only the private
user suffix of each warm request is prefilled).

``--spec`` adds the speculative-decoding A/B (docs/speculative.md). The
smoke model is briefly TRAINED first (the +1-mod-V synthetic stream with
a small vocab), because speculation's win depends on the drafter
predicting the target — on random weights no cheap drafter agrees with
the target and every row would honestly lose. The trained model emits
periodic streams and the mixed-length workload's prompts contain one
full period, so the zero-model-cost n-gram drafter proposes the true
continuation from the first generated token: that row is asserted
token-identical to the non-speculative engine and (under ``--smoke``)
>1.0x tokens/s, with the acceptance rate reported. An early-exit model
drafter row (``draft_layers=1``) is reported unasserted: on this
compute-bound CPU host its draft passes cost real FLOPs, so it hovers
near 1.0x — the row exists to exercise the model-drafter path
end-to-end and to report its acceptance.

``--chaos`` adds the fault-tolerance rows: a 2-replica router replays the
mixed workload under ``FaultSchedule.canned`` (pool squeeze + injected
decode failure + replica crash mid-decode; docs/robustness.md), asserting
zero lost requests and token-identical completed output, and reporting
goodput (``--smoke`` asserts >= 90%).

``--longctx`` adds the long-context decode A/B (docs/kernels.md §Paged
flash decode): paged state is built directly at an 8k-token context and
one greedy decode chain runs through ``decode_paged`` twice — once on the
legacy gather path (``QuantConfig.flash="gather"``: pool -> dense KV view
every step) and once on the flash page-table path (``"pallas"`` on TPU,
the XLA ``"ref"`` formulation on CPU hosts). The chains are asserted
token-identical; ``--smoke`` additionally asserts the flash row wins
tokens/s (>= 1.0x floor; typical CPU margin is ~1.3x — the dense view
re-materialises ~25MB/step that the flash path never touches).

``--kvq`` adds the vector-quantized KV-page rows (docs/serving.md
§KV-cache quantization): an fp-pool engine and a ``kv_quant="vq"`` engine
(uint8 codebook indices in the pages, codebook fit from a calibration
prefill) are compared on measured HBM bytes per cached token and on the
number of full-length sequences resident in ONE pool byte budget — and
the quantized capacity is demonstrated by decoding that many requests
concurrently to completion (``--smoke`` asserts >= 4x bytes/token and
>= 2x resident sequences; typical at v=4/c=16 on the fp32 pool is 16x).

``--obs`` adds the observability-overhead A/B (docs/observability.md):
the mixed workload through a fully instrumented engine (phase timers on,
tracer recording) and through ``Obs.disabled()``, interleaved best-of-3;
``--smoke`` asserts the instrumented engine keeps >= 95% of the bare
tokens/s (the < 5% overhead ceiling), and a micro-row prices one step's
worth of recording in microseconds.

``--trace PATH`` (requires ``--chaos``) attaches one shared
:class:`~repro.obs.Tracer` to both chaos replicas and exports the
faulted run as Chrome/Perfetto ``trace_event`` JSON — request lifecycle
spans, per-replica step-phase spans, and fault/degradation/preemption
annotations — validated structurally before the bench exits (load it at
``ui.perfetto.dev``).

``--snapshot PATH`` (or ``auto``) writes every emitted row plus run
metadata to a ``BENCH_serve.json`` perf snapshot — the on-disk trajectory
for ROADMAP item 5 — which ``scripts/perf_gate.py`` diffs against the
committed copy in CI.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.models.model import Model
from repro.obs import Obs, Tracer, validate_trace
from repro.serve import (BatchToCompletionEngine, Engine, FaultInjector,
                         FaultSchedule, FinishReason, ReplicaHealth,
                         ReplicaRouter, Request, SpecConfig)

try:                                   # `python -m benchmarks.serve_bench`
    from .common import emit, snapshot, time_jax_pair
except ImportError:                    # `python benchmarks/serve_bench.py`
    from common import emit, snapshot, time_jax_pair


def mixed_workload(n_requests: int, slots: int, prompt_len: int = 4,
                   long_new: int = 56, short_new: int = 2):
    """One long + (slots-1) short requests per group, fixed prompt length.

    Fixed prompts keep the batch engine on a single compiled prefill shape
    — the A/B then measures scheduling, not recompilation."""
    reqs = []
    for i in range(n_requests):
        long = (i % slots) == 0
        n_new = long_new if long else short_new + (i % 3)
        reqs.append(Request(tokens=[(7 * i + j) % 50 + 2
                                    for j in range(prompt_len)],
                            max_new_tokens=n_new))
    return reqs


def _run_timed(engine, reqs):
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    return toks, dt


def shared_prefix_workload(n_requests: int, system_len: int = 24,
                           user_len: int = 4, n_new: int = 4):
    """N requests sharing one deterministic system prompt + private suffix."""
    system = [(5 * j) % 60 + 2 for j in range(system_len)]
    return [Request(tokens=system + [(11 * i + j) % 60 + 2
                                     for j in range(user_len)],
                    max_new_tokens=n_new)
            for i in range(n_requests)]


def prefix_bench(mk_engine, n_requests: int, smoke: bool) -> float:
    """Cold/warm A/B over the shared-system-prompt workload.

    The first request is served to completion before the rest are
    submitted (it warms the prefix index the way long-lived production
    traffic would); the cold engine replays the identical arrival
    sequence with ``prefix_cache=False``.
    Returns the warm engine's prefill-token reduction in [0, 1).
    """
    streams, engines = {}, {}
    for tag, warm in (("cold", False), ("warm", True)):
        eng = mk_engine(prefix_cache=warm)
        reqs = shared_prefix_workload(n_requests)
        eng.run([reqs[0]])
        for r in reqs[1:]:
            eng.submit(r)
        eng.run_until_idle()
        assert all(r.done and len(r.out_tokens) == r.max_new_tokens
                   for r in reqs), f"prefix {tag}: incomplete requests"
        streams[tag] = [r.out_tokens for r in reqs]
        engines[tag] = eng
    assert streams["warm"] == streams["cold"], \
        "prefix reuse changed greedy output vs the cold path"
    warm = engines["warm"]
    reduction = 1.0 - warm.prefilled_tokens / max(warm.prompt_tokens, 1)
    emit("serve.prefix_reuse.prefill_reduction", reduction * 100.0,
         f"prefilled {warm.prefilled_tokens}/{warm.prompt_tokens} prompt "
         f"tokens, hit_rate={warm.prefix_hit_rate:.2f}, "
         f"cow_forks={warm.kv.cow_forks}", unit="%", direction="up")
    print(f"prefix reuse: tokens identical to cold path; prefill tokens "
          f"reduced {reduction * 100:.0f}% "
          f"({engines['cold'].prefilled_tokens} -> {warm.prefilled_tokens})")
    if smoke:
        assert reduction >= 0.30, (
            f"shared-system-prompt workload must cut prefill tokens by "
            f">=30%, got {reduction * 100:.0f}%")
        print("prefix smoke check OK (>= 30% prefill reduction)")
    return reduction


def spec_bench(slots: int, n_requests: int, smoke: bool) -> float:
    """Speculative-decoding A/B: n-gram-drafted vs plain continuous.

    Trains the smoke model briefly on the +1-mod-V synthetic stream with
    a small vocab (see the module docstring for why trained weights are
    a precondition, not a convenience), then replays a mixed-length
    workload whose prompts hold one full output period. Returns the
    asserted row's tokens/s ratio over the non-speculative engine.
    """
    from repro.data import SyntheticDataset
    from repro.train import TrainConfig, Trainer
    vocab = 24
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive",
                                                 vocab_size=vocab)
    model = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    params, _, _ = Trainer(model, ds, DENSE, TrainConfig(
        total_steps=150, lr=3e-3, warmup=10, log_every=1000)).run(params)
    max_seq = 96

    def cycle_workload(n):
        """mixed_workload's long/short mix, prompts = one full +1 cycle."""
        reqs = mixed_workload(n, slots, prompt_len=28)
        for i, r in enumerate(reqs):
            r.tokens = [(3 * i + j) % vocab for j in range(28)]
        return reqs

    def mk(spec=None):
        return Engine(model, params, DENSE, batch_size=slots,
                      max_seq=max_seq, page_size=16, prefill_chunk=8,
                      spec_decode=spec)

    rows = [("continuous", None),
            ("spec_ngram", SpecConfig(k=8, drafter="ngram")),
            ("spec_exit1", SpecConfig(k=6, draft_layers=1))]
    rates, streams = {}, {}
    for tag, spec in rows:
        eng = mk(spec)
        eng.run(cycle_workload(slots))          # warmup (per-engine jits)
        reqs = cycle_workload(n_requests)
        toks, dt = _run_timed(eng, reqs)
        assert all(r.done and len(r.out_tokens) == r.max_new_tokens
                   for r in reqs), f"{tag}: incomplete requests"
        rates[tag] = toks / dt
        streams[tag] = [r.out_tokens for r in reqs]
        extra = ""
        if spec is not None:
            assert streams[tag] == streams["continuous"], \
                f"{tag}: speculative greedy output diverges from the " \
                f"non-speculative engine"
            extra = (f" acceptance={eng.acceptance_rate:.2f}"
                     f" tok/verify={eng.tokens_per_verify:.2f}")
        emit(f"serve.{tag}.us_per_tok", dt / max(toks, 1) * 1e6,
             f"tok/s={toks / dt:.1f}{extra}")
        print(f"{tag}: {toks / dt:.1f} tok/s{extra}")
    ratio = rates["spec_ngram"] / rates["continuous"]
    print(f"speculative (ngram drafter): {ratio:.2f}x tokens/s vs "
          f"continuous, token-identical output "
          f"(exit1 model drafter: "
          f"{rates['spec_exit1'] / rates['continuous']:.2f}x, "
          f"compute-bound on CPU — see docs/speculative.md)")
    if smoke:
        assert ratio > 1.0, (
            f"n-gram-drafted speculative decoding must beat the plain "
            f"continuous engine on the periodic smoke workload, got "
            f"{ratio:.2f}x")
        print("spec smoke check OK (> 1.0x, token-identical)")
    return ratio


def chaos_bench(slots: int, n_requests: int, max_seq: int,
                smoke: bool, trace_path: str = "") -> float:
    """Fault-tolerant serving under the canned chaos schedule.

    A 2-replica router replays the mixed workload while
    ``FaultSchedule.canned`` squeezes replica 0's page pool dry, injects
    a one-shot decode failure, then stalls and hard-crashes replica 1
    mid-decode (docs/robustness.md). Asserted invariants: ZERO lost
    requests (every request finishes with a reason — crash recovery
    requeues the dead replica's in-flight work), and completed requests
    are token-identical to a fault-free run. Returns goodput — the
    fraction of requests that finished ``COMPLETED`` (not shed, not
    deadline-expired); ``--smoke`` asserts >= 90%.
    """
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), DENSE)

    def mk_router(tracer=None):
        # one SHARED tracer across both replicas -> one merged timeline;
        # the router stamps each engine's pid with its replica index
        return ReplicaRouter([Engine(model, params, DENSE, batch_size=slots,
                                     max_seq=max_seq, page_size=16,
                                     prefill_chunk=8,
                                     obs=Obs(tracer=tracer)
                                     if tracer is not None else None)
                              for _ in range(2)])

    def workload():
        # longs first: least-loaded dispatch then spreads them across both
        # replicas, so the mid-decode crash of the last replica actually
        # has in-flight work to recover (mixed_workload puts every long
        # request at an even index, which round-robins them all onto
        # replica 0 otherwise)
        reqs = mixed_workload(n_requests, slots)
        return sorted(reqs, key=lambda r: -r.max_new_tokens)

    ref_reqs = workload()
    mk_router().run(ref_reqs)               # fault-free reference output

    tracer = Tracer(enabled=True) if trace_path else None
    router = mk_router(tracer)
    router.run(mixed_workload(2 * slots, slots, long_new=3, short_new=2))
    if tracer is not None:
        tracer.clear()                  # drop warmup; trace the chaos run
    FaultInjector(FaultSchedule.canned(replicas=2)).attach(router)
    reqs = workload()
    t0 = time.perf_counter()
    for r in reqs:
        router.submit(r)
    router.run_until_idle()
    dt = time.perf_counter() - t0

    lost = [r for r in reqs if not r.done]
    assert not lost, f"chaos: {len(lost)} request(s) lost"
    completed = [r for r in reqs
                 if r.finish_reason is FinishReason.COMPLETED]
    for got, want in zip(reqs, ref_reqs):
        if got.finish_reason is FinishReason.COMPLETED:
            assert got.out_tokens == want.out_tokens, \
                "chaos: completed request diverged from fault-free run"
    goodput = len(completed) / len(reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    dead = sum(st.health is ReplicaHealth.DEAD for st in router.status)
    emit("serve.chaos.goodput_pct", goodput * 100.0,
         f"completed={len(completed)}/{len(reqs)} "
         f"retried={router.retried_requests} "
         f"shed={sum(r.shed for r in reqs)} dead_replicas={dead}",
         unit="%", direction="up")
    emit("serve.chaos.us_per_tok", dt / max(toks, 1) * 1e6,
         f"tok/s={toks / dt:.1f} under faults")
    print(f"chaos: {goodput * 100:.0f}% goodput, zero lost, completed "
          f"output token-identical to fault-free run "
          f"({router.retried_requests} recovery retries, {dead} replica(s) "
          f"died)")
    if smoke:
        assert goodput >= 0.90, (
            f"chaos goodput must stay >= 90% under the canned fault "
            f"schedule, got {goodput * 100:.0f}%")
        print("chaos smoke check OK (>= 90% goodput, zero lost)")
    if tracer is not None:
        doc = tracer.export(trace_path)
        problems = validate_trace(doc)
        assert not problems, f"chaos trace invalid: {problems[:5]}"
        n_req_spans = sum(1 for e in doc["traceEvents"]
                          if e.get("ph") == "b")
        n_annot = sum(1 for e in doc["traceEvents"]
                      if e.get("ph") == "i" and e.get("cat") == "annot")
        assert n_req_spans and n_annot, (
            "chaos trace exported but carries no request spans or no "
            "fault/degradation annotations")
        print(f"chaos trace: {len(doc['traceEvents'])} events "
              f"({n_req_spans} request spans, {n_annot} annotations) -> "
              f"{trace_path} (valid; open at ui.perfetto.dev)")
    return goodput


def longctx_bench(smoke: bool, ctx: int = 8192, slots: int = 2,
                  steps: int = 8) -> float:
    """Long-context decode A/B: flash page-table decode vs the gather path.

    The paged state is synthesised directly — pool pages filled with
    unit-normal pseudo prompt KV, a fully-allocated page table, per-slot
    positions [ctx-1, ctx//2] — because the row measures the *decode*
    path and an 8k real prefill would dominate the wall clock without
    touching it. head_dim is widened to 64 (the smoke config's 16 keeps
    the whole model tiny; at 8k the interesting regime is KV-traffic-
    bound, which is head_dim-proportional). A greedy chain of ``steps``
    tokens runs under ``QuantConfig.flash="gather"`` and under the flash
    impl for this host ("pallas" on TPU, "ref" on CPU); the chains must
    be token-identical, then one steady-state step is timed interleaved.
    Returns the flash/gather tokens/s ratio (``--smoke`` asserts >= 1.0).
    """
    ps = 16
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive",
                                                 head_dim=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    pages_per_slot = (ctx + steps + ps - 1) // ps
    num_pages = slots * pages_per_slot
    kv = model.init_paged_cache(slots, ctx + steps, ps, num_pages)
    key = jax.random.PRNGKey(1)
    kv = {k: jax.random.normal(jax.random.fold_in(key, i), v.shape,
                               v.dtype) * 0.3
          for i, (k, v) in enumerate(sorted(kv.items()))}
    page_table = jnp.arange(num_pages, dtype=jnp.int32).reshape(
        slots, pages_per_slot)
    pos0 = jnp.array(([ctx - 1] + [ctx // 2] * (slots - 1))[:slots],
                     jnp.int32)
    tok0 = jnp.full((slots, 1), 3, jnp.int32)
    flash = "pallas" if jax.default_backend() == "tpu" else "ref"

    def mk_step(impl):
        qc = DENSE.replace(flash=impl)

        def step(tok, kv, positions):
            logits, kv = model.decode_paged(params, tok, kv, page_table,
                                            positions, qc)
            return jnp.argmax(logits, -1).astype(jnp.int32), kv
        return jax.jit(step)

    def run_chain(step_fn):
        toks, kv_r, posn, tok = [], kv, pos0, tok0
        for _ in range(steps):
            nxt, kv_r = step_fn(tok, kv_r, posn)
            toks.append([int(t) for t in nxt])
            tok, posn = nxt[:, None], posn + 1
        return toks

    gather_j, flash_j = mk_step("gather"), mk_step(flash)
    streams = {"gather": run_chain(gather_j), flash: run_chain(flash_j)}
    assert streams[flash] == streams["gather"], (
        f"longctx: flash ({flash}) greedy chain diverged from the gather "
        f"path")
    t_g, t_f = time_jax_pair(gather_j, flash_j, tok0, kv, pos0,
                             warmup=1, iters=5)
    view_mb = (2 * slots * pages_per_slot * ps * cfg.num_kv_heads
               * cfg.head_dim * cfg.num_layers * 4 / 1e6)
    ratio = t_g / t_f
    emit(f"serve.longctx{ctx}.gather.us_per_tok", t_g / slots,
         f"dense KV view {view_mb:.1f}MB/step")
    emit(f"serve.longctx{ctx}.flash_{flash}.us_per_tok", t_f / slots,
         f"{ratio:.2f}x vs gather; tokens identical over {steps} "
         f"greedy steps x {slots} slots")
    print(f"longctx {ctx}: flash ({flash}) {ratio:.2f}x tokens/s vs "
          f"gather, token-identical greedy chains")
    if smoke:
        assert ratio >= 1.0, (
            f"flash decode must not lose to the gather path at {ctx}-token "
            f"context, got {ratio:.2f}x")
        print("longctx smoke check OK (>= 1.0x, token-identical)")
    return ratio


def kvq_bench(slots: int, smoke: bool) -> float:
    """Vector-quantized KV pages A/B (docs/serving.md §KV-cache quantization).

    Two engines on the same smoke model: an fp pool and a ``kv_quant="vq"``
    pool whose pages hold uint8 codebook indices (the engine fits the
    codebook from a calibration prefill at construction). The row reports
    the measured HBM bytes one cached token pins (from the actual pool
    arrays, so dtype/layout changes show up) and the resident-sequence
    capacity both pools reach under ONE byte budget — the fp engine's pool.
    The quantized capacity is then *demonstrated*, not just computed: a
    batch of full-length requests equal to the fp pool's capacity times
    >=2 runs concurrently to completion inside that same budget, with the
    peak decode concurrency checked against the batch size.

    Returns the capacity ratio (``--smoke`` asserts bytes/token >= 4x and
    capacity >= 2x; typical at v=4/c=16 on the fp32 smoke pool is 16x).
    """
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    KVQ = DENSE.replace(kv_quant="vq")
    ps, max_seq, chunk = 8, 32, 4
    prompt_len, n_new = 4, 20
    need = prompt_len + n_new                  # tokens a request pins
    pages_per_req = -(-need // ps)

    # fp engine: pool sized so exactly two full requests fit resident
    # (plus one spare page so allocation is not knife-edge) — the byte
    # budget every other number in this row is measured against.
    fp = Engine(model, params, DENSE, batch_size=slots, max_seq=max_seq,
                page_size=ps, prefill_chunk=chunk, prefix_cache=False,
                num_pages=2 * pages_per_req + 1)
    budget = fp.kv.pool_bytes
    cap_fp = budget // (pages_per_req * fp.kv.page_bytes)

    # quantized engine under the SAME byte budget: every fp page's bytes
    # buy `bytes_per_token` ratio more code pages.
    probe = Engine(model, params, KVQ, batch_size=1, max_seq=max_seq,
                   page_size=ps, prefill_chunk=chunk, prefix_cache=False)
    bpt_fp, bpt_q = fp.kv.bytes_per_token, probe.kv.bytes_per_token
    nq = budget // probe.kv.page_bytes
    cap_q = budget // (pages_per_req * probe.kv.page_bytes)
    cap_used = min(cap_q, 4 * slots)
    kvq = Engine(model, params, KVQ, batch_size=cap_used, max_seq=max_seq,
                 page_size=ps, prefill_chunk=chunk, prefix_cache=False,
                 num_pages=nq, kv_codebook=probe.kv_codebook)
    assert kvq.kv.pool_bytes <= budget, (
        f"kvq pool {kvq.kv.pool_bytes}B exceeds the fp byte budget "
        f"{budget}B")

    # demonstrate the capacity: cap_used identical full-length requests,
    # all resident at once, to completion.
    reqs = [Request(tokens=[(7 * i + j) % 50 + 2
                            for j in range(prompt_len)],
                    max_new_tokens=n_new) for i in range(cap_used)]
    peak, t0 = 0, time.perf_counter()
    for r in reqs:
        kvq.submit(r)
    while kvq.scheduler.has_work:
        kvq.step()
        peak = max(peak, len(kvq.scheduler.decode_slots()))
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    assert all(r.done and len(r.out_tokens) == r.max_new_tokens
               for r in reqs), "kvq: incomplete requests"
    assert peak == cap_used, (
        f"kvq: only {peak}/{cap_used} sequences decoded concurrently — "
        f"the capacity claim did not hold on the pool")

    bytes_ratio = bpt_fp / bpt_q
    cap_ratio = cap_q / max(cap_fp, 1)
    cb = kvq.kv_codebook
    emit("serve.kvq.bytes_per_tok", bpt_q,
         f"fp {bpt_fp}B -> vq {bpt_q}B ({bytes_ratio:.1f}x smaller; "
         f"v={cb.v} c={cb.c}, {cb.equivalent_bits:.1f} eq-bits)",
         unit="B", direction="down")
    emit("serve.kvq.resident_seqs_per_pool", cap_q,
         f"{cap_q} vs fp {cap_fp} full {need}-token seqs in the same "
         f"{budget}B pool ({cap_ratio:.1f}x); {peak} demonstrated live",
         unit="seqs", direction="up")
    emit("serve.kvq.us_per_tok", dt / max(toks, 1) * 1e6,
         f"tok/s={toks / dt:.1f} at {peak} concurrent quantized slots")
    print(f"kvq: {bpt_fp}B -> {bpt_q}B per cached token "
          f"({bytes_ratio:.1f}x), {cap_q} vs {cap_fp} resident seqs in a "
          f"{budget}B pool ({cap_ratio:.1f}x), {peak} run live")
    if smoke:
        assert bytes_ratio >= 4.0, (
            f"vq KV pages must cut bytes/token >= 4x, got "
            f"{bytes_ratio:.2f}x")
        assert cap_ratio >= 2.0, (
            f"vq KV pages must hold >= 2x the concurrent sequences at a "
            f"fixed pool byte budget, got {cap_ratio:.2f}x")
        print(f"kvq smoke check OK (>= 4x bytes/token, >= 2x resident "
              f"sequences, {peak} decoded concurrently)")
    return cap_ratio


def obs_bench(model, params, slots: int, n_requests: int, max_seq: int,
              smoke: bool) -> float:
    """Observability-overhead A/B: fully instrumented vs ``Obs.disabled()``.

    Same mixed workload, two engines differing only in the obs bundle —
    phase timers + an enabled tracer vs the disabled no-op path
    (counters stay live in both; they are engine state). Interleaved
    best-of-3 tokens/s absorbs host scheduler noise the same way
    ``time_jax_pair`` does. Returns the relative overhead in [0, 1);
    ``--smoke`` asserts < 5% (the ISSUE ceiling). A micro-row prices the
    raw recording primitive so the per-step cost is visible even when
    the end-to-end delta drowns in noise.
    """
    def mk(obs):
        return Engine(model, params, DENSE, batch_size=slots,
                      max_seq=max_seq, page_size=16, prefill_chunk=8,
                      prefix_cache=False, obs=obs)

    eng_off = mk(Obs.disabled())
    eng_on = mk(Obs(tracer=Tracer(enabled=True)))
    for e in (eng_off, eng_on):       # per-instance jit warmup
        e.run(mixed_workload(slots, slots, long_new=3, short_new=2))
    best = {"off": 0.0, "on": 0.0}
    for _ in range(3):
        for tag, e in (("off", eng_off), ("on", eng_on)):
            toks, dt = _run_timed(e, mixed_workload(n_requests, slots))
            best[tag] = max(best[tag], toks / dt)
    overhead = 1.0 - best["on"] / best["off"]

    # micro: one phase record (timer observe + trace event append), x7
    # for a step's worth of phases (admit, prefill, decode, sample,
    # draft, verify, device_read)
    obs = eng_on.obs
    n_iter = 20000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        with obs.phase("decode"):
            pass
    per_phase_us = (time.perf_counter() - t0) / n_iter * 1e6
    # floor at 1.0: the perf-gate tolerance is *relative* to the
    # baseline, so committing a 0.0 row (obs measured faster than bare,
    # i.e. pure noise) would gate every future positive reading
    emit("serve.obs.overhead_pct", max(overhead * 100.0, 1.0),
         f"obs-on {best['on']:.1f} vs obs-off {best['off']:.1f} tok/s, "
         f"best-of-3 interleaved", unit="%", direction="down", tol=4.0)
    emit("serve.obs.record_us_per_step", per_phase_us * 7,
         f"{per_phase_us:.3f}us per phase record (hist observe + trace "
         f"append) x 7 phases/step", tol=1.0)
    print(f"obs overhead: {overhead * 100:+.1f}% tokens/s "
          f"({best['on']:.1f} instrumented vs {best['off']:.1f} bare), "
          f"{per_phase_us:.3f}us per phase record")
    if smoke:
        assert overhead < 0.05, (
            f"instrumented engine lost {overhead * 100:.1f}% tokens/s — "
            f"the < 5% observability-overhead ceiling is blown")
        print("obs smoke check OK (< 5% overhead, obs fully on)")
    return overhead


def bench(slots: int, n_requests: int, max_seq: int, smoke: bool,
          sharded: bool = False, devices: int = 0, spec: bool = False,
          chaos: bool = False, longctx: bool = False, kvq: bool = False,
          obs: bool = False, trace: str = ""):
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), DENSE)

    def batch_engine():
        return BatchToCompletionEngine(model, params, DENSE,
                                       batch_size=slots, max_seq=max_seq)

    def cont_engine(mesh=None, prefix_cache=True):
        return Engine(model, params, DENSE, batch_size=slots,
                      max_seq=max_seq, page_size=16, prefill_chunk=8,
                      mesh=mesh, prefix_cache=prefix_cache)

    makers = [("batch_to_completion", batch_engine),
              ("continuous_paged", cont_engine)]
    if sharded:
        # honour an explicit --devices N even when MORE devices are visible
        # (the mesh takes the first N); default to everything
        n_dev = min(devices, jax.device_count()) if devices \
            else jax.device_count()
        if n_dev < 2:
            print("[serve_bench] --sharded skipped: 1 device visible "
                  "(use --devices N to force host devices)")
        else:
            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh((1, n_dev), ("data", "model"))
            makers.append((f"continuous_paged_tp{n_dev}",
                           lambda: cont_engine(mesh=mesh)))

    results = {}
    token_streams = {}
    for tag, mk in makers:
        eng = mk()
        # warmup on the engine instance itself: jitted prefill/decode are
        # per-instance, so a throwaway engine would put compilation back
        # into the timed region
        eng.run(mixed_workload(slots, slots, long_new=3, short_new=2))
        reqs = mixed_workload(n_requests, slots)
        toks, dt = _run_timed(eng, reqs)
        assert all(r.done and len(r.out_tokens) == r.max_new_tokens
                   for r in reqs), f"{tag}: incomplete requests"
        results[tag] = toks / dt
        token_streams[tag] = [r.out_tokens for r in reqs]
        emit(f"serve.{tag}.us_per_tok", dt / max(toks, 1) * 1e6,
             f"tok/s={toks / dt:.1f} toks={toks}")

    for tag in results:
        if tag.startswith("continuous_paged_tp"):
            assert token_streams[tag] == token_streams["continuous_paged"], \
                f"{tag}: sharded tokens diverge from single-device engine"
            print(f"{tag}: tokens identical to single-device continuous "
                  f"engine ({results[tag]:.1f} vs "
                  f"{results['continuous_paged']:.1f} tok/s)")

    ratio = results["continuous_paged"] / results["batch_to_completion"]
    print(f"\ncontinuous vs batch-to-completion: {ratio:.2f}x tokens/s "
          f"({results['continuous_paged']:.1f} vs "
          f"{results['batch_to_completion']:.1f})")
    if smoke:
        assert ratio >= 1.3, (
            f"continuous batching must beat batch-to-completion by >=1.3x "
            f"on the mixed-length smoke workload, got {ratio:.2f}x")
        print("smoke check OK (>= 1.3x)")

    # shared-system-prompt row: cold/warm parity + prefill-token reduction
    prefix_bench(cont_engine, n_requests, smoke)
    # speculative-decoding rows (trains its own small-vocab model)
    if spec:
        spec_bench(slots, n_requests, smoke)
    # fault-injected rows (2-replica router under the canned schedule)
    if chaos:
        chaos_bench(slots, n_requests, max_seq, smoke, trace_path=trace)
    # 8k-context decode A/B (flash page-table decode vs gather)
    if longctx:
        longctx_bench(smoke)
    # vector-quantized KV pages: bytes/token + fixed-pool capacity rows
    if kvq:
        kvq_bench(slots, smoke)
    # observability overhead A/B (< 5% ceiling under --smoke)
    if obs:
        obs_bench(model, params, slots, n_requests, max_seq, smoke)
    return ratio


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CPU smoke config + 1.3x assertion (CI)")
    ap.add_argument("--sharded", action="store_true",
                    help="add a tensor-parallel engine row over all "
                         "visible devices (token-checked vs single-device)")
    ap.add_argument("--devices", type=int, default=0,
                    help="re-exec with N forced host devices "
                         "(XLA host-platform override, for --sharded on CPU)")
    ap.add_argument("--spec", action="store_true",
                    help="add the speculative-decoding A/B rows (trains a "
                         "small-vocab smoke model first; with --smoke, "
                         "asserts >1.0x + token-identical output)")
    ap.add_argument("--chaos", action="store_true",
                    help="add fault-injected rows: a 2-replica router under "
                         "the canned chaos schedule (with --smoke, asserts "
                         "zero lost requests and >= 90%% goodput)")
    ap.add_argument("--longctx", action="store_true",
                    help="add the 8k-context decode A/B: flash page-table "
                         "decode vs the gather path (with --smoke, asserts "
                         "token-identical chains and >= 1.0x tokens/s)")
    ap.add_argument("--kvq", action="store_true",
                    help="add the vector-quantized KV-page rows: measured "
                         "bytes/token and resident-sequence capacity at a "
                         "fixed pool byte budget (with --smoke, asserts "
                         ">= 4x bytes/token and >= 2x capacity)")
    ap.add_argument("--obs", action="store_true",
                    help="add the observability-overhead A/B row (with "
                         "--smoke, asserts < 5%% tokens/s overhead with "
                         "phase timers and the tracer fully on)")
    ap.add_argument("--trace", default="",
                    help="with --chaos: export the faulted run as "
                         "Chrome/Perfetto trace_event JSON to this path "
                         "(validated; open at ui.perfetto.dev)")
    ap.add_argument("--snapshot", default="",
                    help="write a BENCH_serve.json perf snapshot to this "
                         "path ('auto' = repo root)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()
    if args.trace and not args.chaos:
        ap.error("--trace requires --chaos (it exports the faulted run)")
    if args.devices and jax.device_count() < args.devices:
        # one-shot sentinel: the host-platform override only adds devices on
        # the CPU backend, so on a GPU/TPU host the re-exec'd process would
        # still be short and exec forever without it
        if os.environ.get("_SERVE_BENCH_REEXEC"):
            raise SystemExit(
                f"--devices {args.devices}: still only {jax.device_count()} "
                "device(s) after the host-platform override (non-CPU "
                "backend?); run on CPU or drop --devices")
        flags = os.environ.get("XLA_FLAGS", "")
        env = dict(os.environ)
        env["_SERVE_BENCH_REEXEC"] = "1"
        env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                            f"{args.devices}").strip()
        os.execve(sys.executable, [sys.executable] + sys.argv, env)
    bench(args.slots, args.requests, args.max_seq, args.smoke, args.sharded,
          args.devices, args.spec, args.chaos, args.longctx, args.kvq,
          args.obs, args.trace)
    if args.snapshot:
        path = args.snapshot
        if path == "auto":
            path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "BENCH_serve.json")
        snapshot(os.path.normpath(path), bench="serve",
                 smoke=args.smoke, slots=args.slots,
                 requests=args.requests, max_seq=args.max_seq,
                 sharded=bool(args.sharded), spec=bool(args.spec),
                 chaos=bool(args.chaos), longctx=bool(args.longctx),
                 kvq=bool(args.kvq), obs=bool(args.obs))


if __name__ == "__main__":
    main()
