"""Lossy-path accuracy harness for vector-quantized KV-cache pages.

The ``kv_quant="vq"`` pool is the repo's first *lossy* serving artefact:
codes round-trip through a per-layer codebook instead of fp rows, so
"the tests pass" is not enough — the question is HOW MUCH the output
distribution moves. This harness answers it on the trained smoke model
(the +1-mod-V synthetic stream, the same recipe ``serve_bench --spec``
uses — accuracy deltas on random weights are meaningless because every
logit is noise):

* **teacher-forced logit MSE** — one on-distribution stream replayed
  through two paged decode chains that differ ONLY in the pool encoding
  (fp rows vs uint8 codes under a calibration-fit codebook); per-step
  next-token logits are compared elementwise.
* **perplexity delta** — the same two chains scored against the true
  continuation; ``--smoke`` asserts the quantized perplexity is within
  0.1 of fp (the ISSUE ceiling; typical at v=4/c=16 is ~100x tighter).
* **greedy argmax agreement** — the fraction of steps where the
  quantized chain's greedy choice matches fp (reported; asserted 1.0
  only in the exact-cover test below, where it is a theorem).
* **exact-cover token identity** — an end-to-end :class:`Engine` run
  under a :meth:`KVCodebook.from_rows` codebook (centroids = the exact
  row set, unit scales) must reproduce the fp engine's greedy tokens
  BIT-IDENTICALLY: encode lands every row on an exact copy of itself,
  so the lossy machinery — encode on write, in-kernel decode on read —
  is exercised while the answer stays provably lossless.

Run:  PYTHONPATH=src python benchmarks/kv_accuracy.py [--smoke]
      [--snapshot auto]

``--snapshot`` MERGES the ``kvacc.*`` rows into ``BENCH_serve.json``
(replacing stale ``kvacc.*`` rows, preserving everything else) — the
accuracy trajectory rides the serving snapshot rather than forking a
second on-disk history.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.kv_codebook import KVCodebook
from repro.core.lut import DENSE
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.serve import Engine, Request
from repro.obs.snapshot import merge_snapshot
from repro.train import TrainConfig, Trainer

try:                                   # `python -m benchmarks.kv_accuracy`
    from .common import ROWS, emit
except ImportError:                    # `python benchmarks/kv_accuracy.py`
    from common import ROWS, emit

VOCAB = 24
PAGE = 8


def _trained_smoke():
    """The spec_bench training recipe: smoke config, small vocab, +1-mod-V
    synthetic stream. 150 steps is enough for the model to put ~all its
    mass on the true successor, which is what makes perplexity deltas
    interpretable."""
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive",
                                                 vocab_size=VOCAB)
    model = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)
    params = model.init(jax.random.PRNGKey(0), DENSE)
    params, _, _ = Trainer(model, ds, DENSE, TrainConfig(
        total_steps=150, lr=3e-3, warmup=10, log_every=1000)).run(params)
    return model, params


def _teacher_chain(model, params, qc, stream, codebook=None):
    """Teacher-forced paged decode over ``stream``; returns per-step
    next-token logits ``(T - p0, V)`` for targets ``stream[p0:]``.

    The chain is the engine's own paged path (prefill_paged one chunk,
    then decode_paged token by token on a static full page table) — not
    a dense-cache stand-in — so encode-on-write and decode-in-kernel are
    both in the loop being measured.
    """
    t_total = len(stream)
    max_seq = -(-t_total // PAGE) * PAGE
    npages = max_seq // PAGE
    kv = model.init_paged_cache(1, max_seq, PAGE, npages, codebook=codebook)
    table = jnp.arange(npages, dtype=jnp.int32).reshape(1, npages)
    p0 = 4
    chunk = jnp.asarray([stream[:p0]], jnp.int32)
    logits, kv = model.prefill_paged(params, chunk, kv, table, 0, 0, p0, qc)
    step = jax.jit(lambda tok, kv, pos: model.decode_paged(
        params, tok, kv, table, pos, qc))
    outs = [logits.reshape(-1)]
    for t in range(p0, t_total - 1):
        tok = jnp.asarray([[stream[t]]], jnp.int32)
        logits, kv = step(tok, kv, jnp.asarray([t], jnp.int32))
        outs.append(logits.reshape(-1))
    return jnp.stack(outs)


def teacher_forced_bench(model, params, smoke: bool):
    """Logit MSE / perplexity delta / greedy agreement, fp vs quantized."""
    kvq_qc = DENSE.replace(kv_quant="vq")
    # the engine's own calibration fit (deterministic token ramp,
    # PRNGKey(0)) — the codebook a production engine would serve with
    probe = Engine(model, params, kvq_qc, batch_size=1, max_seq=64,
                   page_size=PAGE, prefill_chunk=4, prefix_cache=False)
    cb = probe.kv_codebook
    stream = [(3 + j) % VOCAB for j in range(48)]
    lf = _teacher_chain(model, params, DENSE, stream)
    lq = _teacher_chain(model, params, kvq_qc, stream, codebook=cb)
    targets = jnp.asarray(stream[4:], jnp.int32)

    def ppl(lg):
        lp = jax.nn.log_softmax(lg, -1)
        nll = -jnp.take_along_axis(lp, targets[:, None], -1)
        return float(jnp.exp(jnp.mean(nll)))

    mse = float(jnp.mean((lf - lq) ** 2))
    agree = float(jnp.mean(
        (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).astype(jnp.float32)))
    ppl_fp, ppl_q = ppl(lf), ppl(lq)
    delta = abs(ppl_q - ppl_fp)
    emit("kvacc.logit_mse", mse,
         f"teacher-forced over {lf.shape[0]} steps, v={cb.v} c={cb.c}",
         unit="", direction="down", tol=1.0)
    emit("kvacc.ppl_delta", delta,
         f"fp ppl {ppl_fp:.4f} -> vq ppl {ppl_q:.4f}",
         unit="", direction="down", tol=1.0)
    emit("kvacc.greedy_agreement", agree * 100.0,
         f"{agree * 100:.1f}% of greedy choices identical to fp",
         unit="%", direction="up", tol=0.05)
    print(f"teacher-forced: logit MSE {mse:.3e}, ppl {ppl_fp:.4f} -> "
          f"{ppl_q:.4f} (delta {delta:.4f}), greedy agreement "
          f"{agree * 100:.1f}%")
    if smoke:
        assert delta <= 0.1, (
            f"quantized-cache perplexity drifted {delta:.4f} from fp — "
            f"the <= 0.1 acceptance ceiling is blown")
        assert mse <= 0.05, (
            f"teacher-forced logit MSE {mse:.3e} above the 0.05 ceiling")
        print("accuracy smoke check OK (ppl delta <= 0.1, MSE <= 0.05)")
    return delta


def exact_cover_bench(model, params) -> None:
    """Greedy token identity under an exact-cover codebook, end to end.

    fp engine -> greedy tokens; a manual fp paged chain (verified
    token-identical to the engine) harvests every cache row the run
    wrote; ``KVCodebook.from_rows`` makes those rows the centroids; the
    quantized ENGINE under that codebook must reproduce the fp tokens
    exactly. Always asserted — this is a theorem about the machinery,
    not a tolerance."""
    prompt, n_new = [2, 3, 5, 7, 11], 8
    qc = DENSE.replace(flash="gather")

    def run_engine(e_qc, cb=None):
        eng = Engine(model, params, e_qc, batch_size=1, max_seq=32,
                     page_size=PAGE, prefill_chunk=4, prefix_cache=False,
                     kv_codebook=cb)
        req = Request(tokens=list(prompt), max_new_tokens=n_new)
        eng.run([req])
        assert req.done and len(req.out_tokens) == n_new
        return req.out_tokens

    fp_out = run_engine(qc)

    # manual chain on a static table: same tokens, harvestable pool
    p = len(prompt)
    kv = model.init_paged_cache(1, 32, PAGE, 4)
    table = jnp.arange(4, dtype=jnp.int32).reshape(1, 4)
    logits, kv = model.prefill_paged(
        params, jnp.asarray([prompt], jnp.int32), kv, table, 0, 0, p, qc)
    toks, pos = [], p
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits.reshape(-1)))
        toks.append(nxt)
        logits, kv = model.decode_paged(
            params, jnp.asarray([[nxt]], jnp.int32), kv, table,
            jnp.asarray([pos], jnp.int32), qc)
        pos += 1
    assert toks == fp_out, (
        f"manual paged chain {toks} diverged from the fp engine {fp_out} "
        f"— the harvested rows would not describe the engine's run")

    # every row the run READS: positions [0, p + n_new - 1)
    t_rows = p + n_new - 1
    rows = {key: kv[key][:, np.arange(4)].reshape(
        model.cfg.num_layers, 32, model.cfg.num_kv_heads,
        model.cfg.head_dim)[:, :t_rows] for key in ("k", "v")}
    cb = KVCodebook.from_rows(rows["k"], rows["v"])
    vq_out = run_engine(DENSE.replace(kv_quant="vq", flash="gather"), cb)
    assert vq_out == fp_out, (
        f"exact-cover quantized engine {vq_out} != fp {fp_out}: "
        f"encode/decode is not lossless on its own centroid set")
    emit("kvacc.exact_cover_identity", 1.0,
         f"{n_new} greedy tokens bit-identical through the quantized "
         f"engine under a from_rows codebook",
         unit="", direction="up")
    print(f"exact-cover: quantized engine reproduced {fp_out} exactly")


def _merge_snapshot(path: str) -> None:
    """Fold this run's ``kvacc.*`` rows into an existing serve snapshot
    (or start one), replacing stale kvacc rows and nothing else."""
    merge_snapshot(path, ROWS, prefix="kvacc.", kv_accuracy=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance ceilings (ppl delta <= "
                         "0.1, logit MSE <= 0.05)")
    ap.add_argument("--snapshot", default="",
                    help="merge kvacc.* rows into this BENCH_serve.json "
                         "('auto' = repo root)")
    args = ap.parse_args()
    model, params = _trained_smoke()
    teacher_forced_bench(model, params, args.smoke)
    exact_cover_bench(model, params)
    if args.snapshot:
        path = args.snapshot
        if path == "auto":
            path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "BENCH_serve.json")
        _merge_snapshot(os.path.normpath(path))


if __name__ == "__main__":
    main()
