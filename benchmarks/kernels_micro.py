"""Microbenchmarks of the core ops on this host (CPU, ref impl + Pallas
interpret) — wall-time sanity, not TPU numbers (those come from the
dry-run roofline)."""
import jax
import jax.numpy as jnp

from repro.core.lut import build_lut
from repro.kernels import ref
from repro.kernels.ops import lut_matmul, vq_assign

from .common import emit, time_jax, time_jax_pair


def _bench_fused_vs_two_pass(x, z, lut, tag: str) -> None:
    """micro/fused_amm_* rows: one jitted fused program (assignment feeds the
    LUT contraction with no materialised index tensor) against the two-pass
    pipeline that writes the (M, nc) int32 indices out between kernels.

    The two variants are timed interleaved (best-of-N) so host scheduler
    noise hits both equally — the ratio is the signal, not the wall time.
    """
    m, nc, _ = x.shape

    assign_j = jax.jit(lambda a, b: ref.assign_ref(a, b, "l2"))
    lookup_j = jax.jit(ref.lut_gemm_onehot)

    def two_pass(a, b, l):
        idx = assign_j(a, b)            # (M, nc) int32 round-trip
        return lookup_j(idx, l)

    fused_j = jax.jit(lambda a, b, l: ref.vq_amm_ref(a, b, l, metric="l2"))

    t_two, t_fused = time_jax_pair(two_pass, fused_j, x, z, lut,
                                   warmup=3, iters=30)
    idx_bytes = m * nc * 4
    emit(f"micro/two_pass_amm_{tag}", t_two,
         f"idx intermediate {idx_bytes/1e3:.1f}KB")
    emit(f"micro/fused_amm_{tag}", t_fused,
         f"idx bytes eliminated {idx_bytes/1e3:.1f}KB; "
         f"{t_two/t_fused:.2f}x vs two-pass")


def run() -> None:
    key = jax.random.PRNGKey(0)
    m, k, n, v, c = 512, 768, 768, 8, 16
    nc = k // v
    x = jax.random.normal(key, (m, nc, v))
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v))
    w = jax.random.normal(jax.random.fold_in(key, 2), (k, n))
    lut = build_lut(w, z)

    assign_j = jax.jit(lambda a, b: ref.assign_ref(a, b, "l2"))
    t = time_jax(assign_j, x, z)
    emit("micro/assign_l2_512x768", t, f"{m*nc*c*2/t*1e6/1e9:.1f} GFLOP/s")

    idx = assign_j(x, z)
    lookup_j = jax.jit(ref.lut_gemm_onehot)
    t = time_jax(lookup_j, idx, lut)
    emit("micro/lut_gemm_onehot_512x768x768", t,
         f"{2*m*nc*c*n/t*1e6/1e9:.1f} GFLOP/s")

    dense_j = jax.jit(lambda a, b: a @ b)
    xf = x.reshape(m, k)
    t_dense = time_jax(dense_j, xf, w)
    emit("micro/dense_gemm_512x768x768", t_dense,
         f"{2*m*k*n/t_dense*1e6/1e9:.1f} GFLOP/s")

    # int8 table halves the bytes the lookup streams (the TPU decode win)
    from repro.core.lut import quantize_lut_int8
    lut8, scale = quantize_lut_int8(lut)
    lookup8_j = jax.jit(lambda i, l, s: ref.lut_gemm_onehot(i, l, s))
    t8 = time_jax(lookup8_j, idx, lut8, scale)
    emit("micro/lut_gemm_int8", t8,
         f"bytes {lut8.nbytes/1e6:.1f}MB vs bf16 weights {w.nbytes*0.5/1e6:.1f}MB")

    # fused assign+lookup vs the two-pass pipeline, prefill + decode shapes
    _bench_fused_vs_two_pass(x, z, lut, f"{m}x{k}x{n}")
    md = 8                                            # decode-shaped batch
    xd = jax.random.normal(jax.random.fold_in(key, 3), (md, nc, v))
    _bench_fused_vs_two_pass(xd, z, lut, f"{md}x{k}x{n}")
