"""Microbenchmarks of the core ops on this host (CPU, ref impl + Pallas
interpret) — wall-time sanity, not TPU numbers (those come from the
dry-run roofline).

Run:  PYTHONPATH=src python -m benchmarks.kernels_micro [--snapshot auto]

``--snapshot PATH`` (or ``auto`` = repo-root ``BENCH_kernels.json``)
writes every emitted row plus run metadata as a JSON perf snapshot —
the kernel-side half of the ROADMAP item 5 trajectory (serve_bench
writes the serving half to ``BENCH_serve.json``).
"""
import jax
import jax.numpy as jnp

from repro.core.lut import build_lut
from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_paged
from repro.kernels.ops import lut_matmul, vq_assign
from repro.models.layers import _sdpa_decode_combine

try:                                 # `python -m benchmarks.kernels_micro`
    from .common import emit, snapshot, time_jax, time_jax_pair
except ImportError:                  # `python benchmarks/kernels_micro.py`
    from common import emit, snapshot, time_jax, time_jax_pair


def _bench_fused_vs_two_pass(x, z, lut, tag: str) -> None:
    """micro/fused_amm_* rows: one jitted fused program (assignment feeds the
    LUT contraction with no materialised index tensor) against the two-pass
    pipeline that writes the (M, nc) int32 indices out between kernels.

    The two variants are timed interleaved (best-of-N) so host scheduler
    noise hits both equally — the ratio is the signal, not the wall time.
    """
    m, nc, _ = x.shape

    assign_j = jax.jit(lambda a, b: ref.assign_ref(a, b, "l2"))
    lookup_j = jax.jit(ref.lut_gemm_onehot)

    def two_pass(a, b, l):
        idx = assign_j(a, b)            # (M, nc) int32 round-trip
        return lookup_j(idx, l)

    fused_j = jax.jit(lambda a, b, l: ref.vq_amm_ref(a, b, l, metric="l2"))

    t_two, t_fused = time_jax_pair(two_pass, fused_j, x, z, lut,
                                   warmup=3, iters=30)
    idx_bytes = m * nc * 4
    emit(f"micro/two_pass_amm_{tag}", t_two,
         f"idx intermediate {idx_bytes/1e3:.1f}KB")
    emit(f"micro/fused_amm_{tag}", t_fused,
         f"idx bytes eliminated {idx_bytes/1e3:.1f}KB; "
         f"{t_two/t_fused:.2f}x vs two-pass")


def _bench_flash_decode() -> None:
    """micro/flash_* rows: paged decode attention off the page table.

    A/B at an 8k-token context: the legacy gather formulation (pool ->
    dense per-slot KV view -> ``_sdpa_decode_combine``, re-materialised
    every step) against ``flash_decode_paged(impl="ref")``, which scores
    the pool in place and never builds the view (scores are ~2*D/G
    smaller per token than K+V rows). Interleaved best-of-N — the ratio
    is the signal. A small ``impl="pallas"`` interpret-mode row rides
    along as a correctness canary for the real kernel's grid/index maps
    (interpret wall-time is NOT indicative of TPU performance).
    """
    key = jax.random.PRNGKey(11)
    b, kvh, g, d, ps, np_ = 2, 4, 2, 64, 16, 512       # 8192 tokens/slot
    h = kvh * g
    pool = b * np_ + 1                                 # last page = trash
    ks = {}
    for i, nm in enumerate(("k", "v", "q", "kn", "vn")):
        ks[nm] = jax.random.fold_in(key, i)
    k_pages = jax.random.normal(ks["k"], (pool, ps, kvh, d)) * 0.3
    v_pages = jax.random.normal(ks["v"], (pool, ps, kvh, d)) * 0.3
    q = jax.random.normal(ks["q"], (b, 1, h, d))
    k_new = jax.random.normal(ks["kn"], (b, 1, kvh, d)) * 0.3
    v_new = jax.random.normal(ks["vn"], (b, 1, kvh, d)) * 0.3
    phys = jnp.arange(b * np_, dtype=jnp.int32).reshape(b, np_)
    pos = jnp.array([np_ * ps - 1] * b, jnp.int32)     # pos 8191: full ctx

    def gather(q, kp, vp, kn, vn, ph, po):
        view_k = kp[ph].reshape(b, np_ * ps, kvh, d)   # the HBM gather
        view_v = vp[ph].reshape(b, np_ * ps, kvh, d)
        return _sdpa_decode_combine(q, view_k, view_v, kn, vn, po, 0, 0)

    def flash(q, kp, vp, kn, vn, ph, po):
        return flash_decode_paged(q, kp, vp, kn, vn, ph, po, impl="ref")

    gather_j, flash_j = jax.jit(gather), jax.jit(flash)
    out_g = gather_j(q, k_pages, v_pages, k_new, v_new, phys, pos)
    out_f = flash_j(q, k_pages, v_pages, k_new, v_new, phys, pos)
    diff = float(jnp.max(jnp.abs(out_g - out_f)))
    assert diff < 2e-4, f"flash ref diverged from gather oracle: {diff}"
    t_g, t_f = time_jax_pair(gather_j, flash_j, q, k_pages, v_pages,
                             k_new, v_new, phys, pos, warmup=3, iters=20)
    view_mb = 2 * b * np_ * ps * kvh * d * 4 / 1e6
    tag = f"{b}x{np_ * ps}x{h}h{d}"
    emit(f"micro/flash_gather_decode_{tag}", t_g,
         f"dense KV view {view_mb:.1f}MB/step")
    emit(f"micro/flash_ref_decode_{tag}", t_f,
         f"view eliminated; {t_g / t_f:.2f}x vs gather; "
         f"max|diff|={diff:.1e}")

    # interpret-mode Pallas canary: tiny shapes (interpret is slow), the
    # row proves the scalar-prefetch page-table kernel stays oracle-exact
    sb, snp = 2, 8                                     # 128-token context
    s_phys = jnp.arange(sb * snp, dtype=jnp.int32).reshape(sb, snp)
    s_pos = jnp.array([snp * ps - 1] * sb, jnp.int32)
    sq = q[:sb]
    s_pool = sb * snp + 1
    flash_p = jax.jit(lambda q_, kp, vp, kn, vn, ph, po: flash_decode_paged(
        q_, kp, vp, kn, vn, ph, po, impl="pallas",
        interpret=jax.default_backend() != "tpu"))
    args_p = (sq, k_pages[:s_pool], v_pages[:s_pool], k_new[:sb],
              v_new[:sb], s_phys, s_pos)
    out_p = flash_p(*args_p)
    ref_p = flash_j(sq, k_pages[:s_pool], v_pages[:s_pool], k_new[:sb],
                    v_new[:sb], s_phys, s_pos)
    diff_p = float(jnp.max(jnp.abs(out_p - ref_p)))
    assert diff_p < 2e-4, f"pallas kernel diverged from ref: {diff_p}"
    t_p = time_jax(flash_p, *args_p, warmup=1, iters=3)
    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    emit(f"micro/flash_pallas_{mode}_{sb}x{snp * ps}x{h}h{d}", t_p,
         f"max|diff| vs ref={diff_p:.1e}")


def run() -> None:
    key = jax.random.PRNGKey(0)
    m, k, n, v, c = 512, 768, 768, 8, 16
    nc = k // v
    x = jax.random.normal(key, (m, nc, v))
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v))
    w = jax.random.normal(jax.random.fold_in(key, 2), (k, n))
    lut = build_lut(w, z)

    assign_j = jax.jit(lambda a, b: ref.assign_ref(a, b, "l2"))
    t = time_jax(assign_j, x, z)
    emit("micro/assign_l2_512x768", t, f"{m*nc*c*2/t*1e6/1e9:.1f} GFLOP/s")

    idx = assign_j(x, z)
    lookup_j = jax.jit(ref.lut_gemm_onehot)
    t = time_jax(lookup_j, idx, lut)
    emit("micro/lut_gemm_onehot_512x768x768", t,
         f"{2*m*nc*c*n/t*1e6/1e9:.1f} GFLOP/s")

    dense_j = jax.jit(lambda a, b: a @ b)
    xf = x.reshape(m, k)
    t_dense = time_jax(dense_j, xf, w)
    emit("micro/dense_gemm_512x768x768", t_dense,
         f"{2*m*k*n/t_dense*1e6/1e9:.1f} GFLOP/s")

    # int8 table halves the bytes the lookup streams (the TPU decode win)
    from repro.core.lut import quantize_lut_int8
    lut8, scale = quantize_lut_int8(lut)
    lookup8_j = jax.jit(lambda i, l, s: ref.lut_gemm_onehot(i, l, s))
    t8 = time_jax(lookup8_j, idx, lut8, scale)
    emit("micro/lut_gemm_int8", t8,
         f"bytes {lut8.nbytes/1e6:.1f}MB vs bf16 weights {w.nbytes*0.5/1e6:.1f}MB")

    # fused assign+lookup vs the two-pass pipeline, prefill + decode shapes
    _bench_fused_vs_two_pass(x, z, lut, f"{m}x{k}x{n}")
    md = 8                                            # decode-shaped batch
    xd = jax.random.normal(jax.random.fold_in(key, 3), (md, nc, v))
    _bench_fused_vs_two_pass(xd, z, lut, f"{md}x{k}x{n}")

    # paged flash-decode attention vs the legacy gather path
    _bench_flash_decode()


def main(argv=None) -> None:
    import argparse
    import os
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--snapshot", default="",
                    help="write a BENCH_kernels.json perf snapshot to this "
                         "path ('auto' = repo root)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run()
    if args.snapshot:
        path = args.snapshot
        if path == "auto":
            path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "BENCH_kernels.json")
        snapshot(os.path.normpath(path), bench="kernels_micro")


if __name__ == "__main__":
    main()
