"""Microbenchmarks of the core ops on this host (CPU, ref impl + Pallas
interpret) — wall-time sanity, not TPU numbers (those come from the
dry-run roofline)."""
import jax
import jax.numpy as jnp

from repro.core.lut import build_lut
from repro.kernels import ref
from repro.kernels.ops import lut_matmul, vq_assign

from .common import emit, time_jax


def run() -> None:
    key = jax.random.PRNGKey(0)
    m, k, n, v, c = 512, 768, 768, 8, 16
    nc = k // v
    x = jax.random.normal(key, (m, nc, v))
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v))
    w = jax.random.normal(jax.random.fold_in(key, 2), (k, n))
    lut = build_lut(w, z)

    assign_j = jax.jit(lambda a, b: ref.assign_ref(a, b, "l2"))
    t = time_jax(assign_j, x, z)
    emit("micro/assign_l2_512x768", t, f"{m*nc*c*2/t*1e6/1e9:.1f} GFLOP/s")

    idx = assign_j(x, z)
    lookup_j = jax.jit(ref.lut_gemm_onehot)
    t = time_jax(lookup_j, idx, lut)
    emit("micro/lut_gemm_onehot_512x768x768", t,
         f"{2*m*nc*c*n/t*1e6/1e9:.1f} GFLOP/s")

    dense_j = jax.jit(lambda a, b: a @ b)
    xf = x.reshape(m, k)
    t_dense = time_jax(dense_j, xf, w)
    emit("micro/dense_gemm_512x768x768", t_dense,
         f"{2*m*k*n/t_dense*1e6/1e9:.1f} GFLOP/s")

    # int8 table halves the bytes the lookup streams (the TPU decode win)
    from repro.core.lut import quantize_lut_int8
    lut8, scale = quantize_lut_int8(lut)
    lookup8_j = jax.jit(lambda i, l, s: ref.lut_gemm_onehot(i, l, s))
    t8 = time_jax(lookup8_j, idx, lut8, scale)
    emit("micro/lut_gemm_int8", t8,
         f"bytes {lut8.nbytes/1e6:.1f}MB vs bf16 weights {w.nbytes*0.5/1e6:.1f}MB")
