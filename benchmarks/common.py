"""Shared benchmark utilities: timing, CSV row emission, JSON snapshots."""
from __future__ import annotations

import json
import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def snapshot(path: str, **meta) -> dict:
    """Write every row emitted so far (plus ``meta``) as a JSON snapshot.

    The snapshot is the on-disk perf trajectory (ROADMAP item 5): commit
    one per meaningful change and diff them to see regressions. Rows keep
    the ``emit`` schema — name, metric value, free-form derived stats.
    """
    rows = []
    for row in ROWS:
        name, val, derived = row.split(",", 2)
        rows.append({"name": name, "value": float(val), "derived": derived})
    doc = {"date": time.strftime("%Y-%m-%d"),
           "backend": jax.default_backend(),
           "device_count": jax.device_count(),
           **meta, "rows": rows}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[snapshot] {len(rows)} row(s) -> {path}")
    return doc


def time_jax(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jitted callable on this host."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def time_jax_pair(fn_a: Callable, fn_b: Callable, *args,
                  warmup: int = 2, iters: int = 10) -> tuple:
    """Best-of-N wall-time (µs) for two callables, measured interleaved.

    Interleaving + min makes A/B comparisons robust to host scheduler
    noise: a slow slice of the machine penalises both variants equally,
    and the minimum approximates the noise-free cost of each.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        best_a = min(best_a, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        best_b = min(best_b, (time.perf_counter() - t0) * 1e6)
    return best_a, best_b
