"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def time_jax(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jitted callable on this host."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]
