"""Shared benchmark utilities: timing, row emission, JSON snapshots.

Snapshot writing lives in :mod:`repro.obs.snapshot` (one schema for the
perf gate to trust); this module keeps the tiny ``emit``/``ROWS``
surface the benchmark scripts share and forwards the on-disk format.
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax

from repro.obs.snapshot import make_row, write_snapshot

ROWS: List[dict] = []


def emit(name: str, value: float, derived: str = "", unit: str = "us",
         direction: str = "down", tol: float = None) -> None:
    """Record one benchmark row (schema: repro.obs.snapshot.make_row).

    Defaults describe the common case — a CPU timer in microseconds
    where smaller is better. Ratio/accuracy rows should pass an explicit
    ``unit``/``direction`` (and optionally ``tol``) so the perf gate
    applies the right comparison.
    """
    row = make_row(name, value, derived=derived, unit=unit,
                   direction=direction, tol=tol)
    ROWS.append(row)
    print(f"{name},{value:.3f},{derived}")


def snapshot(path: str, **meta) -> dict:
    """Write every row emitted so far (plus ``meta``) as a JSON snapshot.

    The snapshot is the on-disk perf trajectory (ROADMAP item 5): commit
    one per meaningful change; ``scripts/perf_gate.py`` diffs fresh runs
    against the committed copy and fails CI on regressions.
    """
    return write_snapshot(path, ROWS, **meta)


def time_jax(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jitted callable on this host."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def time_jax_pair(fn_a: Callable, fn_b: Callable, *args,
                  warmup: int = 2, iters: int = 10) -> tuple:
    """Best-of-N wall-time (µs) for two callables, measured interleaved.

    Interleaving + min makes A/B comparisons robust to host scheduler
    noise: a slow slice of the machine penalises both variants equally,
    and the minimum approximates the noise-free cost of each.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        best_a = min(best_a, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        best_b = min(best_b, (time.perf_counter() - t0) * 1e6)
    return best_a, best_b
