"""Paper Table II / Fig 7: multistage vs single-stage LUTBoost training.

Scaled-down proxy (CPU container): a small transformer LM on the synthetic
successor task. The claim under test is RELATIVE — multistage (k-means init
+ centroid-only warmup + joint) converges to a better loss than single-stage
(random centroids, joint from scratch), for both L2 and L1 similarity.
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.lut import QuantConfig
from repro.core.lutboost import LutBoostSchedule, convert
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.train import TrainConfig, Trainer

from .common import emit


def _train(metric: str, multistage: bool, steps: int = 140,
           seed: int = 0) -> float:
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64, seed=seed)
    qc = QuantConfig(mode="lut_train", v=4, c=16, metric=metric,
                     recon_weight=0.05)
    params = m.init(jax.random.PRNGKey(seed), qc)

    # warm-start the dense weights so conversion (not LM training from
    # scratch) is what's being measured — mirrors the paper's setting of
    # converting a trained model.
    dense = Trainer(m, ds, qc.replace(mode="dense"),
                    TrainConfig(total_steps=100, lr=3e-3, warmup=10,
                                log_every=10**9))
    params, _, _ = dense.run(params)

    if multistage:
        params = convert(lambda p, b: m.forward(
            p, b, qc.replace(mode="dense"))[0], params, ds.batch(0), qc)
        sched = LutBoostSchedule(stage2_steps=40, stage3_steps=steps - 40)
    else:
        sched = None      # single stage: random centroids, joint training
    tc = TrainConfig(total_steps=steps, lr=1e-3, warmup=0, log_every=10**9)
    _, _, hist = Trainer(m, ds, qc, tc, lutboost=sched).run(params)
    return float(np.mean(hist["loss"][-10:]))


def run() -> None:
    for metric in ("l2", "l1"):
        single = _train(metric, multistage=False)
        multi = _train(metric, multistage=True)
        emit(f"table2/single_stage_{metric}", 0.0, f"loss={single:.4f}")
        emit(f"table2/multi_stage_{metric}", 0.0,
             f"loss={multi:.4f} delta={single - multi:+.4f} "
             f"(paper: multistage +3.3-7.2 acc pts)")
