"""Paper Tables VII, VIII, IX + Fig 1/9: hardware models & simulator."""
from repro.dse.models import LutDlaPoint, imm_resources
from repro.dse.ppa import (PPA_TABLE, design_ppa, dpe_cost,
                           efficiency_curves, scale_to_node)
from repro.simulator.cycle_sim import LutDlaSim, PqaSim

from .common import emit

PAPER_T7 = {  # (v, c, Tn, M) -> (SRAM KB, GB/s)
    "design1": ((3, 16, 128, 256), (36.1, 4.1)),
    "design2": ((4, 16, 256, 256), (72.1, 7.0)),
    "design3": ((3, 16, 768, 512), (408.2, 8.7)),
}


def run() -> None:
    # ---- Table VII: IMM settings & resources ---------------------------
    for name, ((v, c, tn, m), (sram_p, bw_p)) in PAPER_T7.items():
        r = imm_resources(v=v, c=c, tile_n=tn, m=m)
        emit(f"table7/{name}", 0.0,
             f"sram={r['sram_kb']:.1f}KB (paper {sram_p}) "
             f"bw={r['bandwidth_gbs']:.1f}GB/s (paper {bw_p})")

    # ---- Table VIII: PPA vs other accelerators -------------------------
    for name in ("NVDLA-Small", "NVDLA-Large", "Gemmini", "LUT-DLA-1",
                 "LUT-DLA-2", "LUT-DLA-3"):
        e = PPA_TABLE[name]
        scaled = scale_to_node(e, 28)
        emit(f"table8/{name}", 0.0,
             f"area_eff={e['gops'] / e['area']:.1f}GOPS/mm2 "
             f"power_eff={e['gops'] / e['power']:.2f}GOPS/mW "
             f"(28nm-scaled: {scaled.area_eff:.1f}, {scaled.power_eff:.2f})")
    d3, nvl = PPA_TABLE["LUT-DLA-3"], PPA_TABLE["NVDLA-Large"]
    emit("table8/improvement", 0.0,
         f"area_eff x{(d3['gops']/d3['area'])/(nvl['gops']/nvl['area']):.1f} "
         f"power_eff x{(d3['gops']/d3['power'])/(nvl['gops']/nvl['power']):.1f} "
         f"(paper: 1.5-146.1x area, 1.4-7.0x power across baselines)")

    # our analytical generator reproducing the three designs
    for name, pt, m_rows, paper in [
        ("gen_design1", LutDlaPoint(v=3, c=16, n_imm=6, tile_n=128), 256,
         (0.755, 219.57, 460.8)),
        ("gen_design2", LutDlaPoint(v=4, c=16, n_imm=8, tile_n=256), 256,
         (1.701, 314.975, 1228.8)),
        ("gen_design3", LutDlaPoint(v=3, c=16, n_imm=6, tile_n=768), 512,
         (3.64, 496.4, 2764.8)),
    ]:
        p = design_ppa(pt, m_rows=m_rows)
        emit(f"table8/{name}", 0.0,
             f"area={p.area_mm2:.2f}mm2 power={p.power_mw:.0f}mW "
             f"perf={p.perf_gops:.0f}GOPS (paper: {paper[0]}mm2 "
             f"{paper[1]}mW {paper[2]}GOPS)")

    # ---- Table IX: vs PQA ----------------------------------------------
    pt = LutDlaPoint(v=4, c=32, tile_n=128, bits_lut=8)
    r_ls = LutDlaSim(pt).gemm_cycles(512, 768, 768)
    r_pqa = PqaSim(pt).gemm_cycles(512, 768, 768)
    emit("table9/lutdla", 0.0,
         f"cycles={r_ls['cycles'] / 1e3:.0f}k onchip={r_ls['onchip_kb']:.1f}KB "
         f"(paper 4743k / 10.5KB)")
    emit("table9/pqa", 0.0,
         f"cycles={r_pqa['cycles'] / 1e3:.0f}k "
         f"onchip={r_pqa['onchip_kb'] / 1024:.1f}MB (paper 7864k / 6.75MB)")
    emit("table9/speedup", 0.0,
         f"{r_pqa['cycles'] / r_ls['cycles']:.2f}x (paper 1.66x)")

    # ---- Fig 1: LUT vs ALU efficiency ----------------------------------
    rows = efficiency_curves()
    alu8 = next(r for r in rows if r["name"] == "int8")
    best = max((r for r in rows if r["kind"] == "lut"),
               key=lambda r: r["ops_per_um2"])
    emit("fig1/best_lut_vs_int8_alu", 0.0,
         f"{best['name']}: area_eff x{best['ops_per_um2']/alu8['ops_per_um2']:.0f} "
         f"power_eff x{best['ops_per_nw']/alu8['ops_per_nw']:.0f} "
         f"(paper: 1-5 / 1-2 orders of magnitude)")

    # ---- Fig 9: dPE area/energy by metric ------------------------------
    for metric in ("l2", "l1", "chebyshev"):
        d = dpe_cost(8, metric)
        emit(f"fig9/dpe_{metric}", 0.0,
             f"area={d['area_um2']:.0f}um2 energy={d['energy_pj']:.2f}pJ")
