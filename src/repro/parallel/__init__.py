"""Distribution: sharding rules (DP/TP/EP/SP), pipeline parallelism."""
from .sharding import (batch_pspecs, cache_pspecs, paged_cache_pspecs,
                       param_pspecs, logical_to_sharding)
