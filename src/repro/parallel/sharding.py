"""Sharding rules: params / batches / caches → PartitionSpec trees.

Strategy (megatron-style TP on the ``model`` axis, DP over ``pod``דdata``):

* embeddings shard on the vocab dim; attention q/k/v shard heads
  (column-parallel), the output projection is row-parallel; MLP up/gate are
  column-parallel, down is row-parallel. MoE experts shard the *expert* dim
  (EP). Mamba's fused in_proj is column-parallel, out_proj row-parallel.
* LUT-DLA artefacts: codebooks ``z`` are tiny and follow the *input* (K)
  dim of their projection — replicated for column-parallel projections,
  subspace-sharded for row-parallel ones (assignment is then local to the
  shard, and the LUT accumulate produces partial sums that reduce exactly
  like a dense row-parallel matmul). Precomputed LUTs ``(nc, c, N)`` shard
  like the weight they replace: N for column-parallel, nc for row-parallel.
* KV caches: batch over the data axes when batch ≥ their product,
  otherwise the *sequence* dim is sharded over ``data`` (SP long-context
  decode; GSPMD inserts the distributed-softmax collectives).

Everything is path-rule based so it applies uniformly to stacked scan
params (leading layer dim) and per-expert weights.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _spec_for_leaf(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                   model_axis: str, msize: int) -> P:
    """Per-leaf PartitionSpec. `path` is the keystr, `shape` the leaf shape.

    Any dim assigned to the model axis must divide its size; otherwise that
    dim falls back to replicated (e.g. mamba2's vocab 50280 on a 16-way
    axis)."""
    m = model_axis
    ndim = len(shape)

    def lead(base: Tuple, want_ndim: int) -> P:
        """Left-pad `base` with None (stacked layer / expert dims) and drop
        the model axis from any non-divisible dim."""
        pad = want_ndim - len(base)
        axes = [None] * pad + list(base)
        axes = [a if (a is None or shape[i] % msize == 0) else None
                for i, a in enumerate(axes)]
        return P(*axes)

    # ---- embeddings & heads -------------------------------------------
    if "embed" in path and ndim == 2:
        return lead((m, None), ndim)            # vocab-sharded
    if "heads" in path and ndim == 3:           # audio heads (Q, D, V)
        return lead((None, None, m), ndim)
    if "head" in path and ndim == 2:
        return lead((None, m), ndim)
    if "in_proj']" in path and "blocks" not in path and ndim == 2:
        return P()                              # audio stub input proj (tiny)

    # ---- MoE ----------------------------------------------------------
    if "router" in path:
        return lead((None, None), ndim)         # replicated (tiny, hot)
    if "shared_w" in path:
        # shared experts are few (can't shard E over the model axis):
        # tensor-parallel instead — up/gate column-parallel, down row-parallel.
        rowwise = "shared_wd" in path
        if path.endswith("['w']"):              # (..., SE, K, N)
            return lead((None, m, None) if rowwise else (None, None, m), ndim)
        if path.endswith("['z']"):
            return lead((None, m, None, None) if rowwise
                        else (None, None, None, None), ndim)
        if path.endswith("['lut']"):            # (..., SE, nc, c, N)
            return lead((None, m, None, None) if rowwise
                        else (None, None, None, m), ndim)
        if path.endswith("['lut_scale']"):
            return lead((None, None) if rowwise else (None, m), ndim)
    for key in ("wg", "wu", "wd"):
        if f"['{key}']" in path and "moe" in path:
            if path.endswith("['w']"):          # (..., E, K, N)
                return lead((m, None, None), ndim)
            if path.endswith("['z']"):          # (..., E, nc, c, v)
                return lead((m, None, None, None), ndim)
            if path.endswith("['lut']"):        # (..., E, nc, c, N)
                return lead((m, None, None, None), ndim)
            if path.endswith("['lut_scale']"):
                return lead((m, None), ndim)

    # ---- column-parallel projections (shard output dim N) -------------
    col = ("['wq']", "['wk']", "['wv']", "['wg']", "['wu']", "['in_proj']")
    # ---- row-parallel projections (shard input dim K = nc·v) ----------
    row = ("['wo']", "['wd']", "['out_proj']")

    if any(k in path for k in col):
        if path.endswith("['w']"):
            return lead((None, m), ndim)
        if path.endswith("['b']"):
            return lead((m,), ndim)
        if path.endswith("['z']"):
            return lead((None, None, None), ndim)          # replicate
        if path.endswith("['lut']"):
            return lead((None, None, m), ndim)             # N-sharded
        if path.endswith("['lut_scale']"):
            return lead((m,), ndim)
    if any(k in path for k in row):
        if path.endswith("['w']"):
            return lead((m, None), ndim)
        if path.endswith("['b']"):
            return lead((None,), ndim)
        if path.endswith("['z']"):
            return lead((m, None, None), ndim)             # subspace-sharded
        if path.endswith("['lut']"):
            return lead((m, None, None), ndim)             # nc-sharded
        if path.endswith("['lut_scale']"):
            return lead((None,), ndim)

    # ---- mamba channelwise params --------------------------------------
    if "conv_w" in path:
        return lead((None, m), ndim)           # (K, C): channels sharded
    if "conv_b" in path or "gate_norm" in path:
        return lead((m,), ndim)
    if any(k in path for k in ("dt_bias", "A_log", "['D']")):
        return lead((m,), ndim)                # per-head

    # ---- norms & leftovers: replicated ---------------------------------
    return P(*([None] * ndim))


def param_pspecs(params, cfg: ModelConfig, model_axis: str = "model",
                 model_axis_size: int = 16):
    """PartitionSpec tree matching `params`."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_leaf(_path_str(path),
                                          tuple(getattr(leaf, "shape", ())),
                                          cfg, model_axis, model_axis_size),
        params)


def batch_pspecs(cfg: ModelConfig, data_axes: Tuple[str, ...] = ("data",)):
    """PartitionSpecs for a training batch (batch dim over all DP axes)."""
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    if cfg.family == "audio":
        return {"embeds": P(da, None, None), "labels": P(da, None, None)}
    if cfg.family == "vlm":
        return {"patch_embeds": P(da, None, None), "tokens": P(da, None)}
    return {"tokens": P(da, None)}


def cache_pspecs(cfg: ModelConfig, batch_size: int, mesh: Mesh,
                 data_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model"):
    """PartitionSpecs for a decode cache (see Model.init_cache layout).

    If the batch covers the data axes, shard batch; otherwise shard the
    sequence dim over `data` (SP — long-context decode with batch=1).
    """
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    batch_first = batch_size % dp == 0 and batch_size >= dp
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    m = model_axis
    msize = mesh.shape[m]
    mh, md = _kv_model_axes(cfg, m, msize)

    if batch_first:
        kv = P(None, da, None, mh, md)          # (L, B, T, KVH, D)
    else:
        # SP: sequence over data (long-context, batch=1); GSPMD inserts the
        # distributed-softmax collectives for attention over the shards.
        kv = P(None, None,
               da if len(data_axes) == 1 else "data", mh, md)

    pos = P()
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return {"layers": {"k": kv, "v": kv}, "pos": pos}

    mamba = {
        "conv": P(None, da if batch_first else None, None, m),
        "h": P(None, da if batch_first else None, m, None, None),
    }
    if cfg.family == "ssm":
        return {"layers": mamba, "pos": pos}
    return {"layers": {"mamba": mamba, "attn": {"k": kv, "v": kv}},
            "pos": pos}


def _kv_model_axes(cfg: ModelConfig, model_axis: str, msize: int):
    """Model-axis placement inside a KV cache: prefer kv heads, fall back
    to head_dim (matches the column-parallel wk/wv output sharding), else
    replicate across model."""
    kvh, hd = cfg.num_kv_heads, (cfg.head_dim or 0)
    if kvh and kvh % msize == 0:
        return model_axis, None
    if hd and hd % msize == 0:
        return None, model_axis
    return None, None


def paged_cache_pspecs(cfg: ModelConfig, mesh: Mesh,
                       model_axis: str = "model", quantized: bool = False):
    """PartitionSpecs for the PAGED serving cache (Model.init_paged_cache).

    Layout (see docs/serving.md): attention families store KV as a physical
    page pool ``(L, num_pages+1, page_size, KVH, HD)``. The pool — pages,
    trash page included — is REPLICATED over the data axes: data-parallel
    serving is replica-level (one engine + page table per replica group,
    ``repro.serve.router``), so within one engine only tensor parallelism
    applies, on the kv-head / head-dim axis exactly like the dense cache.
    SSM / hybrid slot-indexed state shards its channel / head dims over
    ``model`` (falling back to replicated on non-divisible dims). The page
    table, positions and tokens are host-managed and replicated.

    ``quantized``: the pool holds uint8 codes ``(L, P+1, page, KVH, nc)``
    plus the codebook pytree. kv heads still shard over ``model`` when
    divisible, but the last dim is the SUBSPACE axis, not head_dim — the
    head_dim fallback does not apply (a centroid spans ``v`` contiguous
    fp lanes that one device must own), so it replicates instead. The
    codebook tables are small and replicated everywhere.
    """
    m = model_axis
    msize = mesh.shape[m]
    mh, md = _kv_model_axes(cfg, m, msize)
    if quantized:
        md = None                           # last dim = subspaces, whole
    kv = P(None, None, None, mh, md)        # (L, P+1, page, KVH, HD|nc)
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if quantized:
            cbook = {"zk": P(), "zv": P(), "sk": P(), "sv": P()}
            return {"k": kv, "v": kv, "codebook": cbook}
        return {"k": kv, "v": kv}
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    mamba = {
        # (L, slots, K-1, C): channels over model, like the dense cache
        "conv": P(None, None, None,
                  m if conv_dim % msize == 0 else None),
        # (L, slots, H, HP, N): ssm heads over model
        "h": P(None, None,
               m if cfg.ssm_nheads % msize == 0 else None, None, None),
    }
    if cfg.family == "ssm":
        return mamba
    # hybrid: slot-dense shared-attn cache (n_inv, slots, T+1, KVH, HD)
    return {"mamba": mamba, "attn": {"k": kv, "v": kv}}


def logical_to_sharding(specs, mesh: Mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda s: isinstance(s, P))
