"""GPipe-style pipeline parallelism over a mesh axis, via shard_map.

Each device along the ``stage`` axis holds one contiguous slice of the layer
stack; microbatches stream through with ``collective_permute`` moving
activations stage→stage. The schedule is the classic GPipe fill/steady/drain
with ``n_micro + n_stages - 1`` ticks.

The production dry-run meshes use DP×TP (the assigned topology); this module
provides the PP primitive for deployments that want depth-wise scaling —
tested on small meshes in tests/test_pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(f, mesh, in_specs, out_specs):
    # jax >= 0.6 exposes jax.shard_map (check_vma); older versions ship it
    # under jax.experimental.shard_map with the check_rep spelling.
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def pipeline(block_fn: Callable, n_stages: int, n_micro: int,
             axis: str = "stage"):
    """Build a pipelined forward: f(stage_params, x_micro) -> y_micro.

    block_fn(params_slice, x) -> y applies this stage's layers.
    stage_params: pytree with leading dim = n_stages (sharded over `axis`).
    x_micro: (n_micro, mb, ...) microbatched input (replicated).
    Returns (n_micro, mb, ...) output of the LAST stage.
    """

    def staged(params_local, x_micro):
        # params_local: (1, ...) this stage's slice; x_micro replicated
        stage = jax.lax.axis_index(axis)
        params_me = jax.tree_util.tree_map(lambda t: t[0], params_local)
        mb_shape = x_micro.shape[1:]
        state = jnp.zeros(mb_shape, x_micro.dtype)     # current activation
        outputs = jnp.zeros_like(x_micro)              # collected at last stage

        def tick(carry, t):
            state, outputs = carry
            # stage 0 injects microbatch t (when in range)
            inject = jnp.clip(t, 0, n_micro - 1)
            x_in = x_micro[inject]
            state = jnp.where(stage == 0,
                              jnp.where(t < n_micro, x_in, state), state)
            # every stage processes its current activation
            y = block_fn(params_me, state)
            # last stage's result for microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            take = (t >= n_stages - 1) & (stage == n_stages - 1)
            outputs = jnp.where(
                take,
                jax.lax.dynamic_update_index_in_dim(
                    outputs, y, out_idx, 0),
                outputs)
            # shift activations stage i -> i+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(y, axis, perm)
            return (state, outputs), None

        (_, outputs), _ = jax.lax.scan(
            tick, (state, outputs), jnp.arange(n_micro + n_stages - 1))
        # the last stage holds the real outputs; broadcast to all stages
        outputs = jax.lax.ppermute(
            outputs, axis,
            [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)])
        return outputs

    return staged


def run_pipeline(mesh: Mesh, block_fn: Callable, stage_params, x,
                 n_micro: int, axis: str = "stage"):
    """Convenience wrapper: shard params over `axis`, microbatch x, run."""
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    x_micro = x.reshape(n_micro, b // n_micro, *x.shape[1:])

    staged = pipeline(block_fn, n_stages, n_micro, axis)
    fn = _shard_map(
        staged, mesh,
        in_specs=(P(axis), P()),            # params sharded, x replicated
        out_specs=P())
    y_micro = fn(stage_params, x_micro)
    return y_micro.reshape(b, *x.shape[1:])
