"""Deterministic, step-resumable synthetic data pipeline."""
from .synthetic import SyntheticDataset, make_batch_specs
