"""Synthetic token pipeline — deterministic and stateless-resumable.

Batches are a pure function of (seed, step): after a restart at step k, the
pipeline replays batch k exactly, which together with the checkpoint manager
gives bit-exact resume. Per-host sharding slices the global batch by
``process_index`` so a multi-host launch feeds each host its own shard
(single-process in this container, but the interface is the production one).

The token stream is a order-2 Markov chain over the vocabulary (structured
enough that models measurably learn; fully synthetic so the container needs
no datasets).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def _batch_key(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = self.global_batch // self.num_hosts

    def batch(self, step: int) -> Dict[str, jax.Array]:
        """Host-local batch for `step` (pure function of (seed, step))."""
        cfg = self.cfg
        key = jax.random.fold_in(_batch_key(self.seed, step), self.host_index)
        b, s, v = self.host_batch, self.seq_len, cfg.vocab_size
        if cfg.family == "audio":
            ke, kl = jax.random.split(key)
            return {
                "embeds": jax.random.normal(ke, (b, s, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(
                    kl, (b, s, cfg.num_codebooks), 0, v, jnp.int32)}
        if cfg.family == "vlm":
            # `seq_len` is the TOTAL sequence (image prefix + text)
            kp, kt = jax.random.split(key)
            text = max(s - cfg.num_patches, 1)
            return {
                "patch_embeds": jax.random.normal(
                    kp, (b, cfg.num_patches, cfg.d_model), jnp.float32),
                "tokens": self._markov_tokens(kt, b, text, v)}
        return {"tokens": self._markov_tokens(key, b, s, v)}

    def _markov_tokens(self, key, b, s, v) -> jax.Array:
        """Successor stream: t[i] = (t[i-1] + 1) % V with 10% random jumps.

        Optimal CE ≈ 0.9·(-ln 0.9) + 0.1·ln V — low enough that learning is
        measurable within tens of steps even for tiny smoke models."""
        k1, k2, k3 = jax.random.split(key, 3)
        t0 = jax.random.randint(k1, (b,), 0, v, jnp.int32)
        jumps = jax.random.bernoulli(k2, 0.1, (s, b))
        rand = jax.random.randint(k3, (s, b), 0, v, jnp.int32)

        def step_fn(prev, inp):
            jump, r = inp
            nxt = jnp.where(jump, r, (prev + 1) % v)
            return nxt, nxt

        _, toks = jax.lax.scan(step_fn, t0, (jumps, rand))
        return jnp.swapaxes(toks, 0, 1)


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run inputs)."""
    if cfg.family == "audio":
        return {"embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               dtype),
                "labels": jax.ShapeDtypeStruct(
                    (batch, seq, cfg.num_codebooks), jnp.int32)}
    if cfg.family == "vlm":
        # seq is the TOTAL sequence budget (image prefix + text)
        text = max(seq - cfg.num_patches, 1)
        return {"patch_embeds": jax.ShapeDtypeStruct(
                    (batch, cfg.num_patches, cfg.d_model), dtype),
                "tokens": jax.ShapeDtypeStruct((batch, text), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
