"""repro — LUT-DLA (vector-quantized LUT-based GEMM) framework in JAX."""
__version__ = "0.1.0"
