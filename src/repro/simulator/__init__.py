"""Cycle-approximate simulator of the LUT-DLA accelerator."""
from .cycle_sim import (LutDlaSim, PqaSim, simulate_gemm, simulate_network,
                        BERT_BASE_LAYERS, RESNET18_LAYERS)
