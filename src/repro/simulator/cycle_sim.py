"""Cycle-approximate simulator of LUT-DLA executing GEMMs (paper §VII-C).

Throughput model (calibrated against the paper's Table IX: 4743k cycles for
a 512×768×768 GEMM at c=32, v=4, 16 LUT banks):

  * IMM: ``banks × n_imm`` element-lookup-accumulates per cycle — total
    element accumulates are M·N·N_c.
  * CCM: one centroid distance per CCU per cycle (M·N_c·c comparisons),
    overlapped with lookups (decoupled clock domains, §IV-A).
  * LS dataflow: per (k, n-tile) the ping-pong buffer preloads the next
    LUT tile during the M-row sweep; a stall occurs only when
    load_cycles > compute_cycles (paper Table VII bandwidth condition).
  * PQA (Table IX comparison): whole-layer LUT must be resident before
    compute (no ping-pong / on-demand tiles) → full un-overlapped load
    stalls, whole-layer on-chip SRAM, same lookup throughput.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from repro.dse.models import LutDlaPoint


@dataclasses.dataclass
class LutDlaSim:
    pt: LutDlaPoint
    banks: int = 16                       # element lookups / cycle / IMM
    freq_hz: float = 300e6
    bw_gbs: float = 25.6                  # DDR4 (paper end-to-end setting)
    m_tile: int = 16                      # psum scratch rows (Table IX cfg)

    @property
    def bw_bytes_per_cycle(self) -> float:
        return self.bw_gbs * 1e9 / self.freq_hz

    def gemm_cycles(self, m: int, k: int, n: int) -> Dict[str, float]:
        pt = self.pt
        nc = math.ceil(k / pt.v)
        n_tiles = math.ceil(n / pt.tile_n)
        lut_tile_bytes = pt.c * pt.tile_n * pt.bits_lut / 8
        load_cycles = lut_tile_bytes / self.bw_bytes_per_cycle
        # per-(k, n-tile) lookup sweep over M rows
        tile_compute = m * pt.tile_n / (self.banks * pt.n_imm)
        stall_per_tile = max(0.0, load_cycles - tile_compute)
        tiles = nc * n_tiles
        lookup_total = tiles * (tile_compute + stall_per_tile)
        # CCM similarity: one distance per CCU-cycle, only on the first
        # n-tile pass (indices are buffered — Algorithm 1, line 5)
        sim_total = m * nc * pt.c / pt.n_ccu
        fill = tiles * 4
        cycles = max(lookup_total, sim_total) + fill
        return {
            "cycles": cycles, "stall_cycles": stall_per_tile * tiles,
            "sim_cycles": sim_total, "lookup_cycles": lookup_total,
            "fill": fill,
            "effective_acc_per_cycle": m * n * nc / cycles,
            "onchip_kb": (2 * lut_tile_bytes
                          + self.m_tile * pt.tile_n * 1     # int8 psum
                          + self.m_tile * pt.bits_idx / 8) / 1024,
        }

    def network_cycles(self, layers: List[Tuple[int, int, int]]
                       ) -> Dict[str, float]:
        tot = {"cycles": 0.0, "stall_cycles": 0.0, "macs": 0.0}
        for (m, k, n) in layers:
            r = self.gemm_cycles(m, k, n)
            tot["cycles"] += r["cycles"]
            tot["stall_cycles"] += r["stall_cycles"]
            tot["macs"] += m * k * n
        tot["time_s"] = tot["cycles"] / self.freq_hz
        tot["gops"] = 2 * tot["macs"] / tot["time_s"] / 1e9
        return tot


@dataclasses.dataclass
class PqaSim:
    """PQA-style execution (paper §VII-B / Table IX): the whole layer's LUT
    is loaded on-chip before compute starts (compute pause, no ping-pong
    overlap) and — per the paper's "does not allow for data reuse" — each
    of the `banks` lookup banks holds its own copy of the table (fp32
    entries, PQA's full-precision prototype). Calibrated against Table IX
    (7864k cycles)."""
    pt: LutDlaPoint
    banks: int = 16
    freq_hz: float = 300e6
    bw_gbs: float = 25.6
    entry_bits: int = 32

    @property
    def bw_bytes_per_cycle(self) -> float:
        return self.bw_gbs * 1e9 / self.freq_hz

    def gemm_cycles(self, m: int, k: int, n: int) -> Dict[str, float]:
        pt = self.pt
        nc = math.ceil(k / pt.v)
        lut_bytes = nc * pt.c * n * self.entry_bits / 8
        load = self.banks * lut_bytes / self.bw_bytes_per_cycle
        lookups = m * n * nc / (self.banks * pt.n_imm)
        sim = m * nc * pt.c / pt.n_ccu
        return {"cycles": load + max(lookups, sim),
                "stall_cycles": load,
                "onchip_kb": (lut_bytes + m * n * 1) / 1024}


# ---------------------------------------------------------------------------
# workload definitions (paper Fig 13: ResNet18 + BERT-base compute layers)
# ---------------------------------------------------------------------------

def _conv_as_gemm(hw: int, cin: int, cout: int, ksz: int,
                  stride: int = 1) -> Tuple[int, int, int]:
    out_hw = hw // stride
    return (out_hw * out_hw, cin * ksz * ksz, cout)


#: ResNet18 @224 conv layers (im2col GEMM shapes), batch 1
RESNET18_LAYERS: List[Tuple[int, int, int]] = (
    [_conv_as_gemm(56, 64, 64, 3)] * 4
    + [_conv_as_gemm(56, 64, 128, 3, 2)]
    + [_conv_as_gemm(28, 128, 128, 3)] * 3
    + [_conv_as_gemm(28, 128, 256, 3, 2)]
    + [_conv_as_gemm(14, 256, 256, 3)] * 3
    + [_conv_as_gemm(14, 256, 512, 3, 2)]
    + [_conv_as_gemm(7, 512, 512, 3)] * 3
    + [(1, 512, 1000)]
)

#: BERT-base layer GEMMs (seq 512): QKV+proj+FFN, ×12 layers
BERT_BASE_LAYERS: List[Tuple[int, int, int]] = (
    ([(512, 768, 768)] * 4 + [(512, 768, 3072), (512, 3072, 768)]) * 12
)


def simulate_gemm(m: int, k: int, n: int, pt: LutDlaPoint,
                  arch: str = "lutdla", **kw) -> Dict[str, float]:
    sim = LutDlaSim(pt, **kw) if arch == "lutdla" else PqaSim(pt, **kw)
    return sim.gemm_cycles(m, k, n)


def simulate_network(layers: List[Tuple[int, int, int]], pt: LutDlaPoint,
                     **kw) -> Dict[str, float]:
    return LutDlaSim(pt, **kw).network_cycles(layers)
