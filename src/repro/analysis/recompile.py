"""Recompile guard: steady-state serving must not trace or compile.

The engine's step loop is built so every step reuses a handful of
compiled executables — decode and verify batches are padded to
``num_slots`` lanes, prefill chunks to a static width, draft lookahead
to ``k`` columns — which makes "no recompiles in steady state" a hard
property, not a hope. This module checks it two ways:

* :class:`CompileLog` captures XLA compile events via
  ``jax.log_compiles`` (messages on the ``jax._src.dispatch`` logger),
  catching BOTH jit retraces and eager-op churn (an eager op with a
  fresh shape compiles a fresh executable — the log sees it even though
  no jit cache grows);
* :func:`compile_counts` reads each serving jit's ``_cache_size()``,
  giving the per-(entry point, shape class) "compiled exactly once"
  assertion — shape classes are things like greedy vs temperature
  sampling batches (``temps=None`` is a distinct pytree structure).

:func:`run_recompile_guard` drives an engine through a warmup workload,
then a steady-state workload of the *same shape classes* inside a
:class:`CompileLog`, and reports violations as
:class:`~repro.analysis.findings.Finding` records (rule
``recompile-steady`` / ``recompile-cache``).
"""
from __future__ import annotations

import dataclasses
import logging
import re
from typing import Dict, List, Optional

from .findings import Finding

_COMPILE_RE = re.compile(
    r"Finished XLA compilation of jit\((?P<name>[^)]*)\)")

#: loggers jax.log_compiles routes compile messages through
_LOGGER_NAMES = ("jax._src.dispatch", "jax._src.interpreters.pxla")


class _Capture(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.events: List[str] = []

    def emit(self, record):
        msg = record.getMessage()
        m = _COMPILE_RE.search(msg)
        if m:
            self.events.append(m.group("name"))


class CompileLog:
    """Context manager recording every XLA compilation that finishes
    inside the block.

    >>> # doctest-style sketch (real use: tests/test_recompile_guard.py)
    >>> # with CompileLog() as log:
    >>> #     engine.run_until_idle()
    >>> # assert log.events == []
    """

    def __init__(self):
        self.events: List[str] = []
        self._handler: Optional[_Capture] = None
        self._ctx = None

    def __enter__(self):
        import jax
        self._handler = _Capture()
        self._propagate = {}
        for name in _LOGGER_NAMES:
            lg = logging.getLogger(name)
            lg.addHandler(self._handler)
            # capture quietly: don't spray compile logs over test output
            self._propagate[name] = lg.propagate
            lg.propagate = False
        self._ctx = jax.log_compiles()
        self._ctx.__enter__()
        return self

    def __exit__(self, *exc):
        self._ctx.__exit__(*exc)
        for name in _LOGGER_NAMES:
            lg = logging.getLogger(name)
            lg.removeHandler(self._handler)
            lg.propagate = self._propagate[name]
        self.events = self._handler.events
        return False


def compile_counts(engine) -> Dict[str, int]:
    """``{entry point: compiled-trace count}`` for the engine's jits."""
    out = {}
    for name, fn in engine.jit_entry_points().items():
        size = getattr(fn, "_cache_size", None)
        out[name] = size() if size is not None else -1
    return out


@dataclasses.dataclass
class GuardReport:
    """Outcome of one guard run."""
    warmup_events: List[str]
    steady_events: List[str]
    counts: Dict[str, int]
    findings: List[Finding]

    @property
    def ok(self) -> bool:
        return not self.findings


def run_recompile_guard(engine, warmup_requests, steady_requests,
                        expected_counts: Dict[str, int]) -> GuardReport:
    """Drive ``engine`` through warmup then steady-state; assert the
    steady phase compiles nothing and each jit's cache holds exactly
    the expected number of shape classes.

    Args:
      engine: a fresh :class:`repro.serve.engine.Engine`.
      warmup_requests / steady_requests: two request lists exercising
        the SAME shape classes (the warmup pays every compile).
      expected_counts: ``{entry point: shape classes}`` — e.g. decode
        compiles once, sample twice when the workload mixes greedy and
        temperature batches. Entry points the engine lacks (no drafter)
        are skipped; listed entries must match ``_cache_size`` exactly.
    """
    with CompileLog() as warm:
        for r in warmup_requests:
            engine.submit(r)
        engine.run_until_idle()
    with CompileLog() as steady:
        for r in steady_requests:
            engine.submit(r)
        engine.run_until_idle()

    findings: List[Finding] = []
    if steady.events:
        findings.append(Finding(
            "recompile-steady", "", 0, "engine.run_until_idle",
            "steady-compiles",
            f"{len(steady.events)} XLA compilation(s) in steady state "
            f"(shape churn): {sorted(set(steady.events))}", "error",
            "pad step inputs to the static batch/chunk shapes; check "
            "weak-type or pytree-structure flips between steps"))
    counts = compile_counts(engine)
    for name, want in expected_counts.items():
        got = counts.get(name)
        if got is None:
            continue
        if got != want:
            findings.append(Finding(
                "recompile-cache", "", 0, name, "cache-size",
                f"{name}: {got} compiled shape class(es), expected "
                f"exactly {want}", "error",
                "a retrace added a shape class (or an expected class "
                "never ran) — diff the workload against docs/analysis.md "
                "§Recompile guard"))
    return GuardReport(warm.events, steady.events, counts, findings)
