"""Finding model + committed-baseline diffing for the analysis passes.

Every analysis pass (AST lint, jaxpr invariants, recompile guard) emits
:class:`Finding` records. A finding's :attr:`Finding.key` is *stable
across line-number churn*: it is built from the rule, the repo-relative
path, the enclosing symbol and a per-symbol occurrence discriminator —
NOT the line number — so reformatting a file does not invalidate the
committed baseline.

Baseline workflow (docs/analysis.md):

  * ``analysis/baseline.json`` grandfathers pre-existing debt: a finding
    whose key appears there is reported but does not fail the run.
  * a NEW finding (key absent from the baseline) fails CI;
  * a FIXED finding (baselined key no longer emitted) is reported so the
    baseline can be re-tightened with ``scripts/analyze.py --update``.

Severities: ``error`` findings gate CI (modulo baseline); ``warn``
findings gate CI the same way but mark debt worth burning down; ``info``
findings are classification output only — never baselined, never fatal.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Tuple

SEVERITIES = ("error", "warn", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis finding.

    Attributes:
      rule: rule identifier (e.g. ``host-sync``; docs/analysis.md has the
        full table).
      path: repo-relative posix path of the offending file ('' for
        findings about traced jaxprs with no single source line).
      line: 1-based source line (0 when not applicable). Display only —
        never part of the key.
      symbol: dotted qualname of the enclosing function/class, or
        ``<module>`` / an entry-point name for jaxpr findings.
      detail: stable per-symbol discriminator (call name + occurrence
        index, invariant name, ...).
      message: human-readable description.
      severity: ``error`` | ``warn`` | ``info``.
      suggestion: optional autofix hint printed by the CLI.
    """
    rule: str
    path: str
    line: int
    symbol: str
    detail: str
    message: str
    severity: str = "error"
    suggestion: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    @property
    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.symbol}::{self.detail}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.path else self.symbol
        out = f"[{self.severity}] {loc}: {self.rule}: {self.message}"
        if self.suggestion:
            out += f"\n    fix: {self.suggestion}"
        return out


BASELINE_VERSION = 1


def load_baseline(path: str) -> Dict[str, dict]:
    """Load ``{finding_key: metadata}`` from a baseline file.

    A missing file is an empty baseline (first run / fresh repo)."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"{path}: baseline version {doc.get('version')!r} != "
            f"{BASELINE_VERSION} (regenerate with scripts/analyze.py "
            f"--update)")
    return doc["findings"]


def save_baseline(path: str, findings: Iterable[Finding]) -> None:
    """Write the gating findings (error/warn) as the new baseline."""
    doc = {
        "version": BASELINE_VERSION,
        "findings": {
            f.key: {"rule": f.rule, "path": f.path, "line": f.line,
                    "message": f.message, "severity": f.severity}
            for f in sorted(findings, key=lambda f: f.key)
            if f.severity != "info"
        },
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def diff_baseline(findings: Iterable[Finding], baseline: Dict[str, dict],
                  ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings against the baseline.

    Returns ``(new, grandfathered, fixed_keys)``: gating findings absent
    from the baseline, gating findings the baseline already carries, and
    baselined keys no longer emitted (candidates for --update)."""
    gating = [f for f in findings if f.severity != "info"]
    new = [f for f in gating if f.key not in baseline]
    grandfathered = [f for f in gating if f.key in baseline]
    live_keys = {f.key for f in gating}
    fixed = sorted(k for k in baseline if k not in live_keys)
    return new, grandfathered, fixed
