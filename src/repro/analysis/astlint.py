"""AST lint pass: jit-safety rules over the whole ``repro`` package.

The linter parses every module under ``src/repro``, builds a best-effort
static call graph, and computes two reachability sets:

  * **traced** — functions reachable from a jitted entry point: anything
    decorated/wrapped with ``jax.jit`` (including ``functools.partial``
    forms and lambdas passed to ``jax.jit``), functions passed to jax
    combinators (``lax.scan`` / ``vmap`` / ``cond`` / ...), plus the
    configured :data:`TRACED_SEEDS` (the serving entry points the engine
    wraps in jit lambdas, which static resolution cannot follow).
  * **step-loop** — host-side functions on the serving hot path,
    reachable from :data:`STEP_SEEDS` (``Engine.step`` and friends) but
    not traced.

Rules (docs/analysis.md has the table; waive with ``# analysis:
ok(<rule>)`` on the offending line or the enclosing ``def`` line):

  host-sync             device-sync / tracer-leak calls (``.item()``,
                        ``int(tracer)``, ``float(tracer)``,
                        ``np.asarray``, ``jax.device_get``,
                        ``block_until_ready``, ``.tolist()``) inside a
                        TRACED function.                       [error]
  step-sync             scattered device->host reads inside the engine
                        step loop; batch them through one
                        ``Engine._device_read`` pytree fetch.  [warn]
  sync-site             the same calls anywhere else: host-side OK,
                        reported for classification only.      [info]
  host-rng-under-trace  Python ``random`` / ``np.random`` / ``time`` /
                        ``datetime`` inside a TRACED function. [error]
  mutable-default       mutable default argument values (list/dict/set
                        literals error; shared call results warn — waive
                        when the object is immutable).   [error|warn]
  jit-static-args       a ``jax.jit``-wrapped callable invoked with a
                        str/bool literal argument but compiled without
                        ``static_argnames``/``static_argnums``. [error]
  allocator-free        raw ``allocator.free(...)`` of refcounted pages
                        outside ``kv_cache.py`` — route through
                        ``PageTable.release`` / ``decref``.    [error]
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding

# Serving/jit entry points whose jit wrapping the static pass cannot see
# (the engine jits `lambda ...: model.prefill_paged(...)` — the bound
# method behind a local variable). Reachability seeds, dotted qualnames.
TRACED_SEEDS: Tuple[str, ...] = (
    "repro.models.model.Model.forward",
    "repro.models.model.Model.prefill",
    "repro.models.model.Model.decode",
    "repro.models.model.Model.prefill_paged",
    "repro.models.model.Model.decode_paged",
    "repro.models.model.Model.verify_paged",
    "repro.kernels.ops.vq_assign",
    "repro.kernels.ops.lut_matmul",
    "repro.kernels.ops.vq_amm",
    "repro.kernels.flash_decode.flash_decode_paged",
    "repro.serve.speculative.ModelDrafter.bind.make_draft_k.draft_k",
    "repro.serve.engine._sample_tokens",
)

# Host-side hot-loop seeds: the continuous engine's step machinery and
# the per-round drafter hooks. Everything reachable from here runs once
# per serving step — scattered device reads here are latency.
STEP_SEEDS: Tuple[str, ...] = (
    "repro.serve.engine.Engine.step",
    "repro.serve.engine.Engine.run_until_idle",
    "repro.serve.engine.BatchToCompletionEngine._run_batch",
    "repro.serve.speculative.ModelDrafter.propose",
    "repro.serve.speculative.NgramDrafter.propose",
    "repro.serve.router.ReplicaRouter.step",
)

_WAIVER_RE = re.compile(r"#\s*analysis:\s*ok\(([^)]*)\)")

_COMBINATORS = {
    "jax.lax.scan", "lax.scan", "jax.lax.cond", "lax.cond",
    "jax.lax.while_loop", "lax.while_loop", "jax.lax.switch", "lax.switch",
    "jax.lax.map", "lax.map", "jax.vmap", "vmap", "jax.grad", "grad",
    "jax.value_and_grad", "jax.checkpoint", "jax.remat",
    "functools.partial", "partial", "jax.lax.fori_loop", "lax.fori_loop",
}

_SYNC_READ_KINDS = ("item", "tolist", "np.asarray", "device_get",
                    "block_until_ready")

#: Repo-relative path prefixes whose functions are *declared* sync-free:
#: instrumentation that records host scalars (``repro.obs`` — counters,
#: perf_counter timestamps, trace tuples) and by construction never
#: touches a device value. Step-loop reachability still applies to the
#: code that CALLS them; this knob only stops the observability layer's
#: own helpers from tripping the step-sync rule when they are inlined
#: into the hot path (e.g. ``np.asarray`` on an already-host buffer in a
#: snapshot writer). Keep the list short — every entry is an audited
#: claim, not an escape hatch.
SYNC_FREE_PATHS = ("src/repro/obs",)


@dataclasses.dataclass
class FunctionInfo:
    """One function (or jitted lambda) in the static call graph."""
    qualname: str            # dotted, e.g. repro.serve.engine.Engine.step
    module: str
    path: str                # repo-relative
    node: ast.AST            # FunctionDef / Lambda
    lineno: int
    cls: Optional[str]       # enclosing class simple name, if any
    calls: List[tuple] = dataclasses.field(default_factory=list)
    jit_root: bool = False


class _ModuleIndex:
    """Per-module symbol tables built in one AST pass."""

    def __init__(self, module: str, path: str, tree: ast.Module,
                 source: str):
        self.module = module
        self.path = path
        self.tree = tree
        self.imports: Dict[str, str] = {}       # alias -> module dotted
        self.from_imports: Dict[str, str] = {}  # name -> module.name
        self.functions: Dict[str, FunctionInfo] = {}   # qualname -> info
        self.waivers: Dict[int, Set[str]] = {}  # line -> waived rules
        # names assigned from jax.jit(...) without static args, and the
        # calls made through them: (qualname_scope, name) -> jit lineno
        self.nonstatic_jits: Dict[Tuple[str, str], int] = {}
        for i, ln in enumerate(source.splitlines(), start=1):
            m = _WAIVER_RE.search(ln)
            if m:
                self.waivers[i] = {r.strip() for r in m.group(1).split(",")
                                   if r.strip()}
        self._collect()

    # -- helpers ----------------------------------------------------------
    @staticmethod
    def dotted(node: ast.AST) -> Optional[str]:
        """'a.b.c' for nested Name/Attribute chains, else None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return None

    def _is_jax_jit(self, node: ast.AST) -> bool:
        d = self.dotted(node)
        if d is None:
            return False
        if d in ("jax.jit", "pjit", "jax.pjit"):
            return True
        return d == "jit" and self.from_imports.get("jit", "") == "jax.jit"

    def _jit_call_static(self, call: ast.Call) -> bool:
        return any(kw.arg in ("static_argnames", "static_argnums")
                   for kw in call.keywords)

    # -- collection -------------------------------------------------------
    def _collect(self) -> None:
        for node in self.tree.body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                self._add_import(node)
        self._walk_scope(self.tree.body, prefix=self.module, cls=None)

    def _add_import(self, node) -> None:
        if isinstance(node, ast.Import):
            for a in node.names:
                self.imports[a.asname or a.name.split(".")[0]] = a.name
        else:
            mod = node.module or ""
            if node.level:           # relative: resolve against this module
                base = self.module.split(".")[:-node.level]
                mod = ".".join(base + ([mod] if mod else []))
            for a in node.names:
                self.from_imports[a.asname or a.name] = f"{mod}.{a.name}"

    def _walk_scope(self, body, prefix: str, cls: Optional[str]) -> None:
        for node in body:
            if isinstance(node, ast.ClassDef):
                self._walk_scope(node.body, f"{prefix}.{node.name}",
                                 cls=node.name)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_function(node, prefix, cls)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                self._add_import(node)
            else:
                # module/class-level statements may contain jit lambdas
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call) and \
                            self._is_jax_jit(sub.func):
                        self._register_jit_call(sub, prefix)

    def _add_function(self, node, prefix: str, cls: Optional[str]) -> None:
        qn = f"{prefix}.{node.name}"
        info = FunctionInfo(qualname=qn, module=self.module, path=self.path,
                            node=node, lineno=node.lineno, cls=cls)
        for dec in node.decorator_list:
            if self._is_jax_jit(dec):
                info.jit_root = True
            elif isinstance(dec, ast.Call):
                d = self.dotted(dec.func)
                if d in ("functools.partial", "partial") and dec.args and \
                        self._is_jax_jit(dec.args[0]):
                    info.jit_root = True
        self.functions[qn] = info
        self._scan_body(info, qn, cls)
        # nested defs get their own entries (reachable via call edges)
        for sub in _body_statements(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_function(sub, qn, cls)
            elif isinstance(sub, ast.ClassDef):
                self._walk_scope(sub.body, f"{qn}.{sub.name}", sub.name)

    def _register_jit_call(self, call: ast.Call, scope: str,
                           info: Optional[FunctionInfo] = None) -> None:
        """``jax.jit(X, ...)``: X becomes a traced root (Name) or a
        synthetic traced lambda whose internal calls are edges."""
        if not call.args:
            return
        arg = call.args[0]
        if isinstance(arg, ast.Name):
            if info is not None:
                info.calls.append(("jitname", arg.id))
            else:
                self.functions.setdefault(
                    f"{scope}.<jit@{call.lineno}>",
                    FunctionInfo(f"{scope}.<jit@{call.lineno}>", self.module,
                                 self.path, call, call.lineno, None,
                                 calls=[("name", arg.id)], jit_root=True))
        elif isinstance(arg, ast.Lambda):
            qn = f"{scope}.<lambda@{arg.lineno}>"
            lam = FunctionInfo(qualname=qn, module=self.module,
                               path=self.path, node=arg, lineno=arg.lineno,
                               cls=info.cls if info else None, jit_root=True)
            self.functions[qn] = lam
            self._scan_calls(arg.body, lam)

    def _scan_body(self, info: FunctionInfo, scope: str,
                   cls: Optional[str]) -> None:
        for stmt in _body_statements(info.node):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            self._scan_calls(stmt, info)
            # track `name = jax.jit(...)` / `self._x = jax.jit(...)`
            if isinstance(stmt, ast.Assign) and \
                    isinstance(stmt.value, ast.Call) and \
                    self._is_jax_jit(stmt.value.func) and \
                    not self._jit_call_static(stmt.value):
                for tgt in stmt.targets:
                    name = self._target_name(tgt)
                    if name:
                        self.nonstatic_jits[(info.cls or info.qualname,
                                             name)] = stmt.value.lineno

    @staticmethod
    def _target_name(tgt) -> Optional[str]:
        if isinstance(tgt, ast.Name):
            return tgt.id
        if isinstance(tgt, ast.Attribute) and \
                isinstance(tgt.value, ast.Name) and tgt.value.id == "self":
            return f"self.{tgt.attr}"
        return None

    def _scan_calls(self, root: ast.AST, info: FunctionInfo) -> None:
        """Record call edges inside one statement/expression subtree
        (without descending into nested def/class bodies)."""
        for node in _walk_no_defs(root):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if self._is_jax_jit(f):
                self._register_jit_call(node, info.qualname, info)
                continue
            d = self.dotted(f)
            if isinstance(f, ast.Name):
                info.calls.append(("name", f.id))
            elif isinstance(f, ast.Attribute):
                if isinstance(f.value, ast.Name) and \
                        f.value.id in ("self", "cls"):
                    info.calls.append(("self", f.attr))
                elif isinstance(f.value, ast.Name):
                    info.calls.append(("mod", f.value.id, f.attr))
            if d in _COMBINATORS:        # fn-valued args are call edges
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        info.calls.append(("name", arg.id))
                    elif isinstance(arg, ast.Attribute) and \
                            isinstance(arg.value, ast.Name) and \
                            arg.value.id in ("self", "cls"):
                        info.calls.append(("self", arg.attr))


def _body_statements(node):
    if isinstance(node, ast.Lambda):
        return []
    return node.body


def _walk_no_defs(root: ast.AST):
    """ast.walk that does not descend into nested function/class bodies
    (non-jitted lambdas ARE descended — they run in the caller's
    context)."""
    stack = [root]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            stack.append(child)


# ---------------------------------------------------------------------------
# package loading + call-graph resolution
# ---------------------------------------------------------------------------

class PackageGraph:
    """All modules of a package + resolved reachability sets."""

    def __init__(self, indexes: Sequence[_ModuleIndex]):
        self.modules = {ix.module: ix for ix in indexes}
        self.functions: Dict[str, FunctionInfo] = {}
        for ix in indexes:
            self.functions.update(ix.functions)
        # method name -> qualnames, per class simple name (self-call edges)
        self._methods: Dict[Tuple[str, str], List[str]] = {}
        for qn, fn in self.functions.items():
            if fn.cls is not None:
                self._methods.setdefault(
                    (fn.cls, qn.rsplit(".", 1)[-1]), []).append(qn)
        self.traced = self._reach(self._traced_roots())
        self.step_loop = self._reach(self._seed_qualnames(STEP_SEEDS)) \
            - self.traced

    def _seed_qualnames(self, seeds) -> Set[str]:
        out = set()
        for s in seeds:
            if s in self.functions:
                out.add(s)
        return out

    def _traced_roots(self) -> Set[str]:
        roots = {qn for qn, fn in self.functions.items() if fn.jit_root}
        roots |= self._seed_qualnames(TRACED_SEEDS)
        return roots

    def _resolve(self, fn: FunctionInfo, call: tuple) -> List[str]:
        ix = self.modules[fn.module]
        kind = call[0]
        if kind in ("name", "jitname"):
            name = call[1]
            # nested def in the same enclosing function first
            nested = f"{fn.qualname}.{name}"
            if nested in self.functions:
                return [nested]
            local = f"{fn.module}.{name}"
            if local in self.functions:
                return [local]
            tgt = ix.from_imports.get(name)
            if tgt and tgt in self.functions:
                return [tgt]
            return []
        if kind == "self":
            return self._methods.get((fn.cls, call[1]), []) \
                if fn.cls else []
        if kind == "mod":
            mod = ix.imports.get(call[1])
            if mod:
                tgt = f"{mod}.{call[2]}"
                return [tgt] if tgt in self.functions else []
            # `from repro.x import y` then `y.f(...)`
            tgt = ix.from_imports.get(call[1])
            if tgt:
                full = f"{tgt}.{call[2]}"
                return [full] if full in self.functions else []
            return []
        return []

    def _reach(self, roots: Set[str]) -> Set[str]:
        seen = set(roots)
        frontier = list(roots)
        while frontier:
            qn = frontier.pop()
            fn = self.functions.get(qn)
            if fn is None:
                continue
            for call in fn.calls:
                for tgt in self._resolve(fn, call):
                    if tgt not in seen:
                        seen.add(tgt)
                        frontier.append(tgt)
        return seen


def load_package(root: str, package: str = "repro") -> PackageGraph:
    """Parse every ``*.py`` under ``root``.

    ``root`` may be the package dir itself (``src/repro``) or its parent
    source root (``src``) — both yield ``repro.*`` module names."""
    root = os.path.abspath(root)
    if os.path.isdir(os.path.join(root, package)):  # src -> src/repro
        root = os.path.join(root, package)
    indexes = []
    repo = os.path.dirname(os.path.dirname(root))   # src/repro -> repo
    for dirpath, _dirnames, filenames in os.walk(root):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            parts = [package] + rel[:-3].split(os.sep)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            with open(path, encoding="utf-8") as f:
                source = f.read()
            indexes.append(_ModuleIndex(
                ".".join(parts), os.path.relpath(path, repo),
                ast.parse(source, filename=path), source))
    return PackageGraph(indexes)


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

def _sync_kind(ix: _ModuleIndex, node: ast.Call) -> Optional[str]:
    """Classify one call as a host-sync form, or None."""
    f = node.func
    if isinstance(f, ast.Attribute):
        if f.attr in ("item", "tolist", "block_until_ready") and \
                not node.args:
            return f.attr
        d = ix.dotted(f)
        if d is None:
            return None
        head = d.split(".")[0]
        mod = ix.imports.get(head, head)
        if d.endswith(".device_get") and mod.startswith("jax"):
            return "device_get"
        if f.attr in ("asarray", "array") and mod == "numpy":
            return "np.asarray"
        return None
    if isinstance(f, ast.Name):
        if f.id == "device_get" and \
                ix.from_imports.get("device_get", "").startswith("jax"):
            return "device_get"
        if f.id in ("int", "float", "bool") and len(node.args) == 1 and \
                not isinstance(node.args[0], ast.Constant):
            return f"{f.id}()"
    return None


def _rng_kind(ix: _ModuleIndex, node: ast.Call) -> Optional[str]:
    d = ix.dotted(node.func)
    if d is None:
        return None
    head = d.split(".")[0]
    mod = ix.imports.get(head, ix.from_imports.get(head, head))
    parts = d.split(".")
    if mod == "random" or (len(parts) >= 2 and parts[0] == "random"):
        return d if mod == "random" else None
    if mod == "numpy" and len(parts) >= 3 and parts[1] == "random":
        return d
    if mod == "time" and parts[-1] in ("time", "perf_counter", "monotonic",
                                       "sleep"):
        return d
    if mod == "datetime" and parts[-1] in ("now", "utcnow", "today"):
        return d
    return None


def _waived(ix: _ModuleIndex, rule: str, line: int, def_line: int) -> bool:
    for ln in (line, def_line):
        if rule in ix.waivers.get(ln, set()):
            return True
    return False


def _function_findings(graph: PackageGraph) -> List[Finding]:
    out: List[Finding] = []
    for qn, fn in sorted(graph.functions.items()):
        ix = graph.modules[fn.module]
        traced = qn in graph.traced
        in_step = qn in graph.step_loop
        symbol = qn[len(fn.module) + 1:] if qn.startswith(fn.module) else qn
        counters: Dict[str, int] = {}
        body = fn.node.body if isinstance(fn.node, ast.Lambda) \
            else list(_body_statements(fn.node))
        nodes = []
        roots = [body] if isinstance(fn.node, ast.Lambda) else body
        for stmt in roots:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            nodes.extend(_walk_no_defs(stmt))
        for node in sorted((n for n in nodes if isinstance(n, ast.Call)),
                           key=lambda n: (n.lineno, n.col_offset)):
            kind = _sync_kind(ix, node)
            if kind is not None:
                i = counters.setdefault(f"sync:{kind}", 0)
                counters[f"sync:{kind}"] += 1
                detail = f"{kind}#{i}"
                if traced:
                    rule, sev = "host-sync", "error"
                    msg = (f"{kind} in jit-traced {symbol} — a host sync / "
                           f"tracer leak on the compiled hot path")
                    sugg = ("keep device values on device inside traced "
                            "code; move host reads outside the jit "
                            "boundary")
                elif (in_step and kind in _SYNC_READ_KINDS
                        and not fn.path.startswith(SYNC_FREE_PATHS)):
                    rule, sev = "step-sync", "warn"
                    msg = (f"{kind} in engine step loop ({symbol}) — "
                           f"scattered per-step device read")
                    sugg = ("batch per-step reads into one "
                            "Engine._device_read(...) pytree fetch")
                else:
                    rule, sev = "sync-site", "info"
                    msg = f"{kind} in {symbol}: host-side OK"
                    sugg = ""
                if not _waived(ix, rule, node.lineno, fn.lineno):
                    out.append(Finding(rule, fn.path, node.lineno, symbol,
                                       detail, msg, sev, sugg))
            rng = _rng_kind(ix, node) if traced else None
            if rng is not None:
                i = counters.setdefault(f"rng:{rng}", 0)
                counters[f"rng:{rng}"] += 1
                if not _waived(ix, "host-rng-under-trace", node.lineno,
                               fn.lineno):
                    out.append(Finding(
                        "host-rng-under-trace", fn.path, node.lineno,
                        symbol, f"{rng}#{i}",
                        f"host {rng} under jit trace in {symbol} — value "
                        f"is baked in at trace time",
                        "error",
                        "thread jax.random keys / pass times in as "
                        "arguments"))
        out.extend(_mutable_default_findings(ix, fn, symbol))
        out.extend(_jit_static_findings(graph, ix, fn, symbol))
        out.extend(_allocator_findings(ix, fn, symbol))
    return out


def _mutable_default_findings(ix, fn, symbol) -> List[Finding]:
    node = fn.node
    if isinstance(node, ast.Lambda) or not hasattr(node, "args"):
        return []
    out = []
    defaults = list(node.args.defaults) + \
        [d for d in node.args.kw_defaults if d is not None]
    for i, d in enumerate(defaults):
        if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
            sev, msg = "error", "mutable default argument (shared across " \
                "calls)"
        elif isinstance(d, ast.Call):
            callee = ix.dotted(d.func) or "<call>"
            if callee in ("tuple", "frozenset"):
                continue
            sev = "warn"
            msg = (f"call default `{callee}(...)` evaluated once at def "
                   f"time and shared across calls")
        else:
            continue
        if _waived(ix, "mutable-default", d.lineno, fn.lineno):
            continue
        out.append(Finding(
            "mutable-default", fn.path, d.lineno, symbol, f"default#{i}",
            f"{msg} in {symbol}", sev,
            "default to None and construct in the body (or waive if the "
            "shared object is immutable)"))
    return out


def _jit_static_findings(graph, ix, fn, symbol) -> List[Finding]:
    out = []
    counters: Dict[str, int] = {}
    body = [fn.node.body] if isinstance(fn.node, ast.Lambda) else [
        s for s in _body_statements(fn.node)
        if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef))]
    for stmt in body:
        for node in _walk_no_defs(stmt):
            if not isinstance(node, ast.Call):
                continue
            name = ix._target_name(node.func) if isinstance(
                node.func, (ast.Name, ast.Attribute)) else None
            if name is None:
                continue
            scope = fn.cls or fn.qualname
            if (scope, name) not in ix.nonstatic_jits:
                continue
            bad = [a for a in node.args
                   if isinstance(a, ast.Constant)
                   and isinstance(a.value, (str, bool))]
            bad += [k.value for k in node.keywords
                    if isinstance(k.value, ast.Constant)
                    and isinstance(k.value.value, (str, bool))]
            if not bad:
                continue
            i = counters.setdefault(name, 0)
            counters[name] += 1
            if _waived(ix, "jit-static-args", node.lineno, fn.lineno):
                continue
            out.append(Finding(
                "jit-static-args", fn.path, node.lineno, symbol,
                f"{name}#{i}",
                f"{name} is jitted without static_argnames but called "
                f"with a str/bool literal — every distinct value "
                f"retraces", "error",
                "declare the argument in static_argnames (or hash it "
                "into the closure)"))
    return out


def _allocator_findings(ix, fn, symbol) -> List[Finding]:
    if os.path.basename(fn.path) == "kv_cache.py":
        return []          # the allocator's own module manages refcounts
    out = []
    counters: Dict[str, int] = {}
    body = [fn.node.body] if isinstance(fn.node, ast.Lambda) else [
        s for s in _body_statements(fn.node)
        if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef))]
    for stmt in body:
        for node in _walk_no_defs(stmt):
            if not isinstance(node, ast.Call) or \
                    not isinstance(node.func, ast.Attribute):
                continue
            if node.func.attr not in ("free", "restore"):
                continue
            recv = ix.dotted(node.func.value) or ""
            leaf = recv.split(".")[-1] if recv else ""
            if leaf not in ("allocator", "alloc"):
                continue
            key = f"{leaf}.{node.func.attr}"
            i = counters.setdefault(key, 0)
            counters[key] += 1
            if _waived(ix, "allocator-free", node.lineno, fn.lineno):
                continue
            out.append(Finding(
                "allocator-free", fn.path, node.lineno, symbol,
                f"{key}#{i}",
                f"raw {key}(...) in {symbol}: pages may be refcounted / "
                f"prefix-shared — bypassing the page-table release path "
                f"corrupts shared pages", "error",
                "release through PageTable.release/trim (or decref and "
                "let the owner decide free-list vs prefix LRU)"))
    return out


def run_ast_lint(src_root: str) -> Tuple[List[Finding], PackageGraph]:
    """Lint the package rooted at ``src_root`` (``.../src/repro``).

    Returns (findings, graph). Gating findings are error/warn; ``info``
    findings classify the remaining host-side-OK sync sites."""
    graph = load_package(src_root)
    return _function_findings(graph), graph
