"""Static analysis + dynamic invariant checks for the serving hot path.

Three cooperating passes (docs/analysis.md):

* :mod:`repro.analysis.astlint` — AST lint over the whole package:
  host syncs reachable from jitted entry points or the engine step
  loop, host RNG/clock under trace, mutable default args, jits missing
  ``static_argnames``, paged-allocator API misuse.
* :mod:`repro.analysis.jaxpr_check` — traces every public entry point
  and asserts structural jaxpr/lowering invariants: no f64, no
  transfer ops, gather budgets, KV-pool donation.
* :mod:`repro.analysis.recompile` — ``jax.log_compiles`` harness
  asserting steady-state serving compiles exactly once per
  (entry point, shape class).

Findings are keyed (:class:`~repro.analysis.findings.Finding`) and
diffed against the committed ``analysis/baseline.json`` by
``scripts/analyze.py``: grandfathered debt passes, new findings fail.
"""
from .findings import (Finding, diff_baseline, load_baseline,
                       save_baseline)
from .astlint import run_ast_lint
from .jaxpr_check import (check_donation, check_invariants, iter_eqns,
                          run_jaxpr_checks)
from .recompile import (CompileLog, GuardReport, compile_counts,
                        run_recompile_guard)

__all__ = [
    "Finding", "load_baseline", "save_baseline", "diff_baseline",
    "run_ast_lint", "run_jaxpr_checks", "check_invariants",
    "check_donation", "iter_eqns", "CompileLog", "GuardReport",
    "compile_counts", "run_recompile_guard",
]
