"""Jaxpr/lowering invariants for the serving + kernel entry points.

Traces every public entry point on a tiny smoke-config fixture and
asserts *structural* properties of the jaxpr — things eyeballing HLO
can't police between PRs:

  * **no f64** anywhere (CPU silently promotes; TPU would either crash
    or run at 1/8th throughput — either way the perf claims die);
  * **no transfer ops** (``device_put``) inside a traced entry point —
    a host round-trip inside the step function serializes the pipeline;
  * **gather budgets**: the flash ``"ref"`` path's documented claim is
    that the only pool-sized gather is the *score* gather (scores are
    ``KVH*G`` floats per key vs ``KVH*D`` for a K row — the HBM-traffic
    win of PR 7). The budget makes "no-gather" a checked property: a
    regression that reintroduces a dense KV-view gather fails the run.
    Budgets are exact eqn counts on the pinned fixture and are
    layer-count independent (measured: the flash paths score all layers
    in one batched call);
  * **donation**: the serving jits donate the KV pool
    (``donate_argnums=(2,)``); the check lowers each jit and counts
    ``tf.aliasing_output`` annotations — a signature change that makes
    XLA silently ignore donation doubles pool HBM.

Budgets (empirical on the qwen smoke fixture, asserted exact-or-under):

  ================================  =======  =============================
  entry point                       gathers  what they are
  ================================  =======  =============================
  flash_decode/pallas                  1     self-term row fold only
  flash_decode/ref                     2     score gather + self-term fold
  decode_paged/pallas                  3     embed + self-term + write tgt
  decode_paged/ref                     4     + score gather
  decode_paged/gather                  5     legacy dense-view baseline
  prefill_paged                        4     embed + view(k,v) + slice
  verify_paged                         4     embed + view(k,v) + rows
  vq_amm (ref & fused)                 0     LUT path is gather-free
  ================================  =======  =============================

Quantized-pool (``kv_quant="vq"``) variants — the pool gathers move
uint8 CODES (``nc`` bytes/token/head), never fp rows; the ref/pallas
flash impls replace the centroid lookup with one-hot contractions, so
only the legacy gather path pays decode gathers (the tiny ``z`` tables):

  ================================  =======  =============================
  flash_decode/kvq-pallas              1     self-term fold (codes DMAed)
  flash_decode/kvq-ref                 3     2 code gathers + self-term
  decode_paged/kvq-pallas              3     same as fp — codes add none
  decode_paged/kvq-ref                 5     + 2 code gathers, - score
  decode_paged/kvq-gather              7     code gathers + z decodes
  prefill_paged/kvq                    6     view decodes via z gathers
  verify_paged/kvq                     6     view decodes via z gathers
  ================================  =======  =============================

KVQ donation expects >= 6 aliases: k + v pools AND the four codebook
leaves must all pass through the serving jits in place.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding

try:                                   # jax >= 0.4.16 moved core types
    from jax.extend.core import ClosedJaxpr, Jaxpr        # type: ignore
except Exception:                      # pragma: no cover - version shim
    from jax.core import ClosedJaxpr, Jaxpr               # type: ignore

#: primitives that move data between host and device inside a trace
TRANSFER_PRIMS = ("device_put",)

_ALIAS_RE = re.compile(r"tf\.aliasing_output")


def iter_eqns(jaxpr, path: str = ""):
    """Yield ``(eqn, path)`` over a jaxpr and every sub-jaxpr (pjit,
    scan, cond, pallas_call, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn, path
        for pname, v in eqn.params.items():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for vv in vs:
                sub = None
                if isinstance(vv, ClosedJaxpr):
                    sub = vv.jaxpr
                elif isinstance(vv, Jaxpr):
                    sub = vv
                if sub is not None:
                    yield from iter_eqns(
                        sub, f"{path}/{eqn.primitive.name}")


def _src_of(eqn) -> Tuple[str, int]:
    """Best-effort repo source location of one eqn."""
    tb = getattr(eqn.source_info, "traceback", None)
    if tb is None:
        return "", 0
    for fr in tb.frames:
        fname = fr.file_name or ""
        if "repro" in fname and "analysis" not in fname:
            idx = fname.rfind("src/")
            return fname[idx:] if idx >= 0 else fname, fr.line_num
    return "", 0


def check_invariants(closed: "ClosedJaxpr", *, name: str,
                     forbid_f64: bool = True,
                     forbid_transfer: bool = True,
                     gather_budget: Optional[int] = None) -> List[Finding]:
    """Structural checks over one traced entry point's closed jaxpr."""
    import jax.numpy as jnp
    out: List[Finding] = []
    gathers = 0
    f64_seen: Dict[str, Tuple[str, int]] = {}

    def scan_aval(v, where):
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None and dt == jnp.float64:
            f64_seen.setdefault(where, where_src)

    where_src = ("", 0)
    for cv, c in zip(closed.jaxpr.constvars, closed.consts):
        dt = getattr(c, "dtype", None)
        if forbid_f64 and dt is not None and dt == jnp.float64:
            f64_seen.setdefault("const", ("", 0))
    for eqn, path in iter_eqns(closed.jaxpr):
        pname = eqn.primitive.name
        where_src = _src_of(eqn)
        if pname == "gather":
            gathers += 1
        if forbid_transfer and pname in TRANSFER_PRIMS:
            src, ln = where_src
            out.append(Finding(
                "jaxpr-transfer", src, ln, name,
                f"{pname}@{path or '/'}#{len(out)}",
                f"{name}: transfer op `{pname}` inside the traced entry "
                f"point (host round-trip in the compiled step)", "error",
                "move the transfer outside the jit boundary"))
        if forbid_f64:
            for v in list(eqn.outvars) + list(eqn.invars):
                scan_aval(v, f"{pname}@{path or '/'}")
    for where, (src, ln) in sorted(f64_seen.items()):
        out.append(Finding(
            "jaxpr-f64", src, ln, name, f"f64@{where}",
            f"{name}: float64 value at {where} — silent f64 promotion "
            f"(TPU-hostile, doubles HBM traffic)", "error",
            "cast to float32 / check jnp dtype promotion at this site"))
    if gather_budget is not None and gathers > gather_budget:
        out.append(Finding(
            "jaxpr-gather-budget", "", 0, name, "gather-budget",
            f"{name}: {gathers} gather ops > documented budget "
            f"{gather_budget} — a dense KV-view gather (or similar) "
            f"crept back into the hot path", "error",
            "keep pool reads score-sized (docs/kernels.md §Paged flash "
            "decode); raise the budget only with a traffic argument"))
    return out


def check_donation(jitted, args, *, name: str, min_aliases: int,
                   ) -> List[Finding]:
    """Lower a jit with donated args and assert the aliases survived."""
    txt = jitted.lower(*args).as_text()
    n = len(_ALIAS_RE.findall(txt))
    if n >= min_aliases:
        return []
    return [Finding(
        "jaxpr-donation", "", 0, name, "donation",
        f"{name}: only {n} donated-buffer aliases in the lowered module "
        f"(expected >= {min_aliases}) — the KV pool is being copied "
        f"instead of updated in place", "error",
        "check donate_argnums still points at the kv pytree and that "
        "output shapes/dtypes match the donated buffers")]


# ---------------------------------------------------------------------------
# entry-point registry (tiny smoke fixtures)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EntryCheck:
    """One registered entry point: a fixture builder plus its budgets."""
    name: str
    build: Callable[[], Tuple[Callable, tuple]]   # -> (fn, args)
    gather_budget: Optional[int] = None
    donate_argnums: Tuple[int, ...] = ()
    min_aliases: int = 0


_FIXTURE_CACHE: dict = {}


def _serve_fixture():
    """Tiny qwen smoke model + paged state, built once per process."""
    if "serve" in _FIXTURE_CACHE:
        return _FIXTURE_CACHE["serve"]
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core.lut import DENSE
    from repro.models.model import Model
    from repro.serve import PageTable

    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0), DENSE)
    slots, max_seq, ps = 2, 32, 8
    pt = PageTable(num_slots=slots, max_seq=max_seq, page_size=ps)
    kv = m.init_paged_cache(slots, max_seq, ps, pt.allocator.num_pages)
    for s in range(slots):
        pt.ensure(s, 20)
    fix = {
        "model": m, "params": params, "kv": kv, "table": pt.device(),
        "tok": jnp.zeros((slots, 1), jnp.int32),
        "pos": jnp.asarray([5, 9], jnp.int32),
        "ptoks": jnp.zeros((1, 8), jnp.int32),
        "vtoks": jnp.zeros((slots, 3), jnp.int32),
        "nlive": jnp.asarray([3, 3], jnp.int32),
        "DENSE": DENSE,
    }
    _FIXTURE_CACHE["serve"] = fix
    return fix


def _kvq_fixture():
    """The serve fixture re-based on a vector-quantized page pool."""
    if "kvq" in _FIXTURE_CACHE:
        return _FIXTURE_CACHE["kvq"]
    import jax
    from repro.core.kv_codebook import KVCodebook
    from repro.serve import PageTable
    fx = dict(_serve_fixture())
    m = fx["model"]
    cfg = m.cfg
    key = jax.random.PRNGKey(1)
    rows = jax.random.normal(
        key, (cfg.num_layers, 24, cfg.num_kv_heads, cfg.head_dim))
    cb = KVCodebook.fit(rows, rows + 0.1, v=4, c=16, iters=2)
    slots, max_seq, ps = 2, 32, 8
    pt = PageTable(num_slots=slots, max_seq=max_seq, page_size=ps)
    for s in range(slots):
        pt.ensure(s, 20)
    fx["kv"] = m.init_paged_cache(slots, max_seq, ps,
                                  pt.allocator.num_pages, codebook=cb)
    fx["table"] = pt.device()
    _FIXTURE_CACHE["kvq"] = fx
    return fx


def _decode_entry(flash: str, kvq: bool = False):
    def build():
        fx = _kvq_fixture() if kvq else _serve_fixture()
        m, qc = fx["model"], fx["DENSE"].replace(
            flash=flash, kv_quant="vq" if kvq else "none")

        def fn(p, t, kv, pt, po):
            return m.decode_paged(p, t, kv, pt, po, qc)
        return fn, (fx["params"], fx["tok"], fx["kv"], fx["table"],
                    fx["pos"])
    return build


def _prefill_entry(kvq: bool = False):
    def build():
        import jax.numpy as jnp
        fx = _kvq_fixture() if kvq else _serve_fixture()
        m = fx["model"]
        qc = fx["DENSE"].replace(kv_quant="vq" if kvq else "none")

        def fn(p, t, kv, pt, s, po, v):
            return m.prefill_paged(p, t, kv, pt, s, po, v, qc)
        return fn, (fx["params"], fx["ptoks"], fx["kv"], fx["table"],
                    jnp.int32(0), jnp.int32(0), jnp.int32(8))
    return build


def _verify_entry(kvq: bool = False):
    def build():
        fx = _kvq_fixture() if kvq else _serve_fixture()
        m = fx["model"]
        qc = fx["DENSE"].replace(kv_quant="vq" if kvq else "none")

        def fn(p, t, kv, pt, po, nl):
            return m.verify_paged(p, t, kv, pt, po, nl, qc)
        return fn, (fx["params"], fx["vtoks"], fx["kv"], fx["table"],
                    fx["pos"], fx["nlive"])
    return build


def _flash_entry(impl: str, kvq: bool = False):
    def build():
        import jax.numpy as jnp
        from repro.kernels.flash_decode import flash_decode_paged
        b, kvh, g, d, np_, ps = 2, 2, 2, 16, 4, 8
        nc = 4
        q = jnp.ones((b, 1, kvh * g, d))
        if kvq:
            kp = jnp.ones((np_ + 1, ps, kvh, nc), jnp.uint8)
            cb = {"zk": jnp.ones((nc, 16, 4)), "zv": jnp.ones((nc, 16, 4)),
                  "sk": jnp.ones((kvh,)), "sv": jnp.ones((kvh,))}
        else:
            kp = jnp.ones((np_ + 1, ps, kvh, d))
            cb = None
        kn = jnp.ones((b, 1, kvh, d))
        phys = jnp.zeros((b, np_), jnp.int32)
        pos = jnp.asarray([5, 7], jnp.int32)

        def fn(q, kp, vp, kn, vn, phys, pos):
            return flash_decode_paged(q, kp, vp, kn, vn, phys, pos,
                                      impl=impl, codebook=cb,
                                      interpret=True)
        return fn, (q, kp, kp, kn, kn, phys, pos)
    return build


def _vq_amm_entry(impl: str):
    def build():
        import jax.numpy as jnp
        from repro.kernels import ops
        x = jnp.ones((4, 8, 4))
        z = jnp.ones((8, 16, 4))
        lut = jnp.ones((8, 16, 32))

        def fn(x, z, lut):
            return ops.vq_amm(x, z, lut, impl=impl)
        return fn, (x, z, lut)
    return build


def registry() -> List[EntryCheck]:
    """All registered entry points (budgets documented in the module
    docstring; donation expectations = KV-pool leaves k + v)."""
    return [
        EntryCheck("decode_paged/gather", _decode_entry("gather"),
                   gather_budget=5, donate_argnums=(2,), min_aliases=2),
        EntryCheck("decode_paged/ref", _decode_entry("ref"),
                   gather_budget=4, donate_argnums=(2,), min_aliases=2),
        EntryCheck("decode_paged/pallas", _decode_entry("pallas"),
                   gather_budget=3, donate_argnums=(2,), min_aliases=2),
        EntryCheck("prefill_paged", _prefill_entry(), gather_budget=4,
                   donate_argnums=(2,), min_aliases=2),
        EntryCheck("verify_paged", _verify_entry(), gather_budget=4,
                   donate_argnums=(2,), min_aliases=2),
        EntryCheck("flash_decode/ref", _flash_entry("ref"),
                   gather_budget=2),
        EntryCheck("flash_decode/pallas", _flash_entry("pallas"),
                   gather_budget=1),
        EntryCheck("vq_amm/ref", _vq_amm_entry("ref"), gather_budget=0),
        EntryCheck("vq_amm/fused", _vq_amm_entry("fused"),
                   gather_budget=0),
        # quantized-pool variants (budgets in the module docstring): the
        # pools donate through unchanged, plus the 4 codebook leaves
        EntryCheck("decode_paged/kvq-gather",
                   _decode_entry("gather", kvq=True),
                   gather_budget=7, donate_argnums=(2,), min_aliases=6),
        EntryCheck("decode_paged/kvq-ref", _decode_entry("ref", kvq=True),
                   gather_budget=5, donate_argnums=(2,), min_aliases=6),
        EntryCheck("decode_paged/kvq-pallas",
                   _decode_entry("pallas", kvq=True),
                   gather_budget=3, donate_argnums=(2,), min_aliases=6),
        EntryCheck("prefill_paged/kvq", _prefill_entry(kvq=True),
                   gather_budget=6, donate_argnums=(2,), min_aliases=6),
        EntryCheck("verify_paged/kvq", _verify_entry(kvq=True),
                   gather_budget=6, donate_argnums=(2,), min_aliases=6),
        EntryCheck("flash_decode/kvq-ref", _flash_entry("ref", kvq=True),
                   gather_budget=3),
        EntryCheck("flash_decode/kvq-pallas",
                   _flash_entry("pallas", kvq=True), gather_budget=1),
    ]


def check_entry(ec: EntryCheck) -> List[Finding]:
    """Trace one registered entry point and run every invariant."""
    import jax
    fn, args = ec.build()
    closed = jax.make_jaxpr(fn)(*args)
    out = check_invariants(closed, name=ec.name,
                           gather_budget=ec.gather_budget)
    if ec.donate_argnums:
        jitted = jax.jit(fn, donate_argnums=ec.donate_argnums)
        out += check_donation(jitted, args, name=ec.name,
                              min_aliases=ec.min_aliases)
    return out


def run_jaxpr_checks(names: Optional[Sequence[str]] = None,
                     ) -> List[Finding]:
    """Run every registered entry check (or the named subset)."""
    out: List[Finding] = []
    for ec in registry():
        if names is not None and ec.name not in names:
            continue
        out.extend(check_entry(ec))
    return out
