"""Gradient compression for cross-pod data parallelism.

At 1000+-node scale the gradient all-reduce over the slow inter-pod links
dominates step time. Two standard mitigations, both implemented here:

* **bf16 all-reduce with error feedback** — gradients are cast to bf16
  before the reduction; the per-leaf fp32 residual (what the cast dropped)
  is carried in an error-feedback buffer and added back before the next
  cast, so the compression error does not accumulate (Karimireddy et al.).
* **Hierarchical reduction** — reduce-scatter/all-gather over the fast
  intra-pod ``data`` axis and a single all-reduce over the slow ``pod``
  axis. Under pjit, expressing the gradient reduction as psum over
  ("data",) then psum over ("pod",) lets XLA schedule the intra-pod part
  first and overlap the cross-pod part with the optimizer; when not inside
  shard_map (the usual pjit train step) GSPMD derives the same hierarchy
  from the mesh axis order.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_compress(grads, ef_state) -> Tuple[Any, Any]:
    """Cast grads to bf16 with error feedback. Returns (bf16 grads, new_ef).

    ef_state: fp32 pytree (same structure) of residuals; pass None to init.
    """
    if ef_state is None:
        ef_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        compressed = corrected.astype(jnp.bfloat16)
        new_e = corrected - compressed.astype(jnp.float32)
        return compressed, new_e

    pairs = jax.tree_util.tree_map(leaf, grads, ef_state)
    comp = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef


def decompress(grads):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32), grads)


def psum_hierarchical(grads, data_axis: str = "data",
                      pod_axis: str = "pod"):
    """Inside shard_map: two-level gradient reduction (intra-pod first)."""
    g = jax.tree_util.tree_map(
        lambda t: jax.lax.psum(t, axis_name=data_axis), grads)
    try:
        g = jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t, axis_name=pod_axis), g)
    except NameError:
        pass  # single-pod mesh: no pod axis bound
    return g
