"""Training loop: LUTBoost-staged train step + fault-tolerant driver.

``make_train_step`` builds the pure step function (grad-accum microbatching,
AdamW, gradient clipping, optional bf16+error-feedback gradient compression,
LUTBoost stage masking). The caller jits it with shardings (see
``repro.launch.train``) — the function itself is mesh-agnostic.

``Trainer`` is the driver: deterministic resumable data, checkpoint/restart,
NaN/loss-spike detection with batch skip (flaky-node proxy), and a step-time
watchdog (straggler telemetry).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.lut import QuantConfig
from repro.core.lutboost import LutBoostSchedule, stage_mask
from .compression import ef_compress
from .optimizer import adamw_init, adamw_update, clip_by_global_norm, cosine_lr


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 1000
    lr: float = 3e-4
    warmup: int = 100
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    microbatches: int = 1              # gradient accumulation
    compress_grads: bool = False       # bf16 all-reduce + error feedback
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    log_every: int = 10
    loss_spike_factor: float = 10.0    # skip batches whose loss spikes
    seed: int = 0


def make_train_step(model, qc: QuantConfig, tc: TrainConfig,
                    stage: int = 3) -> Callable:
    """Returns step_fn(params, opt_state, batch, step) -> (params, opt, metrics).

    stage: LUTBoost stage (2 = centroids only, 3 = joint). Ignored in dense
    mode. The function is pure — jit/pjit it at the call site.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, qc)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step_fn(params, opt_state, batch, step):
        if tc.microbatches > 1:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(tc.microbatches, b // tc.microbatches,
                                 *x.shape[1:])
            micro = jax.tree_util.tree_map(reshape, batch)

            def accum(carry, mb):
                gsum, lsum = carry
                loss, _, g = grads_of(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micro)
            inv = 1.0 / tc.microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if tc.compress_grads:
            grads, new_ef = ef_compress(grads, opt_state.get("ef"))
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            new_ef = opt_state.get("ef")

        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = cosine_lr(step, tc.lr, tc.warmup, tc.total_steps)
        mask = None
        if qc.is_lut and stage == 2:
            mask = stage_mask(params, 2)
        new_params, new_adam = adamw_update(
            grads, opt_state["adam"], params, lr,
            weight_decay=tc.weight_decay, mask=mask)
        new_opt = {"adam": new_adam}
        if new_ef is not None:
            new_opt["ef"] = new_ef
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr, "loss": loss})
        return new_params, new_opt, metrics

    return step_fn


def init_opt_state(params, tc: TrainConfig) -> Dict[str, Any]:
    opt: Dict[str, Any] = {"adam": adamw_init(params)}
    if tc.compress_grads:
        opt["ef"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return opt


class Trainer:
    """Fault-tolerant training driver (single-host or per-host in SPMD)."""

    def __init__(self, model, dataset, qc: QuantConfig, tc: TrainConfig,
                 checkpoint_dir: Optional[str] = None,
                 lutboost: Optional[LutBoostSchedule] = None,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.dataset = dataset
        self.qc = qc
        self.tc = tc
        self.lutboost = lutboost
        self.log = log_fn
        self.ckpt = (CheckpointManager(checkpoint_dir, tc.keep_checkpoints)
                     if checkpoint_dir else None)
        self._steps = {}

    def _step_fn(self, stage: int):
        if stage not in self._steps:
            self._steps[stage] = jax.jit(
                make_train_step(self.model, self.qc, self.tc, stage))
        return self._steps[stage]

    def _stage(self, step: int) -> int:
        if self.lutboost is None or not self.qc.is_lut:
            return 3
        return self.lutboost.stage(step)

    def run(self, params, opt_state=None, start_step: int = 0,
            num_steps: Optional[int] = None) -> Tuple[Any, Any, Dict]:
        tc = self.tc
        if opt_state is None:
            opt_state = init_opt_state(params, tc)

        # resume from the latest checkpoint if present
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            (params, opt_state), step0, extra = self.ckpt.restore(
                (params, opt_state))
            start_step = step0
            self.log(f"[trainer] resumed from step {start_step}")

        end = (start_step + num_steps if num_steps is not None
               else tc.total_steps)
        history = {"loss": [], "step_time": []}
        ema_loss = None
        step = start_step
        while step < end:
            batch = self.dataset.batch(step)
            stage = self._stage(step)
            t0 = time.perf_counter()
            new_params, new_opt, metrics = self._step_fn(stage)(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0

            # fault tolerance: NaN or loss spike -> drop update, skip batch
            if not jnp.isfinite(loss) or (
                    ema_loss is not None
                    and loss > tc.loss_spike_factor * ema_loss):
                self.log(f"[trainer] step {step}: bad loss {loss:.4f} "
                         f"(ema {ema_loss}), skipping batch")
                step += 1
                continue
            params, opt_state = new_params, new_opt
            ema_loss = loss if ema_loss is None else \
                0.95 * ema_loss + 0.05 * loss
            history["loss"].append(loss)
            history["step_time"].append(dt)

            if step % tc.log_every == 0:
                self.log(f"[trainer] step {step} stage {stage} "
                         f"loss {loss:.4f} ({dt*1e3:.1f} ms)")
            if self.ckpt is not None and step > start_step and \
                    step % tc.checkpoint_every == 0:
                self.ckpt.save(step, (params, opt_state),
                               extra={"ema_loss": ema_loss})
            step += 1

        if self.ckpt is not None:
            self.ckpt.save(step, (params, opt_state),
                           extra={"ema_loss": ema_loss})
        return params, opt_state, history
