"""AdamW optimizer + LR schedules, implemented directly in JAX.

State is a plain pytree {"m": ..., "v": ..., "count": scalar} so it
checkpoints/reshards with the same machinery as parameters. Supports a
trainable mask (LUTBoost stage-② centroid-only training) applied to the
update, so frozen leaves keep zero moments and identical values.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0,
                 mask: Optional[Any] = None) -> Tuple[Any, dict]:
    """One AdamW step. Returns (new_params, new_state).

    mask: optional pytree of bools — False leaves are left untouched
    (gradients zeroed AND moments frozen), used by LUTBoost stage ②.
    """
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def leaf_update(g, m, v, p, keep):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        if keep is not None:
            m_new = jnp.where(keep, m_new, m)
            v_new = jnp.where(keep, v_new, v)
            p_new = jnp.where(keep, p_new, p)
        return m_new, v_new, p_new

    if mask is None:
        flat = jax.tree_util.tree_map(
            lambda g, m, v, p: leaf_update(g, m, v, p, None),
            grads, state["m"], state["v"], params)
    else:
        flat = jax.tree_util.tree_map(
            lambda g, m, v, p, k: leaf_update(g, m, v, p, k),
            grads, state["m"], state["v"], params, mask)
    m_new = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    p_new = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return p_new, {"m": m_new, "v": v_new, "count": count}


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), norm


def cosine_lr(step, base_lr: float, warmup: int, total: int,
              min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, base_lr * cos)
