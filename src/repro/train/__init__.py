"""Training substrate: optimizer, trainer, gradient compression."""
from .optimizer import adamw_init, adamw_update, clip_by_global_norm, cosine_lr
from .trainer import TrainConfig, Trainer, make_train_step
