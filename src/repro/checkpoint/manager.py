"""Atomic, self-describing checkpoints for fault-tolerant training.

Design (1000+-node posture):

* **Atomicity** — write to ``<dir>/tmp.<step>``, fsync, then ``rename`` to
  ``step_<k>``; a crash mid-write never corrupts the latest checkpoint.
* **Integrity** — a manifest records every leaf's path/shape/dtype plus a
  CRC32 per array; restore verifies before handing data to the trainer.
* **Elastic resharding** — arrays are saved as *global logical* arrays
  (gathered from any sharding). ``restore(..., shardings=...)`` re-places
  them onto an arbitrary target mesh, so a job can restart on a different
  pod count (elastic scaling) or topology.
* **Retention** — keep the last N checkpoints; deletion is also atomic
  (rename to ``.trash`` then rm).

Storage is ``.npz`` per checkpoint (no external deps); the layout would be a
sharded array-per-file format on a real cluster — the manager's interface
(save/restore/latest_step) is what the trainer depends on.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(path: str, tree) -> None:
    arrays = _flatten(tree)
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                    "crc": zlib.crc32(v.tobytes())}
                for k, v in arrays.items()}
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "arrays.npz"),
             **{k: v for k, v in arrays.items()})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_pytree(path: str, tree_like, shardings=None):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    arrays = {}
    for k, meta in manifest.items():
        arr = data[k]
        if zlib.crc32(arr.tobytes()) != meta["crc"]:
            raise IOError(f"checkpoint corruption detected at leaf {k}")
        arrays[k] = arr

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    for (path_k, ref), shd in zip(flat, shard_flat):
        key = jax.tree_util.keystr(path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"shape mismatch for {key}: {arr.shape} vs {np.shape(ref)}")
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))      # elastic re-place
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None
             ) -> str:
        tmp = os.path.join(self.directory, f"tmp.{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(tmp, tree)
        if extra:
            with open(os.path.join(tmp, "extra.json"), "w") as f:
                json.dump(extra, f)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                          # atomic commit
        self._gc()
        return final

    def restore(self, tree_like, step: Optional[int] = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._step_dir(step)
        tree = load_pytree(path, tree_like, shardings)
        extra_path = os.path.join(path, "extra.json")
        extra = None
        if os.path.exists(extra_path):
            with open(extra_path) as f:
                extra = json.load(f)
        return tree, step, extra

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            trash = os.path.join(self.directory, f".trash.{s}")
            os.rename(self._step_dir(s), trash)
            shutil.rmtree(trash, ignore_errors=True)
