"""Fault-tolerant checkpointing with elastic resharding restore."""
from .manager import CheckpointManager, load_pytree, save_pytree
