"""Analytical models of LUT-DLA (paper §VI-B, Eqs. 1–5 and Table I).

All quantities use the paper's symbols:
  M, K, N      GEMM dims (input M×K, weight K×N)
  v            sub-vector length
  c            centroids per codebook
  beta         memory bandwidth (bits/cycle)
  n_ccu, n_imm module parallelism
"""
from __future__ import annotations

import dataclasses
import math
from enum import Enum
from typing import Dict

from repro.core.similarity import ALPHA_SIM


@dataclasses.dataclass(frozen=True)
class LutDlaPoint:
    """One co-design point."""
    v: int
    c: int
    metric: str = "l2"
    n_ccu: int = 1
    n_imm: int = 1
    bits_lut: int = 8          # LUT entry width (paper +INT8 mode)
    bits_idx: int = 0          # derived: ceil(log2 c)
    bits_out: int = 32         # accumulator/output width
    tile_n: int = 128          # T_n

    def __post_init__(self):
        object.__setattr__(self, "bits_idx",
                           max(1, math.ceil(math.log2(self.c))))

    @property
    def equivalent_bits(self) -> float:
        return self.bits_idx / self.v


# ---------------------------------------------------------------------------
# Eq. (1): computation cost-utility tau(v, c)
# ---------------------------------------------------------------------------

def compute_model(m: int, k: int, n: int, pt: LutDlaPoint) -> Dict[str, float]:
    """OPs for the LUT path vs dense GEMM (paper Eq. 1)."""
    nc = k / pt.v
    alpha = ALPHA_SIM[pt.metric]
    op_sim = alpha * pt.c * m * k            # compare M·K elements to c cents
    op_add = m * n * nc                      # accumulate nc partials per out
    dense = 2.0 * m * n * k
    return {"op_sim": op_sim, "op_add": op_add, "total": op_sim + op_add,
            "dense_ops": dense, "speedup_ops": dense / (op_sim + op_add)}


# ---------------------------------------------------------------------------
# Eq. (2): memory phi(v, c)
# ---------------------------------------------------------------------------

def memory_model(m: int, k: int, n: int, pt: LutDlaPoint) -> Dict[str, float]:
    """Memory footprint in BITS (paper Eq. 2): LUT + output + indices."""
    nc = k / pt.v
    mem_lut = n * pt.c * nc * pt.bits_lut
    mem_out = m * n * pt.bits_out
    mem_idx = nc * m * pt.bits_idx
    return {"mem_lut": mem_lut, "mem_out": mem_out, "mem_idx": mem_idx,
            "total": mem_lut + mem_out + mem_idx}


# ---------------------------------------------------------------------------
# Table I: dataflow → on-chip memory requirements
# ---------------------------------------------------------------------------

class DataflowOrder(str, Enum):
    MNK = "MNK"
    NMK = "NMK"
    MKN = "MKN"
    KMN = "KMN"
    KNM = "KNM"
    LS = "LUT-Stationary"


def dataflow_memory(m: int, k: int, n: int, pt: LutDlaPoint,
                    order: DataflowOrder) -> Dict[str, float]:
    """On-chip KB per buffer for each loop order (reproduces Table I).

    Sizes are the minimum such that no LUT entry is loaded twice
    (paper's criterion). "K" here is the subspace loop (N_c iterations).
    """
    nc = k / pt.v
    lut_entry = pt.bits_lut / 8.0                        # bytes
    out_entry = pt.bits_out / 8.0
    idx_entry = pt.bits_idx / 8.0
    full_lut = nc * pt.c * n * lut_entry
    kb = 1024.0

    if order == DataflowOrder.MNK:
        # innermost K: one output element accumulates in place; all LUTs
        # must stay resident (revisited for every (m, n)).
        scratch = 1 * out_entry * 8
        idx = nc * idx_entry
        lut = full_lut
    elif order == DataflowOrder.NMK:
        scratch = 1 * out_entry * 8
        idx = m * nc * idx_entry                          # reused across n
        lut = full_lut
    elif order == DataflowOrder.MKN:
        scratch = n * out_entry                           # one output row
        idx = 1 * idx_entry
        lut = full_lut
    elif order == DataflowOrder.KMN:
        scratch = m * n * out_entry                       # all partials
        idx = 1 * idx_entry
        lut = pt.c * n * lut_entry                        # one subspace
    elif order == DataflowOrder.KNM:
        scratch = m * n * out_entry
        idx = m * idx_entry
        lut = pt.c * pt.tile_n * lut_entry                # one (k, n) tile
    else:  # LUT-Stationary: N outer, K middle, M inner with N tiled by T_n
        scratch = m * pt.tile_n * out_entry               # M × T_n psums
        idx = m * idx_entry
        lut = pt.c * pt.tile_n * lut_entry
    return {"scratchpad_kb": scratch / kb, "indices_kb": idx / kb,
            "psum_lut_kb": lut / kb,
            "total_kb": (scratch + idx + lut) / kb}


# ---------------------------------------------------------------------------
# Eq. (5): pipeline-balance cycles omega
# ---------------------------------------------------------------------------

def parallelism_model(m: int, k: int, n: int, pt: LutDlaPoint,
                      beta_bits_per_cycle: float) -> Dict[str, float]:
    """Clock cycles of the three pipeline phases; omega = max (Eq. 5)."""
    nc = k / pt.v
    load = (pt.c * nc * n * pt.bits_lut / beta_bits_per_cycle) / pt.n_imm
    sim = (m * k / pt.v) / pt.n_ccu          # one subspace compare per cycle
    lut = (m * n * nc / pt.tile_n) / pt.n_imm
    return {"load": load, "sim": sim, "lut": lut,
            "omega": max(load, sim, lut),
            "bound": max((("load", load), ("sim", sim), ("lut", lut)),
                         key=lambda t: t[1])[0]}


# ---------------------------------------------------------------------------
# Table VII: per-IMM SRAM + bandwidth
# ---------------------------------------------------------------------------

def imm_resources(v: int, c: int, tile_n: int, m: int,
                  bits_lut: int = 8, freq_hz: float = 300e6
                  ) -> Dict[str, float]:
    """SRAM KB and min streaming bandwidth for one IMM (paper Table VII).

    SRAM = ping-pong LUT tile pair (2·c·T_n int8) + requantised int8 psum
    scratch (M·T_n) + index buffer — exact on all three published designs
    (36.1 / 72.1 / 408.2 KB).

    Min bandwidth ≈ LUT tile stream (c·T_n entries per M-row sweep) plus the
    int8 activation/index streams; the paper's quoted numbers sit ~20-40%
    above the pure LUT stream, consistent with these side channels.
    """
    import math as _m
    lut_bytes = c * tile_n * bits_lut / 8
    psum_bytes = m * tile_n                               # int8 requantised
    idx_bytes = m * _m.ceil(_m.log2(c)) / 8
    sram_kb = (2 * lut_bytes + psum_bytes + idx_bytes) / 1024
    bw_lut = tile_n * c / m * freq_hz * (bits_lut / 8)
    bw_side = (v + 1) * freq_hz * 0.5                     # acts + idx stream
    return {"sram_kb": sram_kb, "bandwidth_gbs": (bw_lut + bw_side) / 1e9}
