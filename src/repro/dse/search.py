"""Co-Design Space Search Engine (paper §VI-C, Algorithm 2).

    min  omega(v, c, beta, n_IMM, n_CCU)
    s.t. tau, phi        <= GEMM requirements
         area, power     <= HW constraints
         LUTBoost(v, c)  >= accuracy constraint

Steps (Fig. 11): ① prune by compute/memory models; ② prune by hardware
models; ③ coarse-grained accuracy (a fast-trainable proxy or a supplied
accuracy table); ④ LUT-first greedy parallelism expansion — when lookup
throughput is the binding phase, add IMMs so idle CCUs serve more IMMs;
when similarity comparison binds, add CCUs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .models import LutDlaPoint, compute_model, memory_model, parallelism_model
from .ppa import design_ppa


@dataclasses.dataclass
class SearchConstraints:
    m: int = 512
    k: int = 768
    n: int = 768
    beta_bits_per_cycle: float = 683.0     # 25.6 GB/s @ 300 MHz
    max_ops_ratio: float = 1.0             # tau must beat dense GEMM
    max_mem_ratio: float = 4.0             # phi vs dense weight bytes
    max_area_mm2: float = 4.0
    max_power_mw: float = 500.0
    min_accuracy: float = 0.0              # on the proxy accuracy scale
    max_units: int = 256


@dataclasses.dataclass
class SearchResult:
    point: LutDlaPoint
    omega: float
    bound: str
    area_mm2: float
    power_mw: float
    accuracy: float
    history: List[Dict] = dataclasses.field(default_factory=list)


def co_design_search(
    constraints: SearchConstraints,
    v_space: Iterable[int] = (2, 3, 4, 6, 8, 9, 12, 16),
    c_space: Iterable[int] = (8, 16, 32, 64),
    metrics: Iterable[str] = ("l2", "l1", "chebyshev"),
    accuracy_fn: Optional[Callable[[LutDlaPoint], float]] = None,
    verbose: bool = False,
) -> Tuple[Optional[SearchResult], Dict[str, int]]:
    """Algorithm 2. Returns (best design, pruning statistics)."""
    cn = constraints
    m, k, n = cn.m, cn.k, cn.n
    stats = {"total": 0, "pruned_compute": 0, "pruned_memory": 0,
             "pruned_hw": 0, "pruned_accuracy": 0, "expanded": 0}
    dense_bits = k * n * 8                        # int8 dense weight bytes
    best: Optional[SearchResult] = None

    for metric in metrics:
        for v in v_space:
            if k % v:
                continue
            for c in c_space:
                stats["total"] += 1
                pt = LutDlaPoint(v=v, c=c, metric=metric)

                # -- Step 1a: compute pruning (Eq. 1) --------------------
                ops = compute_model(m, k, n, pt)
                if ops["total"] > cn.max_ops_ratio * ops["dense_ops"]:
                    stats["pruned_compute"] += 1
                    continue
                # -- Step 1b: memory pruning (Eq. 2) ---------------------
                mem = memory_model(m, k, n, pt)
                if mem["total"] > cn.max_mem_ratio * dense_bits:
                    stats["pruned_memory"] += 1
                    continue
                # -- Step 2: base hardware constraint --------------------
                ppa1 = design_ppa(pt)
                if (ppa1.area_mm2 > cn.max_area_mm2
                        or ppa1.power_mw > cn.max_power_mw):
                    stats["pruned_hw"] += 1
                    continue
                # -- Step 3: coarse accuracy -----------------------------
                acc = accuracy_fn(pt) if accuracy_fn else 1.0
                if acc < cn.min_accuracy:
                    stats["pruned_accuracy"] += 1
                    continue
                # -- Step 4: LUT-first greedy parallelism expansion ------
                n_ccu, n_imm = 1, 1
                while n_ccu + n_imm < cn.max_units:
                    cand = LutDlaPoint(v=v, c=c, metric=metric,
                                       n_ccu=n_ccu, n_imm=n_imm,
                                       tile_n=pt.tile_n)
                    ppa = design_ppa(cand)
                    if (ppa.area_mm2 > cn.max_area_mm2
                            or ppa.power_mw > cn.max_power_mw):
                        break
                    par = parallelism_model(m, k, n, cand,
                                            cn.beta_bits_per_cycle)
                    res = SearchResult(cand, par["omega"], par["bound"],
                                       ppa.area_mm2, ppa.power_mw, acc)
                    if best is None or res.omega < best.omega:
                        best = res
                        stats["expanded"] += 1
                    # greedy: grow whichever phase binds (paper: IMM-bound
                    # when the lookup dominates and n_imm < n_ccu*N)
                    if par["bound"] == "lut" and n_imm < n_ccu * n:
                        n_imm += 1
                    elif par["bound"] == "sim":
                        n_ccu += 1
                    else:          # load-bound: more IMMs only split BW
                        break
                if verbose and best is not None:
                    print(f"  ({metric},v={v},c={c}) acc={acc:.3f} "
                          f"omega={best.omega:.0f}")
    return best, stats
