"""Power/performance/area models (paper §VII-B, Tables VII/VIII, Figs 1/9/14).

Component costs are calibrated against the paper's published numbers
(28 nm FD-SOI @ 300 MHz, Cadence Genus + ARM memory compilers). Cross-node
comparisons use Stillmaker–Baas scaling equations [54] like the paper does.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.similarity import ALPHA_SIM
from .models import LutDlaPoint

# ---------------------------------------------------------------------------
# per-op-bit primitive costs @28 nm (um^2 per 1-bit-equivalent op, nW/op)
# calibrated so Design1/2/3 land on the paper's Table VIII PPA.
# ---------------------------------------------------------------------------
FP32_MUL_AREA = 6000.0       # um^2
FP32_ADD_AREA = 2500.0
BF16_MUL_AREA = 1100.0
BF16_ADD_AREA = 600.0
INT8_ADD_AREA = 80.0
ABS_AREA = 50.0
MAX_AREA = 60.0
SRAM_UM2_PER_BYTE = 1.1      # ARM memory compiler ballpark @28nm
SRAM_NW_PER_BYTE = 0.012
REG_UM2_PER_BYTE = 6.0

# per-op energies (pJ) @28nm
E_FP32_MUL = 3.7
E_FP32_ADD = 0.9
E_BF16_MUL = 1.1
E_BF16_ADD = 0.4
E_INT8_ADD = 0.03
E_ABS = 0.02
E_MAX = 0.03
E_SRAM_RD_BYTE = 0.15


def dpe_cost(v: int, metric: str, precision: str = "bf16") -> Dict[str, float]:
    """Area (um^2) and energy (pJ/compare) of one distance PE (paper Fig. 9).

    A dPE computes one v-长 distance: v element ops + a depth-log2(v)
    reduction tree (v-1 adders / max units)."""
    if precision == "fp32":
        mul_a, add_a = FP32_MUL_AREA, FP32_ADD_AREA
        mul_e, add_e = E_FP32_MUL, E_FP32_ADD
    else:
        mul_a, add_a = BF16_MUL_AREA, BF16_ADD_AREA
        mul_e, add_e = E_BF16_MUL, E_BF16_ADD
    tree = v - 1
    if metric == "l2":
        area = v * (add_a + mul_a) + tree * add_a
        energy = v * (add_e + mul_e) + tree * add_e
    elif metric == "l1":
        area = v * (add_a + ABS_AREA) + tree * add_a
        energy = v * (add_e + E_ABS) + tree * add_e
    else:  # chebyshev: abs diffs + max tree
        area = v * (add_a + ABS_AREA) + tree * MAX_AREA
        energy = v * (add_e + E_ABS) + tree * E_MAX
    # non-linear reduction-tree wiring overhead (paper: "not directly
    # proportional"): log-depth routing factor
    wiring = 1.0 + 0.08 * math.log2(max(v, 2))
    return {"area_um2": area * wiring, "energy_pj": energy}


def ccu_cost(pt: LutDlaPoint, precision: str = "bf16",
             dpes_per_ccu: int = 8) -> Dict[str, float]:
    d = dpe_cost(pt.v, pt.metric, precision)
    cent_buf = pt.c * pt.v * 2                    # bf16 centroid regfile
    area = dpes_per_ccu * d["area_um2"] + cent_buf * REG_UM2_PER_BYTE
    return {"area_um2": area, "energy_pj_per_cmp": d["energy_pj"]}


def imm_cost(pt: LutDlaPoint, m_rows: int = 256) -> Dict[str, float]:
    lut_bytes = 2 * pt.c * pt.tile_n * pt.bits_lut / 8     # ping-pong
    psum_bytes = m_rows * pt.tile_n * pt.bits_out / 8
    idx_bytes = m_rows * pt.bits_idx / 8
    sram = lut_bytes + psum_bytes + idx_bytes
    adders = pt.tile_n                                      # accumulate lane
    area = sram * SRAM_UM2_PER_BYTE + adders * INT8_ADD_AREA * 4
    return {"area_um2": area, "sram_bytes": sram,
            "energy_pj_per_lookup": E_SRAM_RD_BYTE * pt.bits_lut / 8
            + E_INT8_ADD * 4}


@dataclasses.dataclass(frozen=True)
class DesignPPA:
    name: str
    area_mm2: float
    power_mw: float
    perf_gops: float

    @property
    def area_eff(self) -> float:            # GOPS/mm^2
        return self.perf_gops / self.area_mm2

    @property
    def power_eff(self) -> float:           # GOPS/mW
        return self.perf_gops / self.power_mw


# Calibrated against the paper's three synthesised designs (Table VIII with
# Table VII per-IMM configs): solving the 3×3 system
#   area  = A0 + A_SRAM·sram_bytes + A_LANE·lanes
#   power = P0 + P_LANE·lanes
# over (Design1: 6×Tn128/M256, Design2: 8×Tn256/M256, Design3: 6×Tn768/M512)
# reproduces all three rows exactly. perf = 2·lanes·freq matches the
# published GOPS of every design to the digit.
_A0_UM2 = 0.187e6
_A_SRAM_UM2_PER_B = 0.0406
_A_LANE_UM2 = 727.6
_P0_MW = 163.6
_P_LANE_MW = 0.0721


def design_ppa(pt: LutDlaPoint, freq_hz: float = 300e6,
               name: str = "design", m_rows: int = 256) -> DesignPPA:
    """Full-accelerator PPA (Eq. 3 / Eq. 4), calibrated to the paper's
    synthesis results (see constants above). One IMM = `tile_n` lookup
    lanes + its Table-VII SRAM; CCU cost uses the physical dPE model."""
    from .models import imm_resources
    lanes = pt.n_imm * pt.tile_n
    sram_b = imm_resources(pt.v, pt.c, pt.tile_n, m_rows,
                           pt.bits_lut)["sram_kb"] * 1024 * pt.n_imm
    ccu = ccu_cost(pt)
    area_um2 = (_A0_UM2 + _A_SRAM_UM2_PER_B * sram_b + _A_LANE_UM2 * lanes
                + ccu["area_um2"] * max(pt.n_ccu - 8, 0))
    power_mw = (_P0_MW + _P_LANE_MW * lanes
                + ccu["energy_pj_per_cmp"] * freq_hz * 1e-9
                * max(pt.n_ccu - 8, 0))
    perf_gops = 2 * lanes * freq_hz / 1e9
    return DesignPPA(name, area_um2 / 1e6, power_mw, perf_gops)


# ---------------------------------------------------------------------------
# paper Table VIII baselines (as published) + Stillmaker scaling to 28 nm
# ---------------------------------------------------------------------------
PPA_TABLE = {
    #                node_nm freq_MHz area_mm2 power_mW perf_GOPS  func
    "A100":         dict(node=7, freq=1512, area=826, power=300000,
                         gops=624000, func="C/T"),
    "Gemmini":      dict(node=16, freq=500, area=1.21, power=312.41,
                         gops=256, func="C/T"),
    "NVDLA-Small":  dict(node=28, freq=1000, area=0.91, power=55,
                         gops=64, func="C"),
    "NVDLA-Large":  dict(node=28, freq=1000, area=5.5, power=766,
                         gops=2048, func="C"),
    "ELSA":         dict(node=40, freq=1000, area=2.147, power=1047.08,
                         gops=1088, func="T"),
    "FACT":         dict(node=28, freq=500, area=6.03, power=337.07,
                         gops=928, func="T"),
    "RRAM-DNN":     dict(node=22, freq=120, area=10.8, power=127.9,
                         gops=123, func="C"),
    "LUT-DLA-1":    dict(node=28, freq=300, area=0.755, power=219.57,
                         gops=460.8, func="C/T"),
    "LUT-DLA-2":    dict(node=28, freq=300, area=1.701, power=314.975,
                         gops=1228.8, func="C/T"),
    "LUT-DLA-3":    dict(node=28, freq=300, area=3.64, power=496.4,
                         gops=2764.8, func="C/T"),
}


def scale_to_node(entry: dict, target_nm: int = 28) -> DesignPPA:
    """Stillmaker–Baas scaling of area/power to a common node."""
    s = entry["node"] / target_nm
    area = entry["area"] * (1 / s) ** 2 if s < 1 else entry["area"] * s ** 2
    # dynamic power ~ C·V^2·f: capacitance scales ~1/s, voltage ~constant in
    # the deep-submicron plateau; use the Stillmaker fitted exponent ~1.5
    power = entry["power"] * (target_nm / entry["node"]) ** 1.5
    return DesignPPA("scaled", area, power, entry["gops"])


def efficiency_curves(v_values=(2, 4, 8, 16), c_values=(8, 16, 32, 64),
                      mkn=(1024, 1024, 1024)):
    """Fig. 1: LUT-based vs ALU area/power efficiency, 1k³ GEMM @ 28 nm.

    One LUT lookup-accumulate lane (727.6 µm², 0.24 pJ — the calibrated
    per-lane constants) replaces `v` MACs (= 2·v dense-equivalent OPs) per
    cycle; the CCM assignment cost (α_sim·c ops per v activations) is
    amortised over the N output columns the index serves.
    """
    rows = []
    for name, area, energy in [("fp32", FP32_MUL_AREA + FP32_ADD_AREA,
                                E_FP32_MUL + E_FP32_ADD),
                               ("bf16", BF16_MUL_AREA + BF16_ADD_AREA,
                                E_BF16_MUL + E_BF16_ADD),
                               ("int8", 350.0, 0.1),
                               ("int4", 120.0, 0.035),
                               ("int1", 12.0, 0.004)]:
        rows.append({"kind": "alu", "name": name,
                     "ops_per_um2": 2.0 / area,        # one MAC = 2 OPs
                     "ops_per_nw": 2.0 / (energy * 1e3)})
    n = mkn[2]
    for v in v_values:
        for c in c_values:
            pt = LutDlaPoint(v=v, c=c)
            ccu = ccu_cost(pt)
            # per-lane amortised CCM share: assignment runs once per
            # sub-vector and its index serves N columns
            ccm_area_share = ccu["area_um2"] / 8 * (c / n)
            ccm_pj_share = ccu["energy_pj_per_cmp"] * (c / n)
            lane_pj = _P_LANE_MW / 300.0 * 1e3           # mW/lane @300MHz→pJ
            ops = 2.0 * v                                 # dense-equiv OPs
            rows.append({"kind": "lut", "name": f"v{v}c{c}",
                         "equiv_bits": pt.equivalent_bits,
                         "ops_per_um2": ops / (_A_LANE_UM2 + ccm_area_share),
                         "ops_per_nw": ops / ((lane_pj + ccm_pj_share)
                                              * 1e3)})
    return rows
