"""Co-design space exploration engine (paper §VI)."""
from .models import (DataflowOrder, LutDlaPoint, dataflow_memory,
                     memory_model, compute_model, parallelism_model)
from .ppa import PPA_TABLE, design_ppa, efficiency_curves, scale_to_node
from .search import SearchConstraints, co_design_search
