"""Pure-jnp oracles for the LUT-DLA kernels.

These are the ground truth for kernel tests AND the XLA-native path used by
full-model lowering (the one-hot-matmul formulation has identical MXU cost to
the Pallas kernel, so roofline numbers derived from it are faithful).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.similarity import Metric, pairwise_distance


def assign_ref(x: jax.Array, z: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Nearest-centroid assignment per subspace.

    x : (M, nc, v)   input sub-vectors
    z : (nc, c, v)   centroids
    -> (M, nc) int32 indices
    """
    if metric == "l2":
        # batched MXU form: ||x||^2 - 2<x,z> + ||z||^2
        x2 = jnp.sum(x * x, axis=-1)[..., None]                # (M, nc, 1)
        z2 = jnp.sum(z * z, axis=-1)[None]                     # (1, nc, c)
        xz = jnp.einsum("mkv,kcv->mkc", x, z)                  # (M, nc, c)
        d = x2 - 2.0 * xz + z2
    else:
        diff = jnp.abs(x[:, :, None, :] - z[None])             # (M, nc, c, v)
        d = jnp.sum(diff, -1) if metric == "l1" else jnp.max(diff, -1)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def lut_gemm_ref(idx: jax.Array, lut: jax.Array,
                 scale: jax.Array | None = None) -> jax.Array:
    """LUT gather-accumulate (gather formulation — the literal oracle).

    idx  : (M, nc) int32
    lut  : (nc, c, N)   (float or int8)
    scale: optional (N,) dequant scale when lut is int8
    -> (M, N) float32
    """
    # per-subspace row gather: lut[k][idx[:, k]] -> (nc, M, N), then sum_k.
    gathered = jax.vmap(lambda l, i: l[i], in_axes=(0, 1))(
        lut.astype(jnp.float32), idx)
    out = jnp.sum(gathered, axis=0)
    if scale is not None:
        out = out * scale[None, :].astype(jnp.float32)
    return out


def vq_amm_ref(x: jax.Array, z: jax.Array, lut: jax.Array,
               scale: jax.Array | None = None,
               metric: Metric = "l2",
               out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused assign+lookup kernel (two-pass composition).

    x   : (M, nc, v)    input sub-vectors
    z   : (nc, c, v)    centroids
    lut : (nc, c, N)    precomputed table (float or int8)
    -> (M, N)

    Exactly ``lut_gemm_onehot(assign_ref(x, z), lut)`` — the fused Pallas
    kernel must match this bit-for-bit on indices and to fp32-accumulation
    tolerance on values.
    """
    idx = assign_ref(x, z, metric)
    return lut_gemm_onehot(idx, lut, scale, out_dtype=out_dtype)


def lut_gemm_onehot(idx: jax.Array, lut: jax.Array,
                    scale: jax.Array | None = None,
                    out_dtype=jnp.float32) -> jax.Array:
    """One-hot-matmul formulation (TPU-native; identical math to the kernel).

    out[m, n] = sum_k onehot(idx[m,k]) @ lut[k]    — MXU friendly.
    """
    nc, c, n = lut.shape
    onehot = jax.nn.one_hot(idx, c, dtype=out_dtype)           # (M, nc, c)
    out = jnp.einsum("mkc,kcn->mn", onehot,
                     lut.astype(out_dtype),
                     preferred_element_type=out_dtype)
    if scale is not None:
        out = out * scale[None, :].astype(out_dtype)
    return out
