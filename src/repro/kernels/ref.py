"""Pure-jnp oracles for the LUT-DLA kernels.

These are the ground truth for kernel tests AND the XLA-native path used by
full-model lowering (the one-hot-matmul formulation has identical MXU cost to
the Pallas kernel, so roofline numbers derived from it are faithful).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.similarity import Metric, pairwise_distance


def assign_ref(x: jax.Array, z: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Nearest-centroid assignment per subspace.

    x : (M, nc, v)   input sub-vectors
    z : (nc, c, v)   centroids
    -> (M, nc) int32 indices
    """
    if metric == "l2":
        # batched MXU form: ||x||^2 - 2<x,z> + ||z||^2
        x2 = jnp.sum(x * x, axis=-1)[..., None]                # (M, nc, 1)
        z2 = jnp.sum(z * z, axis=-1)[None]                     # (1, nc, c)
        xz = jnp.einsum("mkv,kcv->mkc", x, z)                  # (M, nc, c)
        d = x2 - 2.0 * xz + z2
    else:
        diff = jnp.abs(x[:, :, None, :] - z[None])             # (M, nc, c, v)
        d = jnp.sum(diff, -1) if metric == "l1" else jnp.max(diff, -1)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def lut_gemm_ref(idx: jax.Array, lut: jax.Array,
                 scale: jax.Array | None = None) -> jax.Array:
    """LUT gather-accumulate (gather formulation — the literal oracle).

    idx  : (M, nc) int32
    lut  : (nc, c, N)   (float or int8)
    scale: optional (N,) dequant scale when lut is int8
    -> (M, N) float32
    """
    # per-subspace row gather: lut[k][idx[:, k]] -> (nc, M, N), then sum_k.
    gathered = jax.vmap(lambda l, i: l[i], in_axes=(0, 1))(
        lut.astype(jnp.float32), idx)
    out = jnp.sum(gathered, axis=0)
    if scale is not None:
        out = out * scale[None, :].astype(jnp.float32)
    return out


def vq_amm_ref(x: jax.Array, z: jax.Array, lut: jax.Array,
               scale: jax.Array | None = None,
               metric: Metric = "l2",
               out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused assign+lookup kernel (two-pass composition).

    x   : (M, nc, v)    input sub-vectors
    z   : (nc, c, v)    centroids
    lut : (nc, c, N)    precomputed table (float or int8)
    -> (M, N)

    Exactly ``lut_gemm_onehot(assign_ref(x, z), lut)`` — the fused Pallas
    kernel must match this bit-for-bit on indices and to fp32-accumulation
    tolerance on values.
    """
    idx = assign_ref(x, z, metric)
    return lut_gemm_onehot(idx, lut, scale, out_dtype=out_dtype)


def lut_gemm_onehot(idx: jax.Array, lut: jax.Array,
                    scale: jax.Array | None = None,
                    out_dtype=jnp.float32) -> jax.Array:
    """One-hot-matmul formulation (TPU-native; identical math to the kernel).

    out[m, n] = sum_k onehot(idx[m,k]) @ lut[k]    — MXU friendly.
    """
    nc, c, n = lut.shape
    onehot = jax.nn.one_hot(idx, c, dtype=out_dtype)           # (M, nc, c)
    out = jnp.einsum("mkc,kcn->mn", onehot,
                     lut.astype(out_dtype),
                     preferred_element_type=out_dtype)
    if scale is not None:
        out = out * scale[None, :].astype(out_dtype)
    return out


def flash_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     k_new: jax.Array, v_new: jax.Array,
                     phys: jax.Array, positions, window=0,
                     kv_start=0) -> jax.Array:
    """Oracle for paged flash decode: gather the view, one full softmax.

    No online/split reductions at all — the (trusted) dense formulation
    the split-KV kernel must reproduce to fp32 tolerance.

    q (B,1,H,D); k_pages/v_pages (P+1, page, KVH, D) one-layer pool
    slice; k_new/v_new (B,1,KVH,D) the fresh token; phys (B, NP)
    trash-redirected page ids; positions (B,) per-slot lengths (-1 =
    inactive). Returns (B, 1, H*D) in q.dtype.
    """
    b, _, h, d = q.shape
    ps, kvh = k_pages.shape[1], k_pages.shape[2]
    g = h // kvh
    np_ = phys.shape[1]
    t = np_ * ps
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    kg = k_pages[phys].reshape(b, t, kvh, d).astype(jnp.float32)
    vg = v_pages[phys].reshape(b, t, kvh, d).astype(jnp.float32)
    scale = d ** -0.5
    kj = jnp.arange(t, dtype=jnp.int32)
    pos = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (b,))
    ks = jnp.broadcast_to(jnp.asarray(kv_start, jnp.int32), (b,))
    win = jnp.asarray(window, jnp.int32)
    mask = (kj[None] < pos[:, None]) & (kj[None] >= ks[:, None])
    mask = mask & jnp.where(win > 0, kj[None] > pos[:, None] - win, True)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, kg,
                    preferred_element_type=jnp.float32) * scale
    s_new = jnp.einsum("bkgd,bkd->bkg", qg,
                       k_new[:, 0].astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
    sc_all = jnp.concatenate([sc, s_new[..., None]], axis=-1)
    mask_all = jnp.concatenate(
        [mask, jnp.ones((b, 1), bool)], axis=-1)       # self: always live
    sc_all = jnp.where(mask_all[:, None, None, :], sc_all, -1e30)
    probs = jax.nn.softmax(sc_all, axis=-1)
    v_all = jnp.concatenate([vg, v_new[:, :1].astype(jnp.float32)], axis=1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v_all,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h * d).astype(q.dtype)


def flash_decode_kvq_ref(q: jax.Array, kc_pages: jax.Array,
                         vc_pages: jax.Array, cb: dict,
                         k_new: jax.Array, v_new: jax.Array,
                         phys: jax.Array, positions, window=0,
                         kv_start=0) -> jax.Array:
    """Oracle for the vector-quantized pool: dequantize-then-reference.

    kc_pages/vc_pages (P+1, page, KVH, nc) uint8 code pools; cb is one
    layer's codebook slice {"zk": (nc,c,v), "zv": ..., "sk": (KVH,),
    "sv": ...}. Decodes the whole pool with plain advanced indexing (no
    one-hot tricks, no LUT factoring) and delegates to the dense oracle
    — the trusted semantics both the LUT-accumulate ref impl and the
    in-kernel-dequant pallas impl must reproduce.
    """
    def deq(codes, z, s):
        nc = z.shape[0]
        sub = z.astype(jnp.float32)[jnp.arange(nc), codes.astype(jnp.int32)]
        rows = sub.reshape(*codes.shape[:-1], -1)
        return rows * s.astype(jnp.float32)[:, None]
    k_pages = deq(kc_pages, cb["zk"], cb["sk"])
    v_pages = deq(vc_pages, cb["zv"], cb["sv"])
    return flash_decode_ref(q, k_pages, v_pages, k_new, v_new, phys,
                            positions, window=window, kv_start=kv_start)
