"""Pallas TPU kernel: LUT gather-accumulate GEMM (the IMM, paper §IV-B).

Implements the paper's LUT-Stationary (LS) dataflow, adapted to the TPU
memory hierarchy:

  * ASIC "PSum LUT SRAM"      -> LUT tile (bk, c, bn) resident in VMEM
  * ASIC "scratchpad"         -> output tile (bm, bn) accumulated in VMEM
  * ASIC "ping-pong buffer"   -> Pallas's automatic HBM->VMEM double-buffered
                                 pipeline prefetching the next (n, k) LUT tile
  * index-addressed SRAM read -> one-hot(idx) @ LUT-tile matmul on the MXU
                                 (the idiomatic TPU "table lookup")

Grid order is ``(m, n, k)`` with k innermost: the output tile (m, n) is
revisited consecutively over k, accumulating partial sums in VMEM exactly
like the LS scratchpad; the LUT block's index map ignores ``m``, so when
``M <= bm`` (decode / modest batch) each LUT tile is fetched from HBM exactly
once — the LS property "never load the same LUT twice".

dtypes: the LUT may be int8 (paper's +INT8 operating point) with a per-column
fp32 scale applied once after the k-accumulation; accumulation is fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import select_blocks


def _lut_gemm_kernel(idx_ref, lut_ref, o_ref, acc_ref, *, n_k: int, c: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[...]                                     # (bm, bk) int32
    lut = lut_ref[...].astype(jnp.float32)                 # (bk, c, bn)
    bm, bk = idx.shape
    # one-hot over centroids: (bm, bk, c); the matmul below is the "lookup".
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, bk, c), 2)
    onehot = (iota == idx[:, :, None]).astype(jnp.float32)
    # (bm, [bk*c]) x ([bk*c], bn) contraction on the MXU.
    acc_ref[...] += jax.lax.dot_general(
        onehot.reshape(bm, bk * c), lut.reshape(bk * c, -1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "out_dtype"))
def lut_gemm_pallas(idx: jax.Array, lut: jax.Array,
                    scale: jax.Array | None = None,
                    block_m: Optional[int] = None,
                    block_n: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: bool = False,
                    out_dtype=jnp.float32) -> jax.Array:
    """idx (M, nc) int32, lut (nc, c, N) -> out (M, N).

    scale: optional (N,) fp32 dequantisation scale for int8 LUTs.
    Block sizes default to the shared decode/prefill heuristic table.
    """
    m, nc = idx.shape
    nc_l, c, n = lut.shape
    assert nc == nc_l, (idx.shape, lut.shape)
    auto = select_blocks("lut_gemm", m, nc, c, n, lut.dtype.itemsize)
    bm = min(block_m or auto.block_m, m)
    bn = min(block_n or auto.block_n, n)
    bk = min(block_k or auto.block_k, nc)
    if m % bm or n % bn or nc % bk:
        pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-nc) % bk
        idx_p = jnp.pad(idx, ((0, pad_m), (0, pad_k)))
        lut_p = jnp.pad(lut, ((0, pad_k), (0, 0), (0, pad_n)))
        # padded subspaces point at centroid 0 of an all-zero LUT: harmless.
        out = lut_gemm_pallas(idx_p, lut_p, None, bm, bn, bk, interpret,
                              out_dtype)
        out = out[:m, :n]
    else:
        grid = (m // bm, n // bn, nc // bk)   # k innermost: LS accumulation
        out = pl.pallas_call(
            functools.partial(_lut_gemm_kernel, n_k=grid[2], c=c),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, c, bn), lambda i, j, k: (k, 0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(idx, lut)
    if scale is not None:
        out = out * scale[None, :].astype(out_dtype)
    return out
