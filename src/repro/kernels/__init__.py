"""Pallas TPU kernels for the LUT-DLA hot spots (assign + lut_gemm + the
fused assign→lut_gemm pipeline that keeps indices out of HBM)."""
from . import ops, ref, tuning
from .assign import vq_assign_pallas
from .flash_decode import (combine_splits, flash_decode_paged,
                           reduce_splits, resolve_flash_impl)
from .fused_amm import vq_amm_pallas
from .lut_gemm import lut_gemm_pallas
from .ops import lut_matmul, vq_amm, vq_assign
