"""Pallas TPU kernels for the LUT-DLA hot spots (assign + lut_gemm)."""
from . import ops, ref
from .assign import vq_assign_pallas
from .lut_gemm import lut_gemm_pallas
from .ops import lut_matmul, vq_assign
