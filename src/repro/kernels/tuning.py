"""Block-size selection shared by every LUT-DLA Pallas kernel.

A tiny autotune table instead of per-call magic numbers. Two workload
regimes dominate serving:

  * decode   (M <= 8)   — one token per active sequence. The LUT stream
                          dominates; M-tiles are as small as the batch and
                          the N-tile is kept wide so each (bk, c, bn) LUT
                          block is fetched from HBM exactly once
                          (LS property: "never load the same LUT twice").
  * prefill  (M >= 256) — batched prompt processing. MXU-shaped M-tiles
                          amortise the LUT fetch across many rows.

Anything in between ("mid") gets a compromise tile. Entries are
(block_m, block_n, block_k); the wrappers clamp each to the actual dim, and
``fit_vmem`` shrinks block_n until the resident LUT tile fits the VMEM
budget for large-``c`` codebooks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# per-kernel VMEM budget for the M-stationary LUT/centroid block (bytes).
# Conservative: real VMEM is ~16 MB/core but the pipeline double-buffers
# input blocks and holds the fp32 accumulator too.
_VMEM_BUDGET = 4 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    block_m: int
    block_n: int
    block_k: int          # subspace (nc) tile


#: regime -> kernel kind -> (bm, bn, bk).  ``bn`` is unused by "assign".
#: "flash_decode" reinterprets the axes (see select_blocks): bm is the
#: per-step slot tile (always 1 — one grid row per slot), bn the kv-head
#: tile and bk the pages per split.
_TABLE = {
    "decode": {
        "assign":   BlockConfig(8, 0, 16),
        "lut_gemm": BlockConfig(8, 512, 16),
        "fused":    BlockConfig(8, 512, 16),
        "flash_decode": BlockConfig(1, 8, 4),
    },
    "mid": {
        "assign":   BlockConfig(128, 0, 8),
        "lut_gemm": BlockConfig(128, 256, 16),
        "fused":    BlockConfig(128, 256, 8),
        "flash_decode": BlockConfig(1, 8, 8),
    },
    "prefill": {
        "assign":   BlockConfig(256, 0, 8),
        "lut_gemm": BlockConfig(256, 512, 16),
        "fused":    BlockConfig(256, 512, 8),
        "flash_decode": BlockConfig(1, 8, 8),
    },
}


def regime(m: int) -> str:
    """Workload regime from the row count (decode | mid | prefill)."""
    if m <= 8:
        return "decode"
    if m >= 256:
        return "prefill"
    return "mid"


def fit_vmem(block_n: int, block_k: int, c: int,
             bytes_per_entry: int = 4) -> tuple[int, int]:
    """Shrink (block_n, then block_k) until the (bk, c, bn) LUT tile fits
    the VMEM budget. Returns (block_n, block_k)."""
    bn, bk = block_n, block_k
    while bn > 128 and bk * c * bn * bytes_per_entry > _VMEM_BUDGET:
        bn //= 2
    while bk > 1 and bk * c * bn * bytes_per_entry > _VMEM_BUDGET:
        bk //= 2
    return bn, bk


def select_blocks(kind: str, m: int, nc: int, c: int,
                  n: Optional[int] = None,
                  itemsize: int = 4,
                  deq_itemsize: int = 0) -> BlockConfig:
    """Pick (block_m, block_n, block_k) for kernel ``kind`` on this shape.

    kind: "assign" | "lut_gemm" | "fused" | "flash_decode".  All values
    are upper bounds — callers clamp to the actual dims (and pad
    non-multiples).
    itemsize: bytes per LUT entry (1 for int8 LUTs — they fit 4x bigger
    tiles in the same VMEM budget).
    deq_itemsize: flash_decode only — a vector-quantized KV pool DMAs
    uint8 code tiles (itemsize=1) but dequantizes them to fp INSIDE the
    kernel, so the dequantized copies stay VMEM-resident too; this is
    their element size (0 for fp pools). Counting the code tile at the
    full head_dim width overstates it by ``v``x — conservative on
    purpose.

    For "flash_decode" the axes are reinterpreted for the paged
    attention kernel: m = batch slots, nc = pages per slot, c = page
    size (tokens), n = head_dim, itemsize = KV pool bytes/elt. The
    returned block_n is the kv-head tile (halved until the double-
    buffered K+V page tile fits VMEM) and block_k the pages per split.
    """
    cfg = _TABLE[regime(m)][kind]
    if kind == "flash_decode":
        bh = cfg.block_n
        hd = n or 128
        # resident per grid step: K and V page tiles (double-buffered),
        # plus the in-kernel dequantized fp tiles for quantized pools
        per_elt = itemsize + deq_itemsize
        while bh > 1 and 4 * c * bh * hd * per_elt > _VMEM_BUDGET:
            bh //= 2
        sp = min(cfg.block_k, max(nc, 1))
        return BlockConfig(cfg.block_m, bh, sp)
    bm = min(cfg.block_m, max(m, 1))
    bk = min(cfg.block_k, max(nc, 1))
    if kind == "assign":
        return BlockConfig(bm, 0, bk)
    bn, bk = fit_vmem(cfg.block_n, bk, c, itemsize)
    if n is not None:
        bn = min(bn, max(n, 1))
    return BlockConfig(bm, bn, bk)
