"""Public kernel entry points with impl dispatch.

``impl``:
  * "fused"   — single Pallas kernel doing assign + LUT accumulation with
                indices confined to VMEM (no (M, nc) HBM round-trip).
                Only meaningful for :func:`vq_amm`; the single-stage entry
                points treat it as "auto".
  * "pallas"  — the Pallas kernels (interpret=True automatically on CPU).
                For :func:`vq_amm` this is the two-pass assign→lut_gemm
                composition (the fused kernel's baseline).
  * "ref"     — XLA-native one-hot/einsum formulation. Used for full-model
                lowering in the multi-pod dry-run: the HLO cost is identical
                to the kernel's MXU work, and XLA can shard/fuse it.
  * "auto"    — fused on TPU for vq_amm, pallas on TPU otherwise,
                ref off-TPU (default).

Block sizes default to the shared decode/prefill heuristic in
:mod:`repro.kernels.tuning`; pass ``block_m``/``block_n``/``block_k``
through ``**kw`` to override.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.similarity import Metric
from . import ref as _ref
from .assign import vq_assign_pallas
from .fused_amm import vq_amm_pallas
from .lut_gemm import lut_gemm_pallas

Impl = Literal["auto", "fused", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def vq_assign(x: jax.Array, z: jax.Array, metric: Metric = "l2",
              impl: Impl = "auto", **kw) -> jax.Array:
    """CCM stage: nearest-centroid assignment per subspace.

    Args:
      x: (M, nc, v) inputs split into ``nc`` sub-vectors of length ``v``.
      z: (nc, c, v) codebook centroids (``c`` per subspace).
      metric: "l2" | "l1" | "chebyshev" distance.
      impl: dispatch (see module docstring); "fused" degrades to "auto"
        here — there is no single-stage fusion to do.
      **kw: block-size overrides (``block_m`` / ``block_k``) forwarded to
        the Pallas kernel; defaults come from :mod:`repro.kernels.tuning`.

    Returns: (M, nc) int32 centroid indices.
    """
    if impl in ("auto", "fused"):        # no single-stage fusion to do
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.assign_ref(x, z, metric)
    return vq_assign_pallas(x, z, metric, interpret=not _on_tpu(), **kw)


def lut_matmul(idx: jax.Array, lut: jax.Array, scale=None,
               impl: Impl = "auto", out_dtype=jnp.float32, **kw) -> jax.Array:
    """IMM stage: accumulate precomputed partial products out of the LUT.

    Args:
      idx: (M, nc) int32 centroid indices from :func:`vq_assign`.
      lut: (nc, c, N) table — ``lut[k, j] = z[k, j] · W[k·v:(k+1)·v]``.
      scale: optional (N,) per-output-column dequant scale (int8 LUTs).
      impl: dispatch; "fused" degrades to "auto" (single stage).
      out_dtype: accumulator/output dtype (fp32 default).
      **kw: block-size overrides (``block_m``/``block_n``/``block_k``).

    Returns: (M, N) output, ``sum_k lut[k, idx[m, k], :]`` (× scale).
    """
    if impl in ("auto", "fused"):
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.lut_gemm_onehot(idx, lut, scale, out_dtype=out_dtype)
    return lut_gemm_pallas(idx, lut, scale, interpret=not _on_tpu(),
                           out_dtype=out_dtype, **kw)


def vq_amm(x: jax.Array, z: jax.Array, lut: jax.Array, scale=None,
           metric: Metric = "l2", impl: Impl = "auto",
           out_dtype=jnp.float32, **kw) -> jax.Array:
    """Fused approximate matmul: CCM assignment + IMM accumulation in one.

    Args:
      x: (M, nc, v) inputs; z: (nc, c, v) centroids;
      lut: (nc, c, N) precomputed table; scale: optional (N,) dequant.
      metric: "l2" | "l1" | "chebyshev".
      impl: "auto" prefers the fused Pallas kernel on TPU (indices never
        reach HBM) and the XLA-native oracle elsewhere; "pallas" runs the
        unfused two-pass pipeline — kept as the fused kernel's measurable
        baseline; "ref" forces the oracle.
      out_dtype: accumulator/output dtype.
      **kw: block-size overrides (``block_m``/``block_n``/``block_k``).

    Returns: (M, N) ≈ ``x.reshape(M, K) @ W`` for the W the LUT encodes.
    """
    if impl == "auto":
        impl = "fused" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.vq_amm_ref(x, z, lut, scale, metric, out_dtype=out_dtype)
    if impl == "pallas":                 # two-pass baseline
        akw = {k: v for k, v in kw.items() if k in ("block_m", "block_k")}
        idx = vq_assign_pallas(x, z, metric, interpret=not _on_tpu(), **akw)
        return lut_gemm_pallas(idx, lut, scale, interpret=not _on_tpu(),
                               out_dtype=out_dtype, **kw)
    return vq_amm_pallas(x, z, lut, scale, metric,
                         interpret=not _on_tpu(), out_dtype=out_dtype, **kw)
