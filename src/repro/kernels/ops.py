"""Public kernel entry points with impl dispatch.

``impl``:
  * "pallas"  — the Pallas kernels (interpret=True automatically on CPU).
  * "ref"     — XLA-native one-hot/einsum formulation. Used for full-model
                lowering in the multi-pod dry-run: the HLO cost is identical
                to the kernel's MXU work, and XLA can shard/fuse it.
  * "auto"    — pallas on TPU, ref otherwise (default).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.similarity import Metric
from . import ref as _ref
from .assign import vq_assign_pallas
from .lut_gemm import lut_gemm_pallas

Impl = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def vq_assign(x: jax.Array, z: jax.Array, metric: Metric = "l2",
              impl: Impl = "auto", **kw) -> jax.Array:
    """x (M, nc, v), z (nc, c, v) -> idx (M, nc) int32."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.assign_ref(x, z, metric)
    return vq_assign_pallas(x, z, metric, interpret=not _on_tpu(), **kw)


def lut_matmul(idx: jax.Array, lut: jax.Array, scale=None,
               impl: Impl = "auto", out_dtype=jnp.float32, **kw) -> jax.Array:
    """idx (M, nc) int32, lut (nc, c, N) [+ scale (N,)] -> (M, N)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.lut_gemm_onehot(idx, lut, scale, out_dtype=out_dtype)
    return lut_gemm_pallas(idx, lut, scale, interpret=not _on_tpu(),
                           out_dtype=out_dtype, **kw)
