"""Paged flash-decode: attention over the paged KV pool, no gather.

``decode_paged`` historically materialised a dense per-slot KV view with
``model._paged_view`` (an HBM gather of every allocated page) before
running plain SDPA. This module reads the pool *in place* through the
page table instead — the LUT-DLA operand-residency discipline (CCM→IMM)
applied to attention:

  * **split-KV**: each slot's logical KV length is cut into fixed-size
    splits of ``split_pages`` pages (splits align to page boundaries by
    construction). Every split reduces to a triple ``(m, l, acc)`` —
    running max, sum of exponentials at that max, and the partial
    numerator ``sum_j exp(s_j - m) v_j``.
  * **LSE reduction**: triples form a commutative monoid under
    :func:`combine_splits` with identity ``(NEG_INF, 0, 0)``; a second
    tiny pass (:func:`reduce_splits`) folds the per-split triples and
    the new token's self term into the exact softmax output. All-masked
    splits (unallocated / out-of-window pages) emit the identity, never
    NaN: probabilities are forced to zero *under the mask*, not by
    relying on ``exp(-inf)``.
  * **GQA in-tile**: queries arrive grouped ``(B, KVH, G, D)`` so the
    ``G`` query heads sharing one kv head hit the same K/V tile; the
    Pallas kernel carries a ``(bh, G)`` running state per block of
    ``bh`` kv heads.
  * **trash-page redirection**: ``phys`` already maps unallocated pages
    to the trash page; their keys all sit at ``kj >= pos`` and are
    masked, so whatever the trash page holds is never attended.

Three implementations share the same masks and split algebra:

  ``pallas``  — the real kernel. Scalar-prefetched page table drives the
                BlockSpec index map, so each (slot, split, page) grid
                step DMAs exactly one physical page into VMEM.
  ``ref``     — XLA-native. Scores are computed against the *whole*
                pool and gathered per slot (scores are ~8x smaller than
                KV rows, so this moves far less HBM traffic than
                gathering K/V), then probabilities scatter back to pool
                space for the value contraction.
  (callers may also pick ``gather`` upstream — the legacy
  ``_paged_view`` + ``_sdpa_decode_combine`` path, see
  ``model.decode_paged``.)

The pure split-triple functions double as the property-test surface:
``tests/test_flash_decode.py`` checks split-count/order invariance and
identity behaviour against the full-softmax oracle
:func:`repro.kernels.ref.flash_decode_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import select_blocks

# Finite stand-in for -inf. exp(NEG_INF - NEG_INF) == 1 (not NaN), which
# is exactly what makes the identity triple compose safely.
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# split-triple algebra (pure, tiny — the property-test surface)
# ---------------------------------------------------------------------------

def combine_splits(a: Tuple[jax.Array, jax.Array, jax.Array],
                   b: Tuple[jax.Array, jax.Array, jax.Array]):
    """Merge two split triples ``(m, l, acc)`` into one.

    Associative and commutative; ``(NEG_INF, 0, 0)`` is the identity.
    ``m`` is the running max, ``l`` the sum of ``exp(s - m)``, and
    ``acc`` the matching partial numerator (trailing value axis).
    """
    m_a, l_a, o_a = a
    m_b, l_b, o_b = b
    m = jnp.maximum(m_a, m_b)
    wa = jnp.exp(m_a - m)
    wb = jnp.exp(m_b - m)
    return (m, l_a * wa + l_b * wb,
            o_a * wa[..., None] + o_b * wb[..., None])


def reduce_splits(m: jax.Array, l: jax.Array, acc: jax.Array):
    """Fold per-split triples over the leading split axis in one pass.

    m, l: (NS, ...); acc: (NS, ..., D). Returns the combined triple
    (same as left-folding :func:`combine_splits`, but vectorised).
    """
    m_t = jnp.max(m, axis=0)
    w = jnp.exp(m - m_t[None])
    return m_t, jnp.sum(l * w, axis=0), jnp.sum(acc * w[..., None], axis=0)


def _split_masks(pos, win, ks, kj):
    """Shared causal/window/kv_start mask. kj broadcasts against pos."""
    mask = (kj < pos) & (kj >= ks)
    return mask & jnp.where(win > 0, kj > pos - win, True)


def flash_decode_splits(qg: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, phys: jax.Array,
                        pos: jax.Array, win: jax.Array, ks: jax.Array,
                        split_pages: int):
    """Per-split triples in plain JAX — the mid-level oracle.

    qg: (B, KVH, G, D) float32 queries, already scaled by D**-0.5.
    k_pages/v_pages: (P+1, page, KVH, D) pool (last page = trash).
    phys: (B, NS*split_pages) physical page ids (trash-padded).
    pos/ks: (B,) int32; win: scalar int32 (0 = no window).
    Returns (m, l, acc) shaped (NS, B, KVH, G[, D]) float32.
    """
    b, kvh, g, d = qg.shape
    ps = k_pages.shape[1]
    nsp = phys.shape[1]
    ns = nsp // split_pages
    sl = split_pages * ps                                  # tokens / split
    kg = k_pages[phys].reshape(b, ns, sl, kvh, d)
    vg = v_pages[phys].reshape(b, ns, sl, kvh, d)
    kj = jnp.arange(ns * sl, dtype=jnp.int32).reshape(ns, sl)
    mask = _split_masks(pos[:, None, None], win, ks[:, None, None],
                        kj[None])                          # (B, NS, SL)
    sc = jnp.einsum("bkgd,bstkd->bskgt", qg, kg,
                    preferred_element_type=jnp.float32)
    sc = jnp.where(mask[:, :, None, None, :], sc, NEG_INF)
    m = jnp.max(sc, axis=-1)                               # (B, NS, KVH, G)
    p = jnp.where(mask[:, :, None, None, :],
                  jnp.exp(sc - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bskgt,bstkd->bskgd", p,
                     vg.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    to_split = lambda t: jnp.moveaxis(t, 1, 0)             # (NS, B, ...)
    return to_split(m), to_split(l), to_split(acc)


# ---------------------------------------------------------------------------
# Pallas kernel (phase 1: per-split triples, pages DMAed in place)
# ---------------------------------------------------------------------------

def _flash_kernel(phys_ref, pos_ref, win_ref, ks_ref,    # scalar prefetch
                  q_ref, k_ref, v_ref,                   # inputs
                  m_ref, l_ref, acc_ref, *, ps, sp):
    """One (slot, kv-head tile, split, page) grid step.

    The page dimension is innermost, so (m, l, acc) output blocks stay
    VMEM-resident across a split: init at page 0, rescale-and-accumulate
    in place afterwards (the in-kernel LSE carry).
    """
    ib = pl.program_id(0)
    is_ = pl.program_id(2)
    ip = pl.program_id(3)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lp = is_ * sp + ip                                   # LOGICAL page id
    kj = lp * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
    mask = _split_masks(pos_ref[ib], win_ref[0], ks_ref[ib], kj)

    q = q_ref[0].astype(jnp.float32)                     # (bh, G, D)
    k = jnp.transpose(k_ref[0].astype(jnp.float32), (1, 0, 2))  # (bh,ps,D)
    sc = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    sc = jnp.where(mask, sc, NEG_INF)                    # (bh, G, ps)
    m_prev = m_ref[0, 0]                                 # (bh, G)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
    p = jnp.where(mask, jnp.exp(sc - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    v = jnp.transpose(v_ref[0].astype(jnp.float32), (1, 0, 2))  # (bh,ps,D)
    pv = jax.lax.dot_general(p, v, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p, axis=-1)
    acc_ref[0, 0] = acc_ref[0, 0] * alpha[..., None] + pv


def _splits_pallas(qg, k_pages, v_pages, phys, pos, win, ks,
                   split_pages: int, block_heads: int,
                   interpret: bool = False):
    """Phase-1 triples via ``pallas_call``. Same contract as
    :func:`flash_decode_splits`; the page table is a scalar-prefetch
    operand whose values drive the K/V BlockSpec index maps — each grid
    step DMAs one physical page, nothing is ever gathered in HBM."""
    b, kvh, g, d = qg.shape
    ps = k_pages.shape[1]
    sp = split_pages
    ns = phys.shape[1] // sp
    bh = block_heads
    grid = (b, kvh // bh, ns, sp)

    def page_map(ib, ih, is_, ip, phys_ref, *_):
        return (phys_ref[ib, is_ * sp + ip], 0, ih, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, g, d),
                         lambda ib, ih, is_, ip, *_: (ib, ih, 0, 0)),
            pl.BlockSpec((1, ps, bh, d), page_map),
            pl.BlockSpec((1, ps, bh, d), page_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bh, g),
                         lambda ib, ih, is_, ip, *_: (is_, ib, ih, 0)),
            pl.BlockSpec((1, 1, bh, g),
                         lambda ib, ih, is_, ip, *_: (is_, ib, ih, 0)),
            pl.BlockSpec((1, 1, bh, g, d),
                         lambda ib, ih, is_, ip, *_: (is_, ib, ih, 0, 0)),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((ns, b, kvh, g), jnp.float32),
        jax.ShapeDtypeStruct((ns, b, kvh, g), jnp.float32),
        jax.ShapeDtypeStruct((ns, b, kvh, g, d), jnp.float32),
    ]
    kern = functools.partial(_flash_kernel, ps=ps, sp=sp)
    return pl.pallas_call(kern, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        phys, pos, win, ks, qg, k_pages, v_pages)


# ---------------------------------------------------------------------------
# quantized-pool variants: pages hold uint8 codebook indices
# (core/kv_codebook.py); fp K/V never exists in HBM — the kernel
# dequantizes (or LUT-accumulates) in VMEM / registers.
# ---------------------------------------------------------------------------

def _deq_tile(codes, z, s):
    """In-kernel dequant of one code tile via one-hot matmul (MXU form).

    codes (ps, bh, nc) int32; z (nc, c, v) f32; s (bh,) f32 scales.
    Returns fp K or V rows (bh, ps, nc*v) — never round-tripped to HBM.
    """
    ps_, bh, nc = codes.shape
    c, v = z.shape[1], z.shape[2]
    iota = jax.lax.broadcasted_iota(jnp.int32, (ps_, bh, nc, c), 3)
    oh = (codes[..., None] == iota).astype(jnp.float32)
    ohb = jnp.transpose(oh, (2, 0, 1, 3)).reshape(nc, ps_ * bh, c)
    sub = jax.lax.dot_general(ohb, z.astype(jnp.float32),
                              (((2,), (1,)), ((0,), (0,))),
                              preferred_element_type=jnp.float32)
    rows = jnp.transpose(sub, (1, 0, 2)).reshape(ps_, bh, nc * v)
    rows = rows * s[None, :, None]
    return jnp.transpose(rows, (1, 0, 2))                # (bh, ps, hd)


def _flash_kernel_kvq(phys_ref, pos_ref, win_ref, ks_ref,   # scalar prefetch
                      q_ref, kc_ref, vc_ref, zk_ref, zv_ref,
                      sk_ref, sv_ref,                       # inputs
                      m_ref, l_ref, acc_ref, *, ps, sp):
    """Quantized-pool twin of :func:`_flash_kernel`: the DMAed page block
    is a uint8 code tile (``nc`` bytes/token/head instead of ``4*D``);
    K/V are dequantized in VMEM right before the score / value dots."""
    ib = pl.program_id(0)
    is_ = pl.program_id(2)
    ip = pl.program_id(3)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lp = is_ * sp + ip                                   # LOGICAL page id
    kj = lp * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
    mask = _split_masks(pos_ref[ib], win_ref[0], ks_ref[ib], kj)

    q = q_ref[0].astype(jnp.float32)                     # (bh, G, D)
    k = _deq_tile(kc_ref[0].astype(jnp.int32), zk_ref[...],
                  sk_ref[:, 0])                          # (bh, ps, D)
    sc = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    sc = jnp.where(mask, sc, NEG_INF)                    # (bh, G, ps)
    m_prev = m_ref[0, 0]                                 # (bh, G)
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
    p = jnp.where(mask, jnp.exp(sc - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    v = _deq_tile(vc_ref[0].astype(jnp.int32), zv_ref[...],
                  sv_ref[:, 0])                          # (bh, ps, D)
    pv = jax.lax.dot_general(p, v, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p, axis=-1)
    acc_ref[0, 0] = acc_ref[0, 0] * alpha[..., None] + pv


def _splits_pallas_kvq(qg, kc_pages, vc_pages, zk, zv, sk, sv,
                       phys, pos, win, ks,
                       split_pages: int, block_heads: int,
                       interpret: bool = False):
    """Phase-1 triples off a QUANTIZED pool. Same grid/page-map contract
    as :func:`_splits_pallas`; the codebook tables and per-head scales
    ride along as small VMEM-resident operands (zk/zv whole, sk/sv tiled
    with the kv-head grid axis)."""
    b, kvh, g, d = qg.shape
    ps = kc_pages.shape[1]
    nc, c, v = zk.shape
    sp = split_pages
    ns = phys.shape[1] // sp
    bh = block_heads
    grid = (b, kvh // bh, ns, sp)

    def page_map(ib, ih, is_, ip, phys_ref, *_):
        return (phys_ref[ib, is_ * sp + ip], 0, ih, 0)

    def table_map(ib, ih, is_, ip, *_):
        return (0, 0, 0)

    def scale_map(ib, ih, is_, ip, *_):
        return (ih, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, g, d),
                         lambda ib, ih, is_, ip, *_: (ib, ih, 0, 0)),
            pl.BlockSpec((1, ps, bh, nc), page_map),
            pl.BlockSpec((1, ps, bh, nc), page_map),
            pl.BlockSpec((nc, c, v), table_map),
            pl.BlockSpec((nc, c, v), table_map),
            pl.BlockSpec((bh, 1), scale_map),
            pl.BlockSpec((bh, 1), scale_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bh, g),
                         lambda ib, ih, is_, ip, *_: (is_, ib, ih, 0)),
            pl.BlockSpec((1, 1, bh, g),
                         lambda ib, ih, is_, ip, *_: (is_, ib, ih, 0)),
            pl.BlockSpec((1, 1, bh, g, d),
                         lambda ib, ih, is_, ip, *_: (is_, ib, ih, 0, 0)),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((ns, b, kvh, g), jnp.float32),
        jax.ShapeDtypeStruct((ns, b, kvh, g), jnp.float32),
        jax.ShapeDtypeStruct((ns, b, kvh, g, d), jnp.float32),
    ]
    kern = functools.partial(_flash_kernel_kvq, ps=ps, sp=sp)
    return pl.pallas_call(kern, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        phys, pos, win, ks, qg, kc_pages, vc_pages,
        zk.astype(jnp.float32), zv.astype(jnp.float32),
        sk.reshape(kvh, 1).astype(jnp.float32),
        sv.reshape(kvh, 1).astype(jnp.float32))


def _flash_xla_kvq(qg, kc_pages, vc_pages, zk, zv, sk, sv,
                   phys, pos, win, ks):
    """XLA-native quantized-pool decode: LUT-accumulate, never dequantize.

    The paper's CCM→IMM split applied to attention scores: per (kv head,
    query head, subspace) build the tiny LUT ``q_sub · z`` — scores are
    then a one-hot contraction of the gathered CODE pages (uint8, ``nc``
    bytes/token/head of HBM traffic instead of ``4*D``). The value side
    pools probability mass per (subspace, centroid) first and applies
    each centroid vector once — fp K/V rows are never materialised, not
    even transiently. Returns the cache triple (m, l, acc)."""
    b, kvh, g, d = qg.shape
    ps = kc_pages.shape[1]
    nc, c, v = zk.shape
    np_ = phys.shape[1]
    t = np_ * ps
    kc = kc_pages[phys].reshape(b, t, kvh, nc)           # uint8 gathers —
    vc = vc_pages[phys].reshape(b, t, kvh, nc)           # 4-16x less HBM
    # score LUT: fold the per-head K scale into the query
    qs = (qg * sk[None, :, None, None]).reshape(b, kvh, g, nc, v)
    lut_k = jnp.einsum("bkgsv,scv->bkgsc", qs, zk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    oh_k = jax.nn.one_hot(kc.astype(jnp.int32), c, dtype=jnp.float32)
    sc = jnp.einsum("btksc,bkgsc->bkgt", oh_k, lut_k,
                    preferred_element_type=jnp.float32)  # (B, KVH, G, T)
    kj = jnp.arange(t, dtype=jnp.int32)
    mask = _split_masks(pos[:, None], win, ks[:, None], kj[None])  # (B, T)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    m = jnp.max(sc, axis=-1)                             # (B, KVH, G)
    p = jnp.where(mask[:, None, None, :],
                  jnp.exp(sc - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    # value LUT-accumulate: probability mass per (head, subspace, centroid)
    oh_v = jax.nn.one_hot(vc.astype(jnp.int32), c, dtype=jnp.float32)
    w = jnp.einsum("bkgt,btksc->bkgsc", p, oh_v,
                   preferred_element_type=jnp.float32)
    acc = jnp.einsum("bkgsc,scv->bkgsv", w, zv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    acc = acc.reshape(b, kvh, g, d) * sv[None, :, None, None]
    return m, l, acc


# ---------------------------------------------------------------------------
# XLA-native impl ("ref"): page-table decode without gathering K/V rows
# ---------------------------------------------------------------------------

def _flash_xla(qg, k_pages, v_pages, phys, pos, win, ks):
    """Whole-softmax paged decode moving only score-sized HBM traffic.

    Per key token a score is KVH*G floats but a K row is KVH*D — scoring
    against the whole pool and *gathering scores* (then scattering
    probabilities for the V contraction) reads each pool page once and
    never materialises a dense KV view. Returns the combined cache
    triple (m, l, acc) shaped (B, KVH, G[, D]) — the caller folds the
    self term.
    """
    b, kvh, g, d = qg.shape
    p1, ps = k_pages.shape[0], k_pages.shape[1]
    np_ = phys.shape[1]
    sc_all = jnp.einsum("bkgd,ptkd->bptkg", qg, k_pages,
                        preferred_element_type=jnp.float32)
    sc = jnp.take_along_axis(
        sc_all, phys[:, :, None, None, None], axis=1)    # (B,NP,ps,KVH,G)
    kj = jnp.arange(np_ * ps, dtype=jnp.int32).reshape(np_, ps)
    mask = _split_masks(pos[:, None, None], win, ks[:, None, None],
                        kj[None])                        # (B, NP, ps)
    sc = jnp.where(mask[..., None, None], sc, NEG_INF)
    m = jnp.max(sc, axis=(1, 2))                         # (B, KVH, G)
    p = jnp.where(mask[..., None, None],
                  jnp.exp(sc - m[:, None, None]), 0.0)
    l = jnp.sum(p, axis=(1, 2))
    # scatter probabilities to pool space. A scatter-ADD keeps duplicate
    # targets exact: unallocated pages of one slot all redirect to the
    # trash page (their masked rows contribute zeros), and CoW-shared
    # pages live in distinct batch rows so they never collide.
    p_all = jnp.zeros((b, p1, ps, kvh, g), jnp.float32)
    p_all = p_all.at[jnp.arange(b)[:, None], phys].add(p)
    acc = jnp.einsum("bptkg,ptkd->bkgd", p_all,
                     v_pages.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return m, l, acc


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       k_new: jax.Array, v_new: jax.Array,
                       phys: jax.Array, positions, *,
                       window=0, kv_start=0, impl: str = "ref",
                       codebook: Optional[dict] = None,
                       split_pages: Optional[int] = None,
                       block_heads: Optional[int] = None,
                       interpret: bool = False) -> jax.Array:
    """Single-token paged decode, numerically matching
    ``layers._sdpa_decode_combine`` over the gathered view.

    q (B,1,H,D); k_pages/v_pages (P+1, page, KVH, D) — one layer's slice
    of the pool, last page = trash; k_new/v_new (B,1,KVH,D) the fresh
    token (NOT yet in the pool — the caller scatters it afterwards, and
    its self term is always computed from the fp values, so the newest
    token is exact even on a quantized pool).
    phys (B, NP) physical page ids, already trash-redirected.
    positions (B,) int32 per-slot lengths (-1 = inactive lane: output is
    the garbage ``v_new`` row, discarded by the caller — same contract
    as ``_sdpa_decode_combine``). window/kv_start: scalar or (B,).
    codebook: one layer's slice of the KV codebook pytree (``{"zk":
    (nc,c,v), "zv": ..., "sk": (KVH,), "sv": ...}``, see
    core/kv_codebook.py) — when given, k_pages/v_pages are uint8 CODE
    pools ``(P+1, page, KVH, nc)`` and the impl dequantizes in VMEM
    (pallas) or LUT-accumulates (ref) without materialising fp K/V.
    impl: "pallas" | "ref". Returns (B, 1, H*D) in q.dtype.
    """
    b, s, h, d = q.shape
    if s != 1:
        raise ValueError(f"flash decode is single-token (got S={s})")
    ps, kvh = k_pages.shape[1], k_pages.shape[2]
    g = h // kvh
    np_ = phys.shape[1]
    scale = d ** -0.5
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32) * scale
    pos = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (b,))
    ks = jnp.broadcast_to(jnp.asarray(kv_start, jnp.int32), (b,))
    win = jnp.asarray(window, jnp.int32).reshape(-1)[:1]   # (1,) scalar

    if impl == "pallas":
        deq = 0 if codebook is None else 4
        blk = select_blocks("flash_decode", b, np_, ps, d,
                            k_pages.dtype.itemsize, deq_itemsize=deq)
        sp = min(split_pages or blk.block_k, np_)
        bh = min(block_heads or blk.block_n, kvh)
        while kvh % bh:
            bh -= 1
        pad = (-np_) % sp
        if pad:                       # trash-pad: kj >= NP*page >= pos
            phys = jnp.pad(phys, ((0, 0), (0, pad)),
                           constant_values=k_pages.shape[0] - 1)
        if codebook is None:
            m, l, acc = _splits_pallas(qg, k_pages, v_pages, phys, pos,
                                       win, ks, sp, bh, interpret=interpret)
        else:
            m, l, acc = _splits_pallas_kvq(
                qg, k_pages, v_pages, codebook["zk"], codebook["zv"],
                codebook["sk"], codebook["sv"], phys, pos, win, ks,
                sp, bh, interpret=interpret)
        m, l, acc = reduce_splits(m, l, acc)
    elif impl == "ref":
        if codebook is None:
            m, l, acc = _flash_xla(qg, k_pages, v_pages, phys, pos,
                                   win[0], ks)
        else:
            m, l, acc = _flash_xla_kvq(
                qg, k_pages, v_pages, codebook["zk"], codebook["zv"],
                codebook["sk"], codebook["sv"], phys, pos, win[0], ks)
    else:
        raise ValueError(f"unknown flash impl {impl!r} (pallas | ref)")

    # fold the self term (qg is pre-scaled). The new token is always
    # live, so the denominator is >= exp(0) — never zero, even for
    # fully-masked (pos=-1) lanes.
    s_new = jnp.einsum("bkgd,bkd->bkg", qg,
                       k_new[:, 0].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    m_f = jnp.maximum(m, s_new)
    alpha = jnp.exp(m - m_f)
    p_new = jnp.exp(s_new - m_f)
    denom = l * alpha + p_new
    out = (acc * alpha[..., None]
           + p_new[..., None] * v_new[:, 0, :, None, :].astype(jnp.float32))
    out = out / denom[..., None]
    return out.reshape(b, 1, h * d).astype(q.dtype)


def resolve_flash_impl(flash: str, on_tpu: Optional[bool] = None) -> str:
    """Map ``QuantConfig.flash`` to a concrete decode impl.

    "auto" picks the Pallas kernel on TPU and the legacy gather path on
    CPU hosts: interpret-mode Pallas is orders of magnitude slower than
    XLA, and "gather" keeps CPU decode bit-identical to earlier
    releases. Opt into "ref" explicitly for the XLA no-gather path.
    """
    if flash == "auto":
        if on_tpu is None:
            on_tpu = jax.default_backend() == "tpu"
        return "pallas" if on_tpu else "gather"
    if flash not in ("pallas", "ref", "gather"):
        raise ValueError(
            f"unknown flash mode {flash!r} (auto | pallas | ref | gather)")
    return flash
