"""Pallas TPU kernel: VQ centroid assignment (the CCM, paper §IV-A).

The paper's CCM pipelines one input vector through a chain of dPEs, each
holding one centroid. On TPU there is no systolic comparison chain; the
native formulation computes all ``c`` distances for a tile of ``bm`` rows ×
``bk`` subspaces at once:

  * L2:        ||x||^2 - 2 x·z^T + ||z||^2   — the cross term is a batched
               (bm×v)×(v×c) matmul -> MXU.
  * L1 / Chebyshev: |x - z| reductions        -> VPU.

Grid: ``(M/bm, nc/bk)``. Block shapes:
  x   (bm, bk, v)   — input sub-vectors for this tile
  z   (bk, c, v)    — centroids, stationary across the M grid dimension
  out (bm, bk)      — int32 indices

The centroid block's index map ignores the m grid coordinate, so Pallas
keeps it resident in VMEM while streaming M tiles — the CCM's
"centroid buffer".
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.similarity import Metric
from .tuning import select_blocks


def _assign_kernel(x_ref, z_ref, o_ref, *, metric: str):
    x = x_ref[...].astype(jnp.float32)          # (bm, bk, v)
    z = z_ref[...].astype(jnp.float32)          # (bk, c, v)
    if metric == "l2":
        x2 = jnp.sum(x * x, axis=-1)[..., None]                 # (bm, bk, 1)
        z2 = jnp.sum(z * z, axis=-1)[None]                      # (1, bk, c)
        # batched matmul over the subspace dim -> MXU
        xz = jax.lax.dot_general(
            x, z,
            dimension_numbers=(((2,), (2,)), ((1,), (0,))),     # (bk, bm, c)
            preferred_element_type=jnp.float32)
        d = x2 - 2.0 * jnp.transpose(xz, (1, 0, 2)) + z2        # (bm, bk, c)
    else:
        diff = jnp.abs(x[:, :, None, :] - z[None])              # (bm, bk, c, v)
        d = jnp.sum(diff, -1) if metric == "l1" else jnp.max(diff, -1)
    o_ref[...] = jnp.argmin(d, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("metric", "block_m", "block_k",
                                             "interpret"))
def vq_assign_pallas(x: jax.Array, z: jax.Array, metric: Metric = "l2",
                     block_m: Optional[int] = None,
                     block_k: Optional[int] = None,
                     interpret: bool = False) -> jax.Array:
    """x (M, nc, v), z (nc, c, v) -> idx (M, nc) int32.

    Block sizes default to the shared decode/prefill heuristic table.
    """
    m, nc, v = x.shape
    nc_z, c, v_z = z.shape
    assert (nc, v) == (nc_z, v_z), (x.shape, z.shape)
    auto = select_blocks("assign", m, nc, c)
    bm = min(block_m or auto.block_m, m)
    bk = min(block_k or auto.block_k, nc)
    if m % bm or nc % bk:
        # pad M and nc up to multiples (indices in padding are discarded)
        pad_m = (-m) % bm
        pad_k = (-nc) % bk
        xp = jnp.pad(x, ((0, pad_m), (0, pad_k), (0, 0)))
        zp = jnp.pad(z, ((0, pad_k), (0, 0), (0, 0)))
        out = vq_assign_pallas(xp, zp, metric, bm, bk, interpret)
        return out[:m, :nc]

    grid = (m // bm, nc // bk)
    return pl.pallas_call(
        functools.partial(_assign_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk, v), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bk, c, v), lambda i, j: (j, 0, 0)),   # M-stationary
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nc), jnp.int32),
        interpret=interpret,
    )(x, z)
