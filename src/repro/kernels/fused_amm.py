"""Pallas TPU kernel: fused VQ-assign + LUT-GEMM (CCM pipelined into IMM).

The paper's accelerator never writes centroid indices to DRAM: the CCM's
comparison chain emits each index straight into the IMM's address port
through an on-chip buffer (§IV, Fig 5). The unfused TPU port lost that
property — ``vq_assign_pallas`` materialised the full (M, nc) int32 index
tensor in HBM and ``lut_gemm_pallas`` read it back, one round-trip per
projection per decode step. This kernel restores the fusion:

  per (m, n, k) grid tile —
    1. CCM: distances of the (bm, bk, v) activation block against the
       (bk, c, v) centroid block. L2 goes through the MXU cross-term
       (batched (bm×v)×(v×c) matmul); L1/Chebyshev are VPU reductions.
    2. argmin -> one-hot (bm, bk, c) entirely in registers/VMEM.
    3. IMM: (bm, bk*c) × (bk*c, bn) contraction against the resident LUT
       tile with fp32 accumulation in VMEM scratch (the LS scratchpad).

Indices never exist outside VMEM. Both the centroid block and the LUT block
are M-stationary (their index maps ignore the m grid coordinate), exactly
like the unfused kernels — so for decode shapes every LUT tile is still
fetched from HBM exactly once.

Cost of fusion: the assignment for an (i, k) tile is recomputed for each of
the N/bn output tiles. For decode (M <= 8) the distance work is O(bm·bk·c·v)
against O(bk·c·bn) LUT bytes streamed — noise. For prefill the block
heuristic keeps bn wide so the recompute factor stays small.

dtypes: activations/centroids may be bf16 (distances are computed in fp32);
the LUT may be int8 (paper's +INT8 point) with the per-column fp32 scale
applied once after the k-accumulation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.similarity import Metric
from .tuning import select_blocks


def _fused_kernel(x_ref, z_ref, lut_ref, o_ref, acc_ref, *,
                  n_k: int, metric: str):
    kg = pl.program_id(2)

    @pl.when(kg == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- CCM: distances + argmin, all in VMEM -----------------------------
    x = x_ref[...].astype(jnp.float32)                      # (bm, bk, v)
    z = z_ref[...].astype(jnp.float32)                      # (bk, c, v)
    if metric == "l2":
        x2 = jnp.sum(x * x, axis=-1)[..., None]             # (bm, bk, 1)
        z2 = jnp.sum(z * z, axis=-1)[None]                  # (1, bk, c)
        xz = jax.lax.dot_general(                           # (bk, bm, c) MXU
            x, z,
            dimension_numbers=(((2,), (2,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)
        d = x2 - 2.0 * jnp.transpose(xz, (1, 0, 2)) + z2    # (bm, bk, c)
    else:
        diff = jnp.abs(x[:, :, None, :] - z[None])          # (bm, bk, c, v)
        d = jnp.sum(diff, -1) if metric == "l1" else jnp.max(diff, -1)
    idx = jnp.argmin(d, axis=-1)                            # (bm, bk) int32

    # --- index -> one-hot, straight into the IMM contraction --------------
    bm, bk, c = d.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, bk, c), 2)
    onehot = (iota == idx[:, :, None]).astype(jnp.float32)
    lut = lut_ref[...].astype(jnp.float32)                  # (bk, c, bn)
    acc_ref[...] += jax.lax.dot_general(
        onehot.reshape(bm, bk * c), lut.reshape(bk * c, -1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kg == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "metric", "block_m", "block_n", "block_k", "interpret", "out_dtype"))
def vq_amm_pallas(x: jax.Array, z: jax.Array, lut: jax.Array,
                  scale: Optional[jax.Array] = None,
                  metric: Metric = "l2",
                  block_m: Optional[int] = None,
                  block_n: Optional[int] = None,
                  block_k: Optional[int] = None,
                  interpret: bool = False,
                  out_dtype=jnp.float32) -> jax.Array:
    """Fused approximate matmul: x (M, nc, v), z (nc, c, v), lut (nc, c, N)
    -> out (M, N) with out = lut_gemm(assign(x, z), lut) and no (M, nc)
    index tensor ever touching HBM.

    scale: optional (N,) fp32 dequantisation scale for int8 LUTs.
    Block sizes default to the shared decode/prefill heuristic table.
    """
    m, nc, v = x.shape
    nc_z, c, v_z = z.shape
    nc_l, c_l, n = lut.shape
    assert (nc, v) == (nc_z, v_z), (x.shape, z.shape)
    assert (nc, c) == (nc_l, c_l), (z.shape, lut.shape)

    auto = select_blocks("fused", m, nc, c, n, lut.dtype.itemsize)
    bm = min(block_m or auto.block_m, m)
    bn = min(block_n or auto.block_n, n)
    bk = min(block_k or auto.block_k, nc)

    if m % bm or n % bn or nc % bk:
        pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-nc) % bk
        # Padded rows/subspaces see all-zero x AND all-zero centroids: every
        # distance ties at 0, argmin picks centroid 0 of an all-zero LUT
        # column block — contributes exactly 0 to the accumulation.
        xp = jnp.pad(x, ((0, pad_m), (0, pad_k), (0, 0)))
        zp = jnp.pad(z, ((0, pad_k), (0, 0), (0, 0)))
        lp = jnp.pad(lut, ((0, pad_k), (0, 0), (0, pad_n)))
        out = vq_amm_pallas(xp, zp, lp, None, metric, bm, bn, bk,
                            interpret, out_dtype)
        out = out[:m, :n]
    else:
        grid = (m // bm, n // bn, nc // bk)   # k innermost: LS accumulation
        out = pl.pallas_call(
            functools.partial(_fused_kernel, n_k=grid[2], metric=metric),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk, v), lambda i, j, k: (i, k, 0)),
                pl.BlockSpec((bk, c, v), lambda i, j, k: (k, 0, 0)),
                pl.BlockSpec((bk, c, bn), lambda i, j, k: (k, 0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(x, z, lut)
    if scale is not None:
        out = out * scale[None, :].astype(out_dtype)
    return out
