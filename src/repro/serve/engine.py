"""Serving engines.

:class:`Engine` — the continuous-batching engine (the default):

  * slot-based scheduling: a request is admitted the moment a slot frees,
    including mid-decode (``SlotScheduler``);
  * chunked prefill: prompts are processed in fixed-width right-padded
    chunks of ``prefill_chunk`` tokens, one chunk interleaved with each
    decode step, so a long prompt never stalls running requests;
  * block/paged KV cache: attention KV lives in fixed-size pages with
    per-slot page tables (``PagedKVCache``), so cache memory scales with
    live tokens rather than ``batch_size × max_seq``;
  * per-slot decode positions: one jitted ``decode_paged`` step advances
    every active slot at its own sequence length;
  * optional tensor parallelism: ``Engine(mesh=...)`` shards params and
    the paged KV pool over the mesh's ``model`` axis and compiles the
    paged steps with explicit in/out shardings (data parallelism is
    replica-level — :class:`repro.serve.router.ReplicaRouter`).

:class:`BatchToCompletionEngine` — the legacy fixed-batch engine, kept as
the measurable baseline for ``benchmarks/serve_bench.py``: requests are
grouped into fixed batches, prefilled together and decoded until the
*longest* request finishes (head-of-line blocking), with a dense
``(batch, max_seq)`` cache.

Padding conventions (see docs/serving.md):

  * Continuous engine: prompts are RIGHT-padded per chunk. Pad positions
    sit causally in the future of every real token, their KV is scattered
    to the trash page, and decode masks cache rows ``>= pos`` — padded
    positions are therefore never attended.
  * Batch engine: prompts are LEFT-padded (right-aligned) so the batch
    decodes from a uniform position. Pad rows occupy cache rows
    ``[0, pad_len)`` and are attention-masked via ``pad_lens``
    (historically they were NOT masked — fixed here). RoPE is relative,
    so the uniform per-row shift of absolute positions is harmless.

The paper's technique enters through ``qc``: with ``mode="lut_infer"``
every projection runs assignment + LUT lookups (the fused CCM→IMM kernel
path) instead of dense GEMMs — precomputed tables must already be in
``params`` (see ``repro.core.precompute_model``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_codebook import KVCodebook
from repro.core.lut import DENSE, QuantConfig
from repro.obs import Obs, safe_ratio

from .kv_cache import PagedKVCache, PagePoolExhausted
from .scheduler import FinishReason, Request, SlotPhase, SlotScheduler
from .speculative import SpecConfig, accept_tokens

log = logging.getLogger(__name__)

# Degradation ladder (docs/robustness.md): each mode sheds work the
# engine can live without, in order of how cheap the capability is to
# lose. Pressure is pool occupancy (PagedKVCache.pressure).
MODE_NORMAL = 0          # full speculative lookahead, full prefill budget
MODE_NO_SPEC = 1         # speculative lookahead off (spec pages freed)
MODE_SHRINK_PREFILL = 2  # prefill chunk budget cut (decode keeps priority)
MODE_STOP_ADMIT = 3      # no new admissions until pressure clears
MODE_NAMES = ("normal", "no_spec", "shrink_prefill", "stop_admit")


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Pressure thresholds for the engine's degradation ladder.

    Escalation is immediate: the mode jumps to however many thresholds
    the current pressure crosses. De-escalation is hysteretic: a mode is
    only re-enabled once pressure drops ``hysteresis`` BELOW the
    threshold that disabled it, so the engine cannot flap between modes
    at a threshold boundary. ``mode_for`` is monotone in pressure for a
    fixed current mode (property-tested in tests/test_faults.py).

    Attributes:
      spec_off: at/above this pressure, speculative lookahead is disabled
        (draft pages are pure opportunism — first thing to go).
      chunk_shrink: at/above, the prefill chunk budget is divided by
        ``chunk_divisor`` (floor ``min_chunk``) — decode drains pages,
        prefill only adds them.
      admit_stop: at/above, admission stops entirely (waiting requests
        stay queued; the bounded queue sheds overflow by priority).
      hysteresis: re-enable margin below each threshold.
    """
    spec_off: float = 0.80
    chunk_shrink: float = 0.90
    admit_stop: float = 0.97
    hysteresis: float = 0.10
    chunk_divisor: int = 4
    min_chunk: int = 2

    def __post_init__(self):
        t = (self.spec_off, self.chunk_shrink, self.admit_stop)
        if not (0.0 < t[0] <= t[1] <= t[2] <= 1.0):
            raise ValueError(f"thresholds must satisfy 0 < spec_off <= "
                             f"chunk_shrink <= admit_stop <= 1, got {t}")

    def mode_for(self, pressure: float, current: int) -> int:
        """Next degradation mode given the pool pressure and the mode the
        engine is currently in (hysteresis needs the history)."""
        thresholds = (self.spec_off, self.chunk_shrink, self.admit_stop)
        up = sum(pressure >= t for t in thresholds)
        down = sum(pressure > t - self.hysteresis for t in thresholds)
        return max(up, min(current, down))


#: Default degradation ladder. Hoisted to a module constant (the
#: dataclass is frozen, so sharing one instance across engines is safe)
#: rather than constructed in the signature — a call in a default arg
#: trips the mutable-default lint and hides construction cost at import.
DEFAULT_DEGRADATION = DegradationPolicy()


def _i32(x) -> jax.Array:
    return jnp.asarray(x, jnp.int32)


def _observe_request(obs: Obs, req) -> None:
    """Record one finished request into ``obs.metrics`` (idempotent).

    Emits the ``req.finish.*`` tally and the latency families — step
    clock (TTFT / end-to-end in engine steps, from the scheduler's
    ``arrival`` / ``first_token_step`` / ``finish_step`` stamps) and
    wall clock (``*_s`` histograms plus TPOT, from the ``*_ts``
    ``perf_counter`` stamps) — then closes the request's trace span.
    Both engines and the shed/expire/truncate finish paths funnel here,
    so ``serve_demo`` and ``serve_bench`` report from one accounting.
    """
    if req.finish_reason is None or getattr(req, "_obs_done", False):
        return
    req._obs_done = True
    m = obs.metrics
    m.counter("req.finish." + req.finish_reason.name.lower(),
              unit="requests").inc()
    if req.arrival is not None:
        if req.first_token_step is not None:
            m.histogram("req.ttft_steps", unit="steps", lo=1.0,
                        hi=1e6).observe(req.first_token_step - req.arrival)
        if req.finish_step is not None:
            m.histogram("req.latency_steps", unit="steps", lo=1.0,
                        hi=1e6).observe(req.finish_step - req.arrival)
    if req.arrival_ts is not None:
        if req.first_token_ts is not None:
            m.histogram("req.ttft_s", unit="s").observe(
                req.first_token_ts - req.arrival_ts)
        if req.finish_ts is not None:
            m.histogram("req.latency_s", unit="s").observe(
                req.finish_ts - req.arrival_ts)
            n_decoded = len(req.out_tokens) - 1
            if n_decoded > 0 and req.first_token_ts is not None:
                m.histogram("req.tpot_s", unit="s").observe(
                    (req.finish_ts - req.first_token_ts) / n_decoded)
    tr = obs.tracer
    if tr.enabled and getattr(req, "_obs_traced", False):
        tr.request_end(req._seq, f"req {req._seq}",
                       {"reason": req.finish_reason.name,
                        "tokens": len(req.out_tokens)})


def _with_argmax(logits: jax.Array, kv):
    """Verify-step output shaping: (logits, per-row argmax ids, kv).

    The argmax is computed ON DEVICE so all-greedy speculative rounds
    transfer only (num_slots, k+1) token ids to the host — the full
    logits tensor is fetched lazily, and only when a temperature slot
    needs the distributions for rejection sampling."""
    return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), kv


def _sample_tokens(key: jax.Array, logits: jax.Array,
                   temps: Optional[jax.Array], slot_ids: Sequence[int]):
    """Shared sampling helper. Returns (new_key, tokens (B,) int32).

    Greedy where temperature <= 0, categorical over ``logits / T``
    elsewhere. temps: (B,) fp32, or None when the whole batch is greedy
    (no PRNG state is consumed then).

    Per-slot PRNG: one subkey per call, folded with each row's *slot
    index*, then one independent categorical draw per row — so identical
    requests occupying different slots draw different samples (regression
    test: test_serve_paged.py::test_identical_hot_requests_diverge).
    Sample streams are NOT reproducible across batch compositions: how
    often the key advances depends on engine scheduling.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if temps is None:
        return key, greedy
    key, sub = jax.random.split(key)
    keys = jax.vmap(lambda i: jax.random.fold_in(sub, i))(_i32(list(slot_ids)))
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return key, jnp.where(temps > 0.0, sampled.astype(jnp.int32), greedy)


class Engine:
    """Continuous-batching engine over a paged KV cache.

    Args:
      model: ``repro.models.model.Model`` (token-prompt families:
        dense / moe / ssm / hybrid; ``head_layout="heads"``).
      params: model params pytree (LUT tables precomputed for
        ``qc.mode == "lut_infer"``).
      qc: quantisation operating point threaded through every projection.
      batch_size: number of slots (max concurrently running requests).
      max_seq: per-slot sequence capacity (rounded up to a page multiple).
      eos_id: optional stop token.
      seed: PRNG seed for sampling.
      page_size: tokens per KV page.
      num_pages: physical page-pool size; default ``slots ×
        pages_per_slot`` (no oversubscription). Smaller pools admit fewer
        concurrent tokens and may trigger preemption.
      prefill_chunk: static prefill chunk width (must divide max_seq).
      prefix_cache: automatic prefix caching (default on). Fully written
        prompt pages are content-hash indexed as prefill covers them; a
        later request sharing a page-aligned prompt prefix maps those
        pages read-shared and starts prefill at its first uncached token
        (docs/serving.md §Prefix caching). Greedy output is unchanged —
        shared pages hold exactly the KV a cold prefill would recompute.
        Mamba2/hybrid state is not paged, so those families always serve
        cold (the knob is inert there).
      spec_decode: optional :class:`~repro.serve.speculative.SpecConfig`
        enabling self-speculative decoding (docs/speculative.md): a cheap
        drafter (the target's own weights through a low-bit LUT operating
        point, an early-exit prefix, or host-side n-gram lookup) proposes
        up to ``k`` tokens per decoding slot and ONE batched
        ``verify_paged`` call scores them, emitting between 1 and ``k+1``
        tokens per round. Greedy output stays token-identical to
        non-speculative decoding; temperature mode applies rejection
        sampling with the residual correction. Attention (paged KV)
        families only — recurrent state cannot roll back.
      max_queue: optional bound on the waiting queue. A ``submit`` that
        would overflow it sheds the lowest-priority (newest) request
        with a clean ``finish_reason = LoadShedded`` result instead of
        raising — admission control for burst traffic
        (docs/robustness.md). ``None`` = unbounded.
      degradation: :class:`DegradationPolicy` stepping the engine down
        a ladder of modes as pool pressure rises — speculative
        lookahead off, then a shrunken prefill budget, then an admission
        stop — and back up (with hysteresis) as pressure clears. Pass
        ``None`` to disable (the pre-fault-tolerance behaviour).
      mesh: optional ``jax.sharding.Mesh`` (``launch.mesh``) with a
        ``model`` axis. When given, the engine serves TENSOR-PARALLEL over
        the mesh: params are placed by ``parallel.sharding.param_pspecs``
        (codebooks replicated for column-parallel projections,
        subspace-sharded for row-parallel ones), the paged KV pool by
        ``paged_cache_pspecs`` (pages replicated over ``data``,
        kv-heads / head-dim over ``model``), and the jitted
        prefill/decode steps carry explicit in/out shardings so GSPMD
        inserts the row-parallel all-reduce after each subspace-sharded
        LUT accumulate. Data parallelism is replica-level — see
        :class:`repro.serve.router.ReplicaRouter`.
    """

    def __init__(self, model, params, qc: QuantConfig = DENSE,
                 batch_size: int = 8, max_seq: int = 512,
                 eos_id: Optional[int] = None, seed: int = 0,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 32, mesh=None,
                 prefix_cache: bool = True,
                 spec_decode: Optional[SpecConfig] = None,
                 max_queue: Optional[int] = None,
                 degradation: Optional[DegradationPolicy]
                 = DEFAULT_DEGRADATION,
                 kv_codebook: Optional[KVCodebook] = None,
                 obs: Optional[Obs] = None):
        self.model = model
        self.params = params
        self.qc = qc
        self.num_slots = batch_size
        max_seq = -(-max_seq // page_size) * page_size   # round up to pages
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.prefill_chunk = max(2, min(prefill_chunk, max_seq))
        if max_seq % self.prefill_chunk:
            raise ValueError(
                f"prefill_chunk ({self.prefill_chunk}) must divide "
                f"max_seq ({max_seq})")
        # KV-cache quantization (docs/serving.md §KV-cache quantization):
        # with qc.kv_quant == "vq" the page pool stores uint8 codebook
        # indices; the codebook is fit here, once, from a deterministic
        # calibration prefill (bit-identical across replicas/restarts, so
        # prefix pages hash compatibly) unless the caller supplies one.
        self.kv_codebook = kv_codebook
        if qc.kv_quant == "vq":
            from repro.models.model import ATTN_FAMILIES
            if model.cfg.family not in ATTN_FAMILIES:
                raise ValueError(
                    "kv_quant='vq' quantizes paged attention KV pages; "
                    f"the {model.cfg.family!r} family has recurrent "
                    "state, which has no page rows to encode")
            if self.kv_codebook is None:
                self.kv_codebook = self._fit_kv_codebook()
        elif kv_codebook is not None:
            raise ValueError(
                "kv_codebook supplied but qc.kv_quant is 'none' — set "
                "qc = qc.replace(kv_quant='vq') to serve quantized")
        # Observability bundle (docs/observability.md): every counter
        # below lives in ``obs.metrics`` behind same-named read-only
        # properties, so the attribute surface tests and the router read
        # is unchanged. The registry is always live (counters double as
        # engine state); ``Obs.disabled()`` only compiles out the timing
        # layer (phase histograms + trace spans). The scheduler and KV
        # pool record into the same bundle; a shared ``Tracer`` across
        # replicas merges them into one Perfetto timeline.
        self.obs = obs if obs is not None else Obs()
        met = self.obs.metrics
        self.kv = PagedKVCache(model, self.num_slots, max_seq,
                               page_size=page_size, num_pages=num_pages,
                               prefix_cache=prefix_cache,
                               codebook=self.kv_codebook)
        self.kv.obs = self.obs
        self.scheduler = SlotScheduler(self.num_slots, max_queue=max_queue,
                                       obs=self.obs)
        self.step_count = 0
        # Degradation ladder state (docs/robustness.md): mode 0..3, step
        # counts per mode for the stats surface, and a monotone count of
        # emitted tokens — the router watchdog's progress marker.
        self.degradation = degradation
        self.mode = MODE_NORMAL
        self._c_mode = tuple(
            met.counter(f"engine.mode_steps.{MODE_NAMES[i]}", unit="steps",
                        desc="steps spent in this degradation mode")
            for i in range(4))
        self._c_transitions = met.counter(
            "engine.degradation.transitions", unit="transitions")
        self._c_emitted = met.counter("engine.emitted_tokens",
                                      unit="tokens")
        # Prefix-cache accounting (docs/serving.md §Prefix caching):
        #   prompt_tokens     — prompt tokens admitted (incl. re-admissions)
        #   cached_tokens     — of those, served from shared pages
        #   prefilled_tokens  — tokens actually pushed through prefill
        self._c_prompt = met.counter("engine.prompt_tokens", unit="tokens")
        self._c_cached = met.counter("engine.cached_tokens", unit="tokens")
        self._c_prefilled = met.counter("engine.prefilled_tokens",
                                        unit="tokens")
        self._g_pool_bytes = met.gauge("engine.pool.live_bytes", unit="B")
        self._g_pressure = met.gauge("engine.pool.pressure")
        self._g_mode = met.gauge("engine.mode")

        # Per-slot temperatures live in a DEVICE-RESIDENT (num_slots,)
        # buffer refreshed only when slot occupancy changes (admission /
        # eviction / preemption) — never per decode step. ``temps_uploads``
        # counts the host->device transfers for the regression test.
        self._temps_h = np.zeros((self.num_slots,), np.float32)
        self._temps_dev: Optional[jax.Array] = None
        self._c_temps = met.counter("engine.temps_uploads", unit="uploads")

        self.mesh = mesh
        self._table_sharding = None
        if mesh is None:
            self._jit_prefill = jax.jit(
                lambda p, t, kv, pt, slot, pos, valid: model.prefill_paged(
                    p, t, kv, pt, slot, pos, valid, qc),
                donate_argnums=(2,))
            self._jit_decode = jax.jit(
                lambda p, t, kv, pt, positions: model.decode_paged(
                    p, t, kv, pt, positions, qc),
                donate_argnums=(2,))
            self._jit_verify = jax.jit(
                lambda p, t, kv, pt, pos, nl: _with_argmax(
                    *model.verify_paged(p, t, kv, pt, pos, nl, qc)),
                donate_argnums=(2,))
        else:
            self._init_sharded(mesh)

        # Batch sampling runs JITTED so a steady-state decode step is
        # exactly two compiled calls (decode + sample) and ONE host
        # transfer — the (num_slots,) token vector through _device_read.
        # slot_ids is the full lane range, closed over as a static
        # constant; greedy (temps=None) and temperature batches are two
        # shape classes of the same jit.
        nslots = self.num_slots
        self._jit_sample = jax.jit(
            lambda key, logits, temps: _sample_tokens(
                key, logits, temps, range(nslots)))
        # Host-transfer accounting: every per-step device->host read in
        # the serving loop goes through _device_read, which bumps this.
        self._c_device_reads = met.counter("engine.device_reads",
                                           unit="reads")

        # Speculative decoding (docs/speculative.md): draft cheap, verify
        # with the target in one multi-token call, roll back rejections.
        self.spec = spec_decode
        self.drafter = None
        self._c_spec_rounds = met.counter(      # verify calls issued
            "engine.spec.rounds", unit="rounds")
        self._c_spec_drafted = met.counter(     # proposals scored
            "engine.spec.drafted", unit="tokens")
        self._c_spec_accepted = met.counter(    # proposals that survived
            "engine.spec.accepted", unit="tokens")
        self._c_spec_emitted = met.counter(     # tokens emitted by spec
            "engine.spec.emitted", unit="tokens")
        if spec_decode is not None:
            if not self.kv.paged:
                raise ValueError(
                    "spec_decode needs rewindable paged KV state; the "
                    f"{model.cfg.family!r} family's recurrent state cannot "
                    "roll back rejected draft tokens")
            if spec_decode.k < 1:
                raise ValueError(f"spec_decode.k must be >= 1, got "
                                 f"{spec_decode.k}")
            self._spec_rng = np.random.default_rng(seed)
            self.drafter = spec_decode.build_drafter()
            self.drafter.bind(self)

    def _fit_kv_codebook(self) -> KVCodebook:
        """Fit the KV codebook from a deterministic calibration prefill.

        A fixed token ramp (no PRNG-dependent data) runs through the fp
        dense-cache prefill; the per-layer K/V rows it leaves behind are
        the k-means sample. Everything downstream of (model, params, qc)
        is deterministic — a warm replica and a cold restart fit the same
        codebook, so their quantized prefix pages share one salt space.
        """
        model, cfg = self.model, self.model.cfg
        t = min(128, self.max_seq)
        tokens = (jnp.arange(t, dtype=jnp.int32) * 31 + 7) % cfg.vocab_size
        cache = model.init_cache(1, t)
        _, cache = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, self.qc)
        )(self.params, {"tokens": tokens[None]}, cache)
        rows_k = cache["layers"]["k"][:, 0]            # (L, T, KVH, HD)
        rows_v = cache["layers"]["v"][:, 0]
        return KVCodebook.fit(rows_k, rows_v, v=self.qc.kv_v,
                              c=self.qc.kv_c, key=jax.random.PRNGKey(0))

    def _init_sharded(self, mesh) -> None:
        """Place params + paged cache on ``mesh`` and compile the paged
        entry points with explicit in/out shardings (tensor parallelism
        over the ``model`` axis; see docs/serving.md §Sharded serving)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import (logical_to_sharding,
                                             paged_cache_pspecs,
                                             param_pspecs)
        model, qc, cfg = self.model, self.qc, self.model.cfg
        msize = mesh.shape["model"]
        pshard = logical_to_sharding(
            param_pspecs(self.params, cfg, model_axis_size=msize), mesh)
        self.params = jax.device_put(self.params, pshard)
        cshard = logical_to_sharding(
            paged_cache_pspecs(cfg, mesh,
                               quantized=self.kv_codebook is not None),
            mesh)
        self.kv.data = jax.device_put(self.kv.data, cshard)
        repl = NamedSharding(mesh, P())
        self._table_sharding = repl
        # NOTE: jax.jit is lazy — tracing happens at the first CALL, which
        # the step methods wrap in _mesh_scope() (the ambient mesh the
        # in-model with_sharding_constraint hooks need); scoping the jit
        # construction here would be inert.
        self._jit_prefill = jax.jit(
            lambda p, t, kv, pt, slot, pos, valid: model.prefill_paged(
                p, t, kv, pt, slot, pos, valid, qc, act_sharding=repl),
            in_shardings=(pshard, repl, cshard, repl, repl, repl, repl),
            out_shardings=(repl, cshard),
            donate_argnums=(2,))
        self._jit_decode = jax.jit(
            lambda p, t, kv, pt, positions: model.decode_paged(
                p, t, kv, pt, positions, qc, act_sharding=repl),
            in_shardings=(pshard, repl, cshard, repl, repl),
            out_shardings=(repl, cshard),
            donate_argnums=(2,))
        self._param_sharding = pshard
        self._cache_sharding = cshard
        self._jit_verify = jax.jit(
            lambda p, t, kv, pt, pos, nl: _with_argmax(
                *model.verify_paged(p, t, kv, pt, pos, nl, qc,
                                    act_sharding=repl)),
            in_shardings=(pshard, repl, cshard, repl, repl, repl),
            out_shardings=(repl, repl, cshard),
            donate_argnums=(2,))

    def _mesh_scope(self):
        """Ambient-mesh context for tracing/compiling the jitted steps
        (lets in-model ``with_sharding_constraint`` hooks see the mesh)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.launch.mesh import mesh_context
        return mesh_context(self.mesh)

    # ------------------------------------------------------------------
    # host transfers
    # ------------------------------------------------------------------
    def _device_read(self, tree):
        """THE device->host funnel for the step loop.

        Every per-step read crosses here as one ``jax.device_get`` of a
        small pytree (token ids, argmax ids, optionally logits) instead
        of scattered ``.item()`` / ``np.asarray`` syncs — so a decode
        step costs exactly one transfer and ``device_reads`` counts them
        for the regression tests (test_recompile_guard.py). This is the
        sanctioned sync point; the `analysis` linter flags any other
        read reachable from the step loop. Because the step loop blocks
        HERE (and only here), the ``device_read`` phase span measures
        the true device wait, not dispatch overhead."""
        self._c_device_reads.inc()
        with self.obs.phase("device_read"):
            return jax.device_get(tree)  # analysis: ok(step-sync)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array, temps: Optional[jax.Array],
                slot_ids: Sequence[int]) -> jax.Array:
        """One token per row via :func:`_sample_tokens` (per-slot keys)."""
        self.key, toks = _sample_tokens(self.key, logits, temps, slot_ids)
        return toks

    # ------------------------------------------------------------------
    # per-slot temperature buffer (device-resident)
    # ------------------------------------------------------------------
    def _set_slot_temp(self, slot_idx: int, temp: float) -> None:
        """Update one lane's temperature; invalidates the device buffer
        only when the value actually changes."""
        if self._temps_h[slot_idx] != temp:
            self._temps_h[slot_idx] = temp
            self._temps_dev = None

    def _decode_temps(self) -> Optional[jax.Array]:
        """(num_slots,) device temps, or None when every lane is greedy.

        The device buffer is cached between decode steps and re-uploaded
        only after an occupancy change touched a temperature — the per-step
        host->device churn the batch engine never had is not re-introduced
        here (regression: test_serve_paged.py::
        test_no_per_step_temperature_upload)."""
        if not (self._temps_h > 0.0).any():
            return None
        if self._temps_dev is None:
            if self._table_sharding is not None:
                self._temps_dev = jax.device_put(self._temps_h,
                                                 self._table_sharding)
            else:
                self._temps_dev = jnp.asarray(self._temps_h)
            self._c_temps.inc()
        return self._temps_dev

    @property
    def load(self) -> int:
        """Requests queued or occupying a slot (router dispatch metric)."""
        return len(self.scheduler.waiting) + sum(
            not s.free for s in self.scheduler.slots)

    # ------------------------------------------------------------------
    # registry-backed counter surface (legacy attribute names)
    # ------------------------------------------------------------------
    @property
    def mode_steps(self) -> Dict[int, int]:
        """Steps spent in each degradation mode (``{mode: steps}``)."""
        return {i: c.value for i, c in enumerate(self._c_mode)}

    @property
    def emitted_tokens(self) -> int:
        return self._c_emitted.value

    @property
    def prompt_tokens(self) -> int:
        return self._c_prompt.value

    @property
    def cached_tokens(self) -> int:
        return self._c_cached.value

    @property
    def prefilled_tokens(self) -> int:
        return self._c_prefilled.value

    @property
    def temps_uploads(self) -> int:
        return self._c_temps.value

    @property
    def device_reads(self) -> int:
        return self._c_device_reads.value

    @property
    def spec_rounds(self) -> int:
        return self._c_spec_rounds.value

    @property
    def spec_drafted(self) -> int:
        return self._c_spec_drafted.value

    @property
    def spec_accepted(self) -> int:
        return self._c_spec_accepted.value

    @property
    def spec_emitted(self) -> int:
        return self._c_spec_emitted.value

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from shared pages
        (0.0 before any admission — never a division error)."""
        return safe_ratio(self._c_cached.value, self._c_prompt.value)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Optional[Request]:
        """Enqueue a request; it is admitted as soon as a slot + pages free.

        Raises :class:`PagePoolExhausted` immediately (before the request
        enters the queue) if its prompt could never be served — so one
        oversized request cannot abort a run with valid requests in
        flight. A request whose *generation* outgrows an undersized page
        pool later is finished as truncated, not errored (see
        :meth:`_decode_step`).

        With a bounded queue (``max_queue``), overflow sheds the
        lowest-priority request with ``finish_reason = LoadShedded`` —
        returned here (possibly ``req`` itself) so callers can observe
        the drop; ``None`` when nothing was shed. ``arrival`` is stamped
        with the current engine step when unset (the deadline clock)."""
        # a recovered / re-submitted request's generated tokens are part
        # of the prompt it will re-prefill with — account for them
        self.kv.check_admissible(len(req.tokens) + len(req.out_tokens))
        if req.arrival is None:
            req.arrival = self.step_count
        if req.arrival_ts is None:
            req.arrival_ts = time.perf_counter()
        victim = self.scheduler.submit(req)
        if victim is not req:
            self._obs_request_start(req)
        if victim is not None:
            if victim.finish_step is None:
                victim.finish_step = self.step_count
            _observe_request(self.obs, victim)
        return victim

    def requeue(self, req: Request) -> None:
        """Re-admit a request the system already accepted (crash recovery
        from another replica): exempt from the queue bound — rescuing a
        request must never shed it — and placed at the queue front. The
        caller accounts the retry (the router does, for its backoff)."""
        self.kv.check_admissible(len(req.tokens) + len(req.out_tokens))
        if req.arrival is None:
            req.arrival = self.step_count
        if req.arrival_ts is None:
            req.arrival_ts = time.perf_counter()
        self.scheduler.requeue(req, front=True, count_retry=False)
        self._obs_request_start(req)

    def _obs_request_start(self, req) -> None:
        """Open (or re-annotate) the request's lifecycle trace span.

        All request spans live on the dedicated ``REQUEST_PID`` track,
        keyed by the scheduler sequence number — a request that migrates
        replicas after a crash stays one span, with a ``requeued``
        marker at each re-admission."""
        tr = self.obs.tracer
        if not tr.enabled or req._seq < 0:
            return
        rid = req._seq
        if getattr(req, "_obs_traced", False):
            tr.request_instant(rid, f"req {rid}", "requeued")
        else:
            req._obs_traced = True
            tr.request_begin(rid, f"req {rid}",
                             {"prompt": len(req.tokens),
                              "max_new": req.max_new_tokens,
                              "priority": req.priority})

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests to completion (continuous batching)."""
        for r in requests:
            self.submit(r)
        self.run_until_idle()
        return requests

    # Steps tolerated with work pending but nothing progressing before
    # run_until_idle errors out. Non-zero because transiently-held pages
    # (fault injection / an external pool holder) legitimately stall the
    # engine; bounded so a genuine livelock still fails loudly.
    STALL_LIMIT = 512

    def run_until_idle(self) -> None:
        """Step until queue and slots are empty."""
        stalled = 0
        while self.scheduler.has_work:
            if self.step():
                stalled = 0
            else:
                stalled += 1
                if stalled > self.STALL_LIMIT:
                    raise RuntimeError(
                        f"engine made no progress in {stalled} steps "
                        f"({self.kv.occupancy()})")

    def jit_entry_points(self) -> Dict[str, object]:
        """Named jitted callables of the serving hot path.

        The recompile guard (:mod:`repro.analysis.recompile`) reads each
        one's ``_cache_size()`` to assert exactly one compile per
        (entry point, shape class) across a mixed workload."""
        eps = {"prefill": self._jit_prefill, "decode": self._jit_decode,
               "verify": self._jit_verify, "sample": self._jit_sample}
        for name in ("_draft_greedy", "_draft_probs"):
            fn = getattr(self.drafter, name, None)
            if fn is not None:
                eps["draft" + name[len("_draft"):]] = fn
        return eps

    @property
    def pressure(self) -> float:
        """Current page-pool pressure in [0, 1] (see PagedKVCache)."""
        return self.kv.pressure

    @property
    def progress_marker(self):
        """Monotone work counter — the router watchdog compares this
        between steps to detect a stalled (alive but useless) replica."""
        return self.prefilled_tokens + self.emitted_tokens

    def _update_degradation(self) -> None:
        """Advance the degradation ladder from the current pressure."""
        if self.degradation is None:
            return
        new = self.degradation.mode_for(self.pressure, self.mode)
        if new != self.mode:
            log.info("degradation %s -> %s (pressure %.2f, %s)",
                     MODE_NAMES[self.mode], MODE_NAMES[new],
                     self.pressure, self.kv.occupancy())
            self._c_transitions.inc()
            self.obs.annotate("degradation", frm=MODE_NAMES[self.mode],
                              to=MODE_NAMES[new],
                              pressure=round(self.pressure, 3))
        self.mode = new

    def step(self) -> bool:
        """One engine iteration: admit, one prefill chunk, one decode step.

        Running at most one prefill chunk per iteration bounds the decode
        stall any prompt can cause to ``prefill_chunk`` tokens of work.
        Under pressure the degradation ladder sheds work first: mode 1
        drops speculative lookahead, mode 2 shrinks the prefill budget,
        mode 3 stops admitting (docs/robustness.md).
        Returns False when there was nothing to do.
        """
        self._update_degradation()
        self._c_mode[self.mode].inc()
        obs = self.obs
        if obs.active:        # pool gauges: host ints, but O(pages) scans
            self._g_pool_bytes.set(float(self.kv.live_bytes))
            self._g_pressure.set(self.pressure)
            self._g_mode.set(float(self.mode))
            obs.track("pool.pressure", self.pressure)
        with obs.phase("admit"):
            for req in self.scheduler.expire_deadlines(self.step_count,
                                                       self.kv):
                log.info("request expired past deadline_steps=%s",
                         req.deadline_steps)
                _observe_request(obs, req)
            for s in self.scheduler.slots:   # expiry may have freed lanes
                if s.free:
                    self._set_slot_temp(s.idx, 0.0)
            # Admission stops at the top of the ladder — but never on an
            # idle engine (nothing running = nothing will release pages,
            # so waiting would deadlock; pressure on an idle pool is ~0
            # anyway unless pages are held externally, and then admit()
            # simply waits).
            if (self.mode < MODE_STOP_ADMIT
                    or not self.scheduler.occupied_slots()):
                for slot in self.scheduler.admit(self.kv):
                    self._set_slot_temp(slot.idx, slot.req.temperature)
                    self._c_prompt.inc(slot.prefill_len)
                    self._c_cached.inc(slot.pos)  # admission: pos = matched
        progressed = False
        slot = self.scheduler.next_prefill()
        if slot is not None:
            with obs.phase("prefill_chunk"):
                self._prefill_chunk_step(slot)
            progressed = True
        if self.scheduler.decode_slots():
            if self.spec is not None and self.mode < MODE_NO_SPEC:
                self._spec_decode_step()
            else:
                self._decode_step()
            progressed = True
        self.step_count += 1
        return progressed

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _evict(self, slot) -> None:
        """Evict + clear the lane's temperature (device buffer refresh)."""
        self.scheduler.evict(slot, self.kv)
        self._set_slot_temp(slot.idx, 0.0)

    def _reserve_lookahead(self, slot_idx: int, pos: int, kk: int) -> int:
        """Reserve pages for ``kk`` draft tokens past the pending one,
        shrinking ``kk`` instead of preempting when the pool runs short
        (speculation is opportunistic). Returns the reserved lookahead."""
        while kk > 0:
            try:
                self.kv.ensure(slot_idx, pos + kk + 1)
                return kk
            except PagePoolExhausted:
                kk -= 1
        return 0

    def _ensure_pages(self, slot_idx: int, n_tokens: int) -> None:
        """Grow a slot to n_tokens, preempting other slots if needed."""
        while True:
            try:
                self.kv.ensure(slot_idx, n_tokens)
                return
            except PagePoolExhausted:
                victim = self.scheduler.preempt_youngest(
                    self.kv, exclude=slot_idx)
                if victim is None:
                    raise
                self._set_slot_temp(victim.idx, 0.0)

    @property
    def prefill_budget(self) -> int:
        """Prompt tokens fed per prefill chunk — the full static chunk
        width normally; divided by the policy's ``chunk_divisor`` in
        degradation mode >= 2 (the compiled chunk SHAPE never changes,
        only how much of it carries real tokens, so no recompilation)."""
        if self.degradation is None or self.mode < MODE_SHRINK_PREFILL:
            return self.prefill_chunk
        return max(self.degradation.min_chunk,
                   self.prefill_chunk // self.degradation.chunk_divisor)

    def _prefill_chunk_step(self, slot) -> None:
        c = self.prefill_chunk           # static compiled width, never shrunk
        chunk = self.scheduler.prompt_chunk(
            slot, min(c, self.prefill_budget))
        valid = len(chunk)
        # prompt pages were committed in full by SlotScheduler.admit() —
        # only decode grows a slot page-by-page
        toks = np.zeros((1, c), np.int32)
        toks[0, :valid] = chunk
        with self._mesh_scope():
            logits, self.kv.data = self._jit_prefill(
                self.params, jnp.asarray(toks), self.kv.data,
                self.kv.table_device(self._table_sharding), _i32(slot.idx),
                _i32(slot.pos), _i32(valid))
        slot.pos += valid
        self._c_prefilled.inc(valid)
        # index the prompt pages this chunk completed: from here on other
        # requests sharing the prefix can map them instead of recomputing
        self.kv.register_prefix(slot.idx, slot.prompt, slot.pos)
        if slot.pos < slot.prefill_len:
            return
        req = slot.req
        temps = (jnp.asarray([req.temperature], jnp.float32)
                 if req.temperature > 0.0 else None)
        tok = int(self._device_read(
            self._sample(logits, temps, [slot.idx]))[0])
        self.scheduler.finish_prefill(slot, tok)
        self._record_token(slot, tok)

    def _grow_or_shed(self, s) -> None:
        """Reserve the page covering slot ``s``'s next write position,
        preempting neighbours if needed. When even that fails: an
        undersized pool that can NEVER hold the sequence finishes the
        request as truncated (the last sampled token is already in
        out_tokens and needs no cache write); a pool that could hold it
        but whose pages are transiently held elsewhere (fault injection /
        an external holder) preempts the slot itself — the request
        requeues and resumes token-identically once pages return."""
        try:
            self._ensure_pages(s.idx, s.pos + 1)
        except PagePoolExhausted:
            if self.kv.pages_for(s.pos + 1) > \
                    self.kv.table.allocator.num_pages:
                req = s.req          # _evict clears slot.req
                req.finish(FinishReason.TRUNCATED, self.step_count)
                self._evict(s)
                _observe_request(self.obs, req)
            else:
                self.scheduler.preempt(s, self.kv)
                self._set_slot_temp(s.idx, 0.0)

    def _decode_step(self) -> None:
        for s in list(self.scheduler.decode_slots()):
            if s.phase is not SlotPhase.DECODE:
                continue          # preempted by an earlier ensure this loop
            # the page covering the write position must exist up front
            self._grow_or_shed(s)
        dslots = self.scheduler.decode_slots()  # preemption may have culled
        if not dslots:
            return
        b = self.num_slots
        toks = np.zeros((b, 1), np.int32)
        # -1 marks lanes that are NOT decoding this step (free slots and
        # slots mid-prefill): decode_paged redirects their KV writes to
        # the trash page/row instead of through their page tables.
        positions = np.full((b,), -1, np.int32)
        for s in dslots:
            toks[s.idx, 0] = s.next_token
            positions[s.idx] = s.pos
        # device-resident per-slot temps: refreshed on admission/eviction,
        # NOT rebuilt and re-uploaded every decode step
        temps = self._decode_temps()
        with self._mesh_scope():
            with self.obs.phase("decode"):
                logits, self.kv.data = self._jit_decode(
                    self.params, jnp.asarray(toks), self.kv.data,
                    self.kv.table_device(self._table_sharding),
                    jnp.asarray(positions))
            with self.obs.phase("sample"):
                self.key, nxt_dev = self._jit_sample(self.key, logits,
                                                     temps)
        nxt = self._device_read(nxt_dev)
        for s in dslots:
            s.pos += 1
            self._record_token(s, int(nxt[s.idx]))

    # ------------------------------------------------------------------
    # speculative decoding (docs/speculative.md)
    # ------------------------------------------------------------------
    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted (0.0 before
        any verify round — never a division error)."""
        return safe_ratio(self._c_spec_accepted.value,
                          self._c_spec_drafted.value)

    @property
    def tokens_per_verify(self) -> float:
        """Mean tokens emitted per verify call (1.0 = no speculation win;
        0.0 before any round)."""
        return safe_ratio(self._c_spec_emitted.value,
                          self._c_spec_rounds.value)

    def _spec_decode_step(self) -> None:
        """One draft/verify round over every decoding slot.

        Replaces :meth:`_decode_step` when ``spec_decode`` is configured:
        the drafter proposes up to ``k`` tokens per slot, ONE
        ``verify_paged`` call scores them all (k+1 static token columns;
        under-drafted slots pad with trash-redirected columns), and the
        accepted prefix plus one target-distribution token is recorded —
        token-identical to sequential greedy decoding when temperature
        is 0. Rejected rows roll back: ``slot.pos`` simply does not
        advance over them and :meth:`PagedKVCache.trim` drops tail pages
        the rejected lookahead no longer needs.
        """
        # page for the committed pending token: same rules as _decode_step
        # (preemption allowed; truncate-finish only when the pool can
        # never supply it, self-preempt when pages are transiently held)
        for s in list(self.scheduler.decode_slots()):
            if s.phase is not SlotPhase.DECODE:
                continue
            self._grow_or_shed(s)
        dslots = self.scheduler.decode_slots()
        if not dslots:
            return
        k = self.spec.k
        # Draft lookahead is capped by sequence room and the slot's
        # remaining generation budget (no proposal that could never be
        # recorded), and its page reservations are OPPORTUNISTIC: shrink
        # the lookahead rather than preempt a neighbour for speculation.
        # A drafter that writes draft KV through the page tables needs
        # its pages reserved BEFORE drafting; host-side drafters reserve
        # after proposing, so a no-proposal round allocates nothing.
        k_slot = {}
        for s in dslots:
            room = self.max_seq - s.pos - 1
            budget = s.req.max_new_tokens - len(s.req.out_tokens) - 1
            kk = max(0, min(k, room, budget))
            if self.drafter.writes_kv:
                kk = self._reserve_lookahead(s.idx, s.pos, kk)
            k_slot[s.idx] = kk
        with self.obs.phase("draft"):
            g, n_prop, q_rows = self.drafter.propose(self, dslots, k_slot,
                                                     k)
        if not self.drafter.writes_kv:
            for s in dslots:
                n_prop[s.idx] = self._reserve_lookahead(
                    s.idx, s.pos, int(n_prop[s.idx]))
        b = self.num_slots
        toks = np.zeros((b, k + 1), np.int32)
        posv = np.full((b,), -1, np.int32)
        nlive = np.zeros((b,), np.int32)
        for s in dslots:
            n = int(n_prop[s.idx])
            toks[s.idx, 0] = s.next_token
            toks[s.idx, 1:1 + n] = g[s.idx, :n]
            posv[s.idx] = s.pos
            nlive[s.idx] = n + 1
        with self._mesh_scope():
            with self.obs.phase("verify"):
                logits, ids, self.kv.data = self._jit_verify(
                    self.params, jnp.asarray(toks), self.kv.data,
                    self.kv.table_device(self._table_sharding),
                    jnp.asarray(posv), jnp.asarray(nlive))
        # all-greedy rounds pull only the (B, k+1) argmax ids; the full
        # logits tensor rides the SAME single transfer only when a
        # temperature slot needs distributions for rejection sampling
        need_q = any(s.req.temperature > 0.0 for s in dslots)
        got = self._device_read((ids, logits) if need_q else (ids,))
        ids_h, lg = got[0], (got[1] if need_q else None)
        self._c_spec_rounds.inc()
        for s in dslots:
            n = int(n_prop[s.idx])
            draft = [int(t) for t in g[s.idx, :n]]
            rows = None if q_rows is None else \
                [q_rows[t][s.idx] for t in range(n)]
            accepted, out = accept_tokens(
                draft, None if lg is None else lg[s.idx, :n + 1],
                s.req.temperature, self._spec_rng, rows,
                targets=ids_h[s.idx, :n + 1])
            self._c_spec_drafted.inc(n)
            self._c_spec_accepted.inc(accepted)
            req = s.req              # _record_token may evict (slot.req=None)
            for tok in out:
                s.pos += 1
                self._record_token(s, tok)
                self._c_spec_emitted.inc()
                if req.done:         # EOS/budget/truncation: drop the rest
                    break
            if not req.done:
                # Roll back the rejected lookahead: pages wholly past the
                # working set (committed rows plus the pending token's
                # write row) return to the pool. On a fully accepted
                # round `pos` advanced over everything the draft
                # reserved, so this is a no-op on the hot path; it only
                # fires — and only ever releases fresh refcount-1 draft
                # pages — when rejection left a page boundary behind.
                self.kv.trim(s.idx, s.pos + 1)

    def _record_token(self, slot, tok: int) -> None:
        """Append a sampled token and apply the eviction rules."""
        req = slot.req
        req.out_tokens.append(tok)
        self._c_emitted.inc()
        if req.first_token_step is None:
            req.first_token_step = self.step_count
            req.first_token_ts = time.perf_counter()
        slot.next_token = tok
        hit_eos = self.eos_id is not None and tok == self.eos_id
        budget_done = len(req.out_tokens) >= req.max_new_tokens
        truncated = slot.pos >= self.max_seq      # no room for another write
        if hit_eos or budget_done or truncated:
            req.finish(FinishReason.COMPLETED if (hit_eos or budget_done)
                       else FinishReason.TRUNCATED, self.step_count)
            self._evict(slot)
            _observe_request(self.obs, req)


class BatchToCompletionEngine:
    """Legacy fixed-batch engine (serve_bench baseline).

    Requests are grouped into batches of ``batch_size``, prefilled together
    (LEFT-padded / right-aligned) and decoded step-by-step until every
    request in the batch finishes — the whole batch waits on its longest
    member, and the dense cache is ``(batch, max_seq)`` regardless of
    occupancy. Pad rows are attention-masked via ``pad_lens`` (see module
    docstring). Attention-family models only (an SSM integrates pad inputs
    into its state; use :class:`Engine`, which never left-pads).
    """

    def __init__(self, model, params, qc: QuantConfig = DENSE,
                 batch_size: int = 8, max_seq: int = 512,
                 eos_id: Optional[int] = None, seed: int = 0,
                 obs: Optional[Obs] = None):
        if model.cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                "BatchToCompletionEngine left-pads prompts, which an SSM "
                "would integrate into its state (kv_start only masks "
                "attention); use the continuous Engine for "
                f"family={model.cfg.family!r}")
        if model.cfg.head_layout == "hd":
            raise ValueError(
                "BatchToCompletionEngine needs kv_start masking for "
                "mixed-length batches, which head_layout='hd' does not "
                "implement — the failure would otherwise be data-dependent "
                "(first unequal-length batch)")
        self.model = model
        self.params = params
        self.qc = qc
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        # monotone step clock across batches: one tick per prefill and per
        # decode step, so Request.first_token_step / finish_step are
        # comparable with the continuous engine's step_count timestamps.
        self.step_count = 0
        # same latency accounting as the continuous engine (the ``req.*``
        # families land in obs.metrics), so serve_demo/serve_bench report
        # both engines from one registry surface
        self.obs = obs if obs is not None else Obs()

        self._prefill = jax.jit(
            lambda p, b, c, pl: model.prefill(p, b, c, qc, pad_lens=pl))
        self._decode = jax.jit(
            lambda p, t, c, pl: model.decode(p, t, c, qc, pad_lens=pl),
            donate_argnums=(2,))

    def _sample(self, logits: jax.Array,
                temps: Optional[jax.Array]) -> jax.Array:
        """Per-row sampling via :func:`_sample_tokens` (row index = slot)."""
        self.key, toks = _sample_tokens(
            self.key, logits, temps, range(logits.shape[0]))
        return toks

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests (in submission-order batches of batch_size)."""
        for r in requests:
            if r.arrival is None:
                r.arrival = self.step_count
            if r.arrival_ts is None:
                r.arrival_ts = time.perf_counter()
        for i in range(0, len(requests), self.batch_size):
            self._run_batch(requests[i:i + self.batch_size])
        return requests

    def _run_batch(self, reqs: List[Request]) -> None:
        b = len(reqs)
        pad_b = self.batch_size
        max_prompt = max(len(r.tokens) for r in reqs)
        if max_prompt > self.max_seq:
            raise ValueError(
                f"prompt of {max_prompt} tokens exceeds max_seq="
                f"{self.max_seq}")
        toks = np.zeros((pad_b, max_prompt), np.int32)
        pad_h = np.full((pad_b,), max_prompt, np.int32)  # empty rows: all pad
        for j, r in enumerate(reqs):
            # left-pad / right-align so the batch decodes from one position
            toks[j, max_prompt - len(r.tokens):] = r.tokens
            pad_h[j] = max_prompt - len(r.tokens)
        # uniform-length batches need no mask: pass None so the model keeps
        # the static kv_start==0 fast path (identical HLO to the original
        # engine; filler rows b..pad_b are discarded anyway)
        pad_lens = jnp.asarray(pad_h) if pad_h[:b].any() else None
        cache = self.model.init_cache(pad_b, self.max_seq)
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache, pad_lens)
        self.step_count += 1

        active = np.ones(pad_b, bool)
        active[b:] = False
        max_new = max(r.max_new_tokens for r in reqs)
        # per-request temperature, moved to device once (not per step)
        temps_h = np.zeros(pad_b, np.float32)
        temps_h[:b] = [r.temperature for r in reqs]
        temps = jnp.asarray(temps_h) if (temps_h > 0.0).any() else None
        next_tok = self._sample(logits, temps)
        for step in range(max_new):
            np_tok = np.asarray(next_tok)
            for j, r in enumerate(reqs):
                if active[j] and not r.done:
                    t = int(np_tok[j])
                    r.out_tokens.append(t)
                    if r.first_token_step is None:
                        r.first_token_step = self.step_count
                        r.first_token_ts = time.perf_counter()
                    if (self.eos_id is not None and t == self.eos_id) or \
                            len(r.out_tokens) >= r.max_new_tokens:
                        r.finish(FinishReason.COMPLETED, self.step_count)
                        active[j] = False
            if not active[:b].any():
                break
            if max_prompt + step >= self.max_seq:
                break             # cache full: truncate instead of letting
                #                   clamped writes silently corrupt row T-1
            logits, cache = self._decode(
                self.params, jnp.asarray(np_tok)[:, None], cache, pad_lens)
            self.step_count += 1
            next_tok = self._sample(logits, temps)
        for r in reqs:
            # anything still unfinished was truncated at max_seq: stamp
            r.finish(FinishReason.TRUNCATED, self.step_count)
            _observe_request(self.obs, r)


def greedy_generate(model, params, prompt_tokens, n_new: int,
                    qc: QuantConfig = DENSE, max_seq: int = 256):
    """Convenience one-shot greedy generation (tests/examples).

    Returns the list of ``n_new`` generated token ids."""
    eng = Engine(model, params, qc, batch_size=1, max_seq=max_seq)
    req = Request(tokens=list(prompt_tokens), max_new_tokens=n_new)
    eng.run([req])
    return req.out_tokens
