"""Batched serving engine.

Continuous-batching-lite: requests are grouped into fixed-size batches,
prefilled together (right-padded), then decoded step-by-step with per-slot
completion tracking. Works with sharded params/caches (pass `shardings`).
Sampling: greedy or temperature.

The paper's technique enters through ``qc``: with ``mode="lut_infer"`` the
engine runs assignment + LUT lookups instead of dense GEMMs (precomputed
tables must already be in params — see ``repro.core.precompute_model``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import DENSE, QuantConfig


@dataclasses.dataclass
class Request:
    tokens: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, qc: QuantConfig = DENSE,
                 batch_size: int = 8, max_seq: int = 512,
                 eos_id: Optional[int] = None, seed: int = 0):
        self.model = model
        self.params = params
        self.qc = qc
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)

        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, qc))
        self._decode = jax.jit(
            lambda p, t, c: model.decode(p, t, c, qc),
            donate_argnums=(2,))

    def _sample(self, logits: jax.Array,
                temps: Optional[jax.Array]) -> jax.Array:
        """Per-slot sampling: greedy where temperature <= 0, categorical
        (logits / T) elsewhere. temps: (B,) fp32 device array, or None
        when the whole batch is greedy."""
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if temps is None:
            return greedy
        self.key, sub = jax.random.split(self.key)
        scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
        sampled = jax.random.categorical(sub, scaled, axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0.0, sampled, greedy)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests (in batches of `batch_size`)."""
        for i in range(0, len(requests), self.batch_size):
            self._run_batch(requests[i:i + self.batch_size])
        return requests

    def _run_batch(self, reqs: List[Request]) -> None:
        b = len(reqs)
        pad_b = self.batch_size
        max_prompt = max(len(r.tokens) for r in reqs)
        toks = np.zeros((pad_b, max_prompt), np.int32)
        for j, r in enumerate(reqs):
            # left-pad? right-align prompts so decode starts uniformly
            toks[j, max_prompt - len(r.tokens):] = r.tokens
        cache = self.model.init_cache(pad_b, self.max_seq)
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache)

        active = np.ones(pad_b, bool)
        active[b:] = False
        max_new = max(r.max_new_tokens for r in reqs)
        # per-request temperature (padding slots decode greedily — discarded);
        # moved to device once, not per decode step
        temps_h = np.zeros(pad_b, np.float32)
        temps_h[:b] = [r.temperature for r in reqs]
        temps = jnp.asarray(temps_h) if (temps_h > 0.0).any() else None
        next_tok = self._sample(logits, temps)
        for step in range(max_new):
            np_tok = np.asarray(next_tok)
            for j, r in enumerate(reqs):
                if active[j] and not r.done:
                    t = int(np_tok[j])
                    r.out_tokens.append(t)
                    if (self.eos_id is not None and t == self.eos_id) or \
                            len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
                        active[j] = False
            if not active[:b].any():
                break
            logits, cache = self._decode(
                self.params, jnp.asarray(np_tok)[:, None], cache)
            next_tok = self._sample(logits, temps)
        for r in reqs:
            r.done = True


def greedy_generate(model, params, prompt_tokens, n_new: int,
                    qc: QuantConfig = DENSE, max_seq: int = 256):
    """Convenience one-shot generation (tests/examples)."""
    eng = Engine(model, params, qc, batch_size=1, max_seq=max_seq)
    req = Request(tokens=list(prompt_tokens), max_new_tokens=n_new)
    eng.run([req])
    return req.out_tokens
