"""Deterministic fault injection for the serving stack.

Chaos testing a serving engine is only useful when the chaos replays
exactly: a flaky repro is worse than no repro. Everything here is
clock-driven — a :class:`FaultSchedule` names faults at absolute step
numbers, and the :class:`FaultInjector` advances its clock once per
``step()`` of whatever it is attached to (a single
:class:`~repro.serve.engine.Engine` or a whole
:class:`~repro.serve.router.ReplicaRouter`). No wall time, no RNG at
injection time; the optional :meth:`FaultSchedule.random` generator is
seeded, so "random" chaos is a pure function of ``(seed, params)``.

Fault kinds (see docs/robustness.md for the cookbook):

==============  ========================================================
kind            effect while active (``[step, step + duration)``)
==============  ========================================================
``crash``       the replica's ``step()`` raises
                :class:`ReplicaCrashed` — permanently (duration is
                ignored). The router watchdog marks the replica dead and
                requeues its in-flight requests.
``step_error``  the first decode/verify device call at or after ``step``
                raises, exactly once — injected BEFORE the jitted call
                runs, so slot and page state stay consistent and the
                next step retries the same decode bit-identically.
``slow``        ``step()`` returns without doing any work (the replica
                is alive but stalled). Long windows trip the router's
                stall watchdog.
``pool_exhaust``  every free page of the replica's pool (including
                reclaimable parked prefix pages) is held by the injector
                for the window, forcing transient
                :class:`PagePoolExhausted` pressure: decode growth
                preempts, admission waits, degradation modes engage.
``submit_error``  the replica's ``submit()`` raises
                :class:`PagePoolExhausted` during the window — exercises
                the router's fall-through to the next-best replica.
==============  ========================================================

Usage::

    sched = FaultSchedule([Fault(step=12, kind="crash", replica=1),
                           Fault(step=4, kind="pool_exhaust", replica=0,
                                 duration=6)])
    inj = FaultInjector(sched)
    inj.attach(router)          # or inj.attach(engine)
    router.run(requests)        # faults fire at the scheduled steps
    print(inj.report())

Attach wraps ``step``/``submit``/jitted-decode entry points in place on
the given objects; it is one-shot per injector (make a fresh injector
per run — the clock is not reusable).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .kv_cache import PagePoolExhausted

__all__ = ["ReplicaCrashed", "Fault", "FaultSchedule", "FaultInjector",
           "FAULT_KINDS"]


class ReplicaCrashed(RuntimeError):
    """A replica process died mid-step (simulated). Unlike an ordinary
    step exception — which merely degrades the replica — the router
    watchdog treats this as immediately fatal: the replica is marked
    dead and its in-flight requests are requeued elsewhere."""


FAULT_KINDS = ("crash", "step_error", "slow", "pool_exhaust",
               "submit_error")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    step: injector-clock step the fault activates at (the clock ticks
      once per attached ``step()`` call, starting at 1).
    kind: one of :data:`FAULT_KINDS`.
    replica: index into ``router.engines`` (0 for a standalone engine).
    duration: steps the fault stays active; ignored for ``crash``
      (permanent) and ``step_error`` (armed from ``step``, fires once).
    """
    step: int
    kind: str
    replica: int = 0
    duration: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; pick from "
                f"{FAULT_KINDS}")
        if self.step < 1:
            raise ValueError(f"fault step must be >= 1, got {self.step}")
        if self.duration < 1:
            raise ValueError(
                f"fault duration must be >= 1, got {self.duration}")

    def active(self, clock: int) -> bool:
        # crash is permanent; step_error is armed from `step` onward and
        # consumed by its first firing (FaultInjector tracks the shot)
        if self.kind in ("crash", "step_error"):
            return clock >= self.step
        return self.step <= clock < self.step + self.duration


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, replayable list of faults."""
    faults: Tuple[Fault, ...]

    def __init__(self, faults):
        object.__setattr__(self, "faults", tuple(faults))

    def for_replica(self, i: int) -> List[Fault]:
        return [f for f in self.faults if f.replica == i]

    @property
    def max_replica(self) -> int:
        return max((f.replica for f in self.faults), default=0)

    @classmethod
    def canned(cls, replicas: int = 2) -> "FaultSchedule":
        """The standing chaos scenario used by tests, ``serve_bench
        --chaos`` and ``serve_demo --chaos``: an early pool squeeze and a
        one-shot decode failure on replica 0, then a hard crash of the
        last replica mid-decode, plus a short slow window. Deterministic
        by construction — no seed involved."""
        victim = replicas - 1
        faults = [
            Fault(step=5, kind="pool_exhaust", replica=0, duration=4),
            Fault(step=7, kind="step_error", replica=0, duration=2),
            Fault(step=10, kind="slow", replica=victim, duration=2),
            Fault(step=14, kind="crash", replica=victim),
        ]
        return cls([f for f in faults if f.replica < replicas])

    @classmethod
    def random(cls, seed: int, *, steps: int = 64, replicas: int = 2,
               n_faults: int = 6, crash_at_most: int = 1,
               kinds: Tuple[str, ...] = FAULT_KINDS) -> "FaultSchedule":
        """A seeded pseudo-random schedule — same ``(seed, params)``,
        same faults, forever. ``crash_at_most`` bounds permanent crashes
        so a fuzzed schedule cannot kill every replica."""
        import numpy as np
        rng = np.random.default_rng(seed)
        faults, crashes = [], 0
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            if kind == "crash":
                if crashes >= crash_at_most:
                    kind = "step_error"
                else:
                    crashes += 1
            faults.append(Fault(
                step=int(rng.integers(1, max(2, steps))),
                kind=kind,
                replica=int(rng.integers(replicas)),
                duration=int(rng.integers(1, 6))))
        return cls(faults)


class FaultInjector:
    """Wraps ``step``/``submit`` entry points to fire a
    :class:`FaultSchedule` deterministically.

    The clock ticks at the top of each attached ``step()`` call (router
    steps tick once for ALL replicas — the schedule is phrased in router
    steps, matching how the watchdog counts). ``fired`` logs every
    injection as ``(clock, fault, note)`` for reports and debugging.
    """

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self.clock = 0
        self.fired: List[Tuple[int, Fault, str]] = []
        self._attached = False
        self._held_pages: Dict[int, List[int]] = {}
        self._shot: Set[int] = set()    # one-shot fault ids already fired
        self._engines: List[object] = []

    # ------------------------------------------------------------------
    def attach(self, target) -> "FaultInjector":
        """Instrument ``target`` (an Engine or a ReplicaRouter) in place.
        Returns ``self`` for chaining."""
        if self._attached:
            raise RuntimeError("FaultInjector.attach is one-shot; build "
                               "a fresh injector per run")
        self._attached = True
        engines = getattr(target, "engines", None)
        if engines is None:            # standalone engine
            self._engines = [target]
            self._wrap_engine(target, 0, tick=True)
        else:
            self._engines = list(engines)
            if self.schedule.max_replica >= len(self._engines):
                raise ValueError(
                    f"schedule names replica {self.schedule.max_replica} "
                    f"but the router only has {len(self._engines)}")
            orig_step = target.step

            def routed_step():
                self._tick()
                return orig_step()
            target.step = routed_step
            for i, eng in enumerate(self._engines):
                self._wrap_engine(eng, i, tick=False)
        return self

    def _wrap_engine(self, eng, i: int, tick: bool) -> None:
        orig_step, orig_submit = eng.step, eng.submit

        def step():
            if tick:
                self._tick()
            f = self._find(i, "crash")
            if f is not None:
                self._log(f, "step raised ReplicaCrashed")
                raise ReplicaCrashed(
                    f"replica {i} crashed (injected at step {f.step})")
            f = self._find(i, "slow")
            if f is not None:
                self._log(f, "step skipped (slow)")
                return True            # alive, but no work done
            return orig_step()
        eng.step = step

        def submit(req):
            f = self._find(i, "submit_error")
            if f is not None:
                self._log(f, "submit raised PagePoolExhausted")
                raise PagePoolExhausted(
                    f"injected: replica {i} refused admission "
                    f"(fault at step {f.step})")
            return orig_submit(req)
        eng.submit = submit

        # step_error: fail the next jitted decode/verify call inside the
        # window — BEFORE the device call, so no state is touched and the
        # retry replays the identical computation.
        for attr in ("_jit_decode", "_jit_verify"):
            fn = getattr(eng, attr, None)
            if fn is None:
                continue

            def guarded(*a, _fn=fn, _i=i, **kw):
                f = self._find(_i, "step_error", one_shot=True)
                if f is not None:
                    self._log(f, "injected decode failure")
                    raise RuntimeError(
                        f"injected decode failure on replica {_i} "
                        f"(fault at step {f.step})")
                return _fn(*a, **kw)
            setattr(eng, attr, guarded)

    # ------------------------------------------------------------------
    def _tick(self) -> None:
        self.clock += 1
        for idx, f in enumerate(self.schedule.faults):
            if f.kind != "pool_exhaust":
                continue
            i = f.replica
            if self.clock == f.step and i < len(self._engines):
                self._squeeze(i, f)
            if self.clock == f.step + f.duration and i in self._held_pages:
                self._release(i)

    def _find(self, i: int, kind: str,
              one_shot: bool = False) -> Optional[Fault]:
        for idx, f in enumerate(self.schedule.faults):
            if f.replica != i or f.kind != kind:
                continue
            if one_shot and idx in self._shot:
                continue
            if f.active(self.clock):
                if one_shot:
                    self._shot.add(idx)
                return f
        return None

    def _log(self, fault: Fault, note: str) -> None:
        self.fired.append((self.clock, fault, note))
        # surface the injection on the victim replica's trace track so
        # chaos traces show *why* a span stalled or a request migrated
        if fault.replica < len(self._engines):
            obs = getattr(self._engines[fault.replica], "obs", None)
            if obs is not None:
                obs.annotate(f"fault.{fault.kind}", step=fault.step,
                             clock=self.clock, note=note)

    def _squeeze(self, i: int, fault: Fault) -> None:
        """Grab every free page of replica ``i``'s pool (reclaiming the
        parked prefix LRU first — those count as capacity) so the engine
        sees genuine transient exhaustion."""
        kv = self._engines[i].kv
        if not getattr(kv, "paged", False):
            return
        table = kv.table
        if table.prefix is not None:
            while table.prefix.reclaimable:
                # deliberate raw-allocator use: fault injection reclaims
                # parked refcount-0 prefix pages exactly like the real
                # eviction path does (not a leaked decref)
                table.allocator.restore(  # analysis: ok(allocator-free)
                    table.prefix.pop_lru())
        held = table.allocator.alloc(table.allocator.available)
        self._held_pages[i] = held
        self._log(fault, f"holding {len(held)} page(s)")

    def _release(self, i: int) -> None:
        held = self._held_pages.pop(i)
        self._engines[i].kv.table.allocator.free(held)
        self.fired.append(
            (self.clock, Fault(step=self.clock, kind="pool_exhaust",
                               replica=i),
             f"released {len(held)} page(s)"))

    # ------------------------------------------------------------------
    def report(self) -> Dict[str, object]:
        """Counts per fault kind actually fired, plus the raw log."""
        counts: Dict[str, int] = {}
        for _, f, note in self.fired:
            counts[f.kind] = counts.get(f.kind, 0) + 1
        return {"clock": self.clock, "by_kind": counts,
                "events": [(c, f.kind, f.replica, note)
                           for c, f, note in self.fired]}
