"""Block/paged KV cache for the continuous-batching engine.

The serving memory system is split into three layers (see docs/serving.md):

  * :class:`PageAllocator` — a host-side free-list over physical page ids.
    Pure Python, no device state; raises :class:`PagePoolExhausted` when a
    request cannot be satisfied.
  * :class:`PageTable`   — host-side slot→page bookkeeping: one row of
    logical-page → physical-page ids per slot (``-1`` = unallocated), grown
    lazily as a slot's sequence crosses page boundaries.
  * :class:`PagedKVCache` — the device arrays (built by
    ``Model.init_paged_cache``) plus a :class:`PageTable`. KV for the
    attention families lives in a shared physical pool of fixed-size pages,
    so HBM scales with *live tokens* across all slots instead of
    ``num_slots × max_seq``. Mamba2 states are O(1) per slot and are
    stored slot-indexed (no paging); they are recycled when a slot is
    evicted (the first prefill chunk of the next occupant resets them).

One extra physical page (the last one, never handed out by the allocator)
serves as a *trash page*: scatter targets for padded prefill positions and
for inactive decode slots are redirected there, so no masking is needed on
the write path.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class PagePoolExhausted(RuntimeError):
    """Raised when a page allocation cannot be satisfied.

    Carries a human-readable account of the pool state so serving errors
    surface as capacity problems, not shape errors deep inside jit.
    """


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical page ids.

    Pages are plain ints in ``[0, num_pages)``. ``alloc`` is all-or-nothing:
    it either returns exactly ``n`` page ids or raises
    :class:`PagePoolExhausted` without allocating anything.
    """

    def __init__(self, num_pages: int):
        if num_pages <= 0:
            raise ValueError(f"num_pages must be positive, got {num_pages}")
        self.num_pages = num_pages
        # pop() from the tail → pages are handed out in ascending id order.
        self._free: List[int] = list(range(num_pages - 1, -1, -1))

    @property
    def available(self) -> int:
        """Number of pages currently free."""
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` pages; raises PagePoolExhausted if short."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"requested {n} page(s) but only {self.available} of "
                f"{self.num_pages} are free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        """Return pages to the pool (idempotence is NOT checked)."""
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"freeing invalid page id {p}")
        self._free.extend(pages)


class PageTable:
    """Host-side slot → physical-page mapping.

    Row ``s`` maps slot ``s``'s logical pages (token positions
    ``[i*page_size, (i+1)*page_size)``) to physical page ids; ``-1`` marks
    an unallocated logical page. The device copy is cached and invalidated
    on every mutation (allocation happens a few times per request, not per
    token, so the host→device transfers are rare and tiny).
    """

    def __init__(self, num_slots: int, max_seq: int, page_size: int,
                 num_pages: Optional[int] = None):
        if max_seq % page_size:
            raise ValueError(
                f"max_seq ({max_seq}) must be a multiple of page_size "
                f"({page_size})")
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = max_seq // page_size
        if num_pages is None:
            num_pages = num_slots * self.pages_per_slot
        self.allocator = PageAllocator(num_pages)
        self.table = np.full((num_slots, self.pages_per_slot), -1, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        self._dev: Optional[jnp.ndarray] = None

    # -- capacity queries ---------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens."""
        return max(1, math.ceil(n_tokens / self.page_size))

    def can_fit(self, n_tokens: int) -> bool:
        """Whether ``n_tokens`` *new* tokens' pages could be allocated now."""
        return self.pages_for(n_tokens) <= self.allocator.available

    def check_admissible(self, n_tokens: int) -> None:
        """Raise if a request of ``n_tokens`` could NEVER be served.

        Catches both per-slot overflow (prompt longer than ``max_seq``) and
        pool overflow (prompt needs more pages than exist), so impossible
        requests fail loudly instead of deadlocking the admission queue.
        """
        if n_tokens > self.max_seq:
            raise PagePoolExhausted(
                f"request of {n_tokens} tokens exceeds max_seq="
                f"{self.max_seq} (pages_per_slot={self.pages_per_slot})")
        if self.pages_for(n_tokens) > self.allocator.num_pages:
            raise PagePoolExhausted(
                f"request of {n_tokens} tokens needs "
                f"{self.pages_for(n_tokens)} pages but the pool only has "
                f"{self.allocator.num_pages}")

    # -- mutation -----------------------------------------------------------
    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot ``slot`` to cover token positions ``[0, n_tokens)``.

        Allocates the missing logical pages (all-or-nothing); raises
        :class:`PagePoolExhausted` when the pool cannot supply them — the
        caller decides whether to wait, or preempt a slot.
        """
        need = self.pages_for(n_tokens)
        if need > self.pages_per_slot:
            raise PagePoolExhausted(
                f"slot {slot}: {n_tokens} tokens exceed max_seq="
                f"{self.max_seq}")
        have = len(self._slot_pages[slot])
        if need <= have:
            return
        new = self.allocator.alloc(need - have)
        for i, p in enumerate(new):
            self.table[slot, have + i] = p
        self._slot_pages[slot].extend(new)
        self._dev = None

    def release(self, slot: int) -> None:
        """Evict a slot: return its pages to the pool, clear its row."""
        if self._slot_pages[slot]:
            self.allocator.free(self._slot_pages[slot])
            self._slot_pages[slot] = []
            self.table[slot, :] = -1
            self._dev = None

    # -- device view --------------------------------------------------------
    def device(self, sharding=None) -> jnp.ndarray:
        """(num_slots, pages_per_slot) int32 device copy (cached).

        ``sharding``: optional placement for the copy — the sharded serving
        engine passes a replicated ``NamedSharding`` so the table lands on
        every mesh device without a resharding step inside jit."""
        if self._dev is None:
            self._dev = (jnp.asarray(self.table) if sharding is None
                         else jax.device_put(self.table, sharding))
        return self._dev

    @property
    def live_pages(self) -> int:
        return self.allocator.in_use


class PagedKVCache:
    """Device cache arrays + page table for one serving engine instance.

    ``data`` is the pytree returned by ``Model.init_paged_cache``:

      * attention families: ``{"k": (L, P+1, page, KVH, HD), "v": ...}``
        where ``P`` is the physical pool size and the final page is the
        trash page (see module docstring).
      * ssm: ``{"conv": (L, slots, K-1, C), "h": (L, slots, H, HP, N)}`` —
        slot-indexed recurrent state, recycled on eviction.
      * hybrid: ``{"mamba": {...}, "attn": {"k": (n_inv, slots, T, KVH,
        HD), ...}}`` — the handful of shared-attention invocations keep a
        slot-dense cache (documented trade-off in docs/serving.md).

    The engine passes ``data`` and ``table.device()`` into jitted
    prefill/decode functions and stores the updated ``data`` back.
    """

    def __init__(self, model, num_slots: int, max_seq: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=None):
        from repro.models.model import ATTN_FAMILIES
        self.cfg = model.cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.paged = model.cfg.family in ATTN_FAMILIES
        self.table = PageTable(num_slots, max_seq, page_size, num_pages)
        self.data: Dict[str, Any] = model.init_paged_cache(
            num_slots, max_seq, page_size,
            num_pages=self.table.allocator.num_pages, dtype=dtype)

    # Paging only applies to the attention families; ssm/hybrid slots hold
    # constant-size state, so capacity checks are trivially true there.
    def pages_for(self, n: int) -> int:
        return self.table.pages_for(n) if self.paged else 0

    def can_fit(self, n_tokens: int) -> bool:
        return self.table.can_fit(n_tokens) if self.paged else True

    def check_admissible(self, n_tokens: int) -> None:
        if n_tokens > self.max_seq:
            raise PagePoolExhausted(
                f"request of {n_tokens} tokens exceeds max_seq="
                f"{self.max_seq}")
        if self.paged:
            self.table.check_admissible(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> None:
        if self.paged:
            self.table.ensure(slot, n_tokens)

    def release(self, slot: int) -> None:
        if self.paged:
            self.table.release(slot)

    def table_device(self, sharding=None) -> jnp.ndarray:
        return self.table.device(sharding)

    @property
    def live_pages(self) -> int:
        return self.table.live_pages if self.paged else 0
