"""Block/paged KV cache for the continuous-batching engine.

The serving memory system is split into three layers (see docs/serving.md):

  * :class:`PageAllocator` — a host-side free-list over physical page ids.
    Pure Python, no device state; raises :class:`PagePoolExhausted` when a
    request cannot be satisfied.
  * :class:`PageTable`   — host-side slot→page bookkeeping: one row of
    logical-page → physical-page ids per slot (``-1`` = unallocated), grown
    lazily as a slot's sequence crosses page boundaries.
  * :class:`PagedKVCache` — the device arrays (built by
    ``Model.init_paged_cache``) plus a :class:`PageTable`. KV for the
    attention families lives in a shared physical pool of fixed-size pages,
    so HBM scales with *live tokens* across all slots instead of
    ``num_slots × max_seq``. Mamba2 states are O(1) per slot and are
    stored slot-indexed (no paging); they are recycled when a slot is
    evicted (the first prefill chunk of the next occupant resets them).

One extra physical page (the last one, never handed out by the allocator)
serves as a *trash page*: scatter targets for padded prefill positions and
for inactive decode slots are redirected there, so no masking is needed on
the write path.

Automatic prefix caching (docs/serving.md §Prefix caching) rides on top:
pages carry reference counts, every *fully written* prompt page is
indexed by a chained content hash of the token ids it covers (a hash trie
at page granularity), and a newly admitted request whose prompt shares a
page-aligned prefix maps the matching pages into its page table
read-shared instead of recomputing their KV. Unreferenced-but-indexed
pages are parked in an LRU (:class:`PrefixCache`) and reclaimed lazily —
eviction decrefs, it no longer frees.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagePoolExhausted(RuntimeError):
    """Raised when a page allocation cannot be satisfied.

    Carries a human-readable account of the pool state so serving errors
    surface as capacity problems, not shape errors deep inside jit.
    """


class PageAllocator:
    """Ref-counted free-list allocator over ``num_pages`` physical page ids.

    Pages are plain ints in ``[0, num_pages)``. ``alloc`` is all-or-nothing:
    it either returns exactly ``n`` page ids (each with refcount 1) or
    raises :class:`PagePoolExhausted` without allocating anything.

    Reference counting supports shared-prefix page reuse: a page mapped
    into several page-table rows holds one reference per row.  ``free``
    is a decref — the page returns to the free list only when the last
    reference drops, and dropping a reference a page does not hold is a
    hard error (double-free), never a silent corruption.

    A page can also be *checked out* with refcount 0: the prefix cache
    parks unreferenced-but-still-indexed pages outside the free list
    (their KV content stays valid for future prefix hits) and hands them
    back via :meth:`restore` when reclaimed, or re-activates them via
    :meth:`revive` on a prefix hit.
    """

    def __init__(self, num_pages: int):
        if num_pages <= 0:
            raise ValueError(f"num_pages must be positive, got {num_pages}")
        self.num_pages = num_pages
        # pop() from the tail → pages are handed out in ascending id order.
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: List[int] = [0] * num_pages

    @property
    def available(self) -> int:
        """Number of pages currently free."""
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def _check(self, p: int) -> None:
        if not (0 <= p < self.num_pages):
            raise ValueError(f"invalid page id {p}")

    def refcount(self, p: int) -> int:
        self._check(p)
        return self._ref[p]

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` pages (refcount 1 each); raises if short."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"requested {n} page(s) but only {self.available} of "
                f"{self.num_pages} are free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] = 1
        return out

    def incref(self, p: int) -> int:
        """Add a reference to a live page (refcount must be >= 1)."""
        self._check(p)
        if self._ref[p] <= 0:
            raise ValueError(
                f"incref on page {p} with refcount {self._ref[p]} "
                f"(revive() is the path for parked cached pages)")
        self._ref[p] += 1
        return self._ref[p]

    def decref(self, p: int) -> int:
        """Drop one reference; returns the new count. Does NOT free —
        the caller decides between the free list and the prefix-cache LRU
        when the count reaches zero. Refcount 0 pages raise (double-free).
        """
        self._check(p)
        if self._ref[p] <= 0:
            raise ValueError(
                f"double-free: page {p} has refcount {self._ref[p]}")
        self._ref[p] -= 1
        return self._ref[p]

    def free(self, pages: List[int]) -> None:
        """Decref each page; a page whose last reference drops returns to
        the free list (exactly once — a second free raises)."""
        for p in pages:
            if self.decref(p) == 0:
                self._free.append(p)

    def revive(self, p: int) -> None:
        """Re-activate a parked refcount-0 page (prefix-cache hit): the
        page is NOT on the free list; it simply gains its first
        reference again."""
        self._check(p)
        if self._ref[p] != 0:
            raise ValueError(
                f"revive on page {p} with refcount {self._ref[p]}")
        self._ref[p] = 1

    def restore(self, p: int) -> None:
        """Return a parked refcount-0 page to the free list (the prefix
        cache reclaimed it — its cached content is dropped)."""
        self._check(p)
        if self._ref[p] != 0:
            raise ValueError(
                f"restore on page {p} with refcount {self._ref[p]}")
        self._free.append(p)


def _chunk_keys(tokens, page_size: int,
                salt: int = 0) -> List[Tuple[int, tuple]]:
    """Chained content keys of ``tokens`` at page granularity.

    Key ``i`` is ``(hash(key_{i-1}), chunk_i_token_tuple)`` and covers
    tokens ``[0, (i+1)*page_size)`` — the chain makes a page identify its
    *entire prefix* (KV at position p depends on every token <= p). The
    current chunk's actual token ids sit in the key, so a lookup compares
    the page's own tokens exactly; ancestry, however, is carried by the
    chained 64-bit parent hash, so a cross-prefix false match still needs
    a ``hash()`` collision between two *parent* chains (~2^-64 per pair —
    negligible by accident, though not cryptographically hard). Only full
    pages are keyed; the tail remainder is ignored.

    ``salt`` seeds the chain. A vector-quantized pool stores codebook
    INDICES, which are only comparable under the codebook that produced
    them — seeding with the codebook fingerprint makes pages written
    under different codebooks (or a quantized vs an fp pool) live in
    disjoint key spaces, so they can never alias.
    """
    out: List[Tuple[int, tuple]] = []
    h = salt
    for i in range(len(tokens) // page_size):
        key = (h, tuple(tokens[i * page_size:(i + 1) * page_size]))
        out.append(key)
        h = hash(key)
    return out


@dataclasses.dataclass
class PrefixMatch:
    """Reuse plan for one prompt against the prefix index.

    ``pages`` are mapped read-shared into the new slot's table; ``tokens``
    prompt tokens skip prefill. ``cow_page`` is set when the whole prompt
    is covered by indexed pages: the final prompt token must still run
    prefill (its logits seed decode) and its KV write would land inside
    the last shared page — that page is copy-on-write forked instead.
    """
    tokens: int = 0
    pages: List[int] = dataclasses.field(default_factory=list)
    cow_page: Optional[int] = None

    @property
    def reused_pages(self) -> int:
        return len(self.pages)


class PrefixCache:
    """Content-keyed index over full KV pages + LRU of unreferenced pages.

    ``(parent_hash, chunk_tokens) -> page`` lookups drive prefix
    matching (exact tuple comparison — see :func:`_chunk_keys`); the LRU
    keeps pages whose refcount dropped to zero ("recently freed") out of
    the free list so their content can still be shared, and surrenders
    the oldest ones when the allocator runs dry.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._page_of: Dict[Tuple[int, tuple], int] = {}   # key -> page id
        self._key_of: Dict[int, Tuple[int, tuple]] = {}    # page id -> key
        self._lru: "OrderedDict[int, None]" = OrderedDict()

    def lookup(self, key: Tuple[int, tuple]) -> Optional[int]:
        return self._page_of.get(key)

    def is_registered(self, page: int) -> bool:
        return page in self._key_of

    def register(self, key: Tuple[int, tuple], page: int) -> None:
        if key in self._page_of or page in self._key_of:
            raise ValueError(f"page {page} / key already registered")
        self._page_of[key] = page
        self._key_of[page] = key

    def unregister(self, page: int) -> None:
        """Drop a page's index entry (and LRU membership, if parked)."""
        key = self._key_of.pop(page, None)
        if key is not None:
            self._page_of.pop(key, None)
        self._lru.pop(page, None)

    def park(self, page: int) -> None:
        """An indexed page lost its last reference: keep it (LRU)."""
        self._lru[page] = None
        self._lru.move_to_end(page)

    def unpark(self, page: int) -> None:
        """An indexed parked page regained a reference."""
        self._lru.pop(page, None)

    def pop_lru(self) -> int:
        """Reclaim the least-recently-parked page (drops its index entry)."""
        page = next(iter(self._lru))
        self.unregister(page)
        return page

    @property
    def reclaimable(self) -> int:
        """Parked pages the allocator may reclaim under pressure."""
        return len(self._lru)


class PageTable:
    """Host-side slot → physical-page mapping.

    Row ``s`` maps slot ``s``'s logical pages (token positions
    ``[i*page_size, (i+1)*page_size)``) to physical page ids; ``-1`` marks
    an unallocated logical page. The device copy is cached and invalidated
    on every mutation (allocation happens a few times per request, not per
    token, so the host→device transfers are rare and tiny).
    """

    def __init__(self, num_slots: int, max_seq: int, page_size: int,
                 num_pages: Optional[int] = None,
                 prefix_cache: bool = True, content_salt: int = 0):
        if max_seq % page_size:
            raise ValueError(
                f"max_seq ({max_seq}) must be a multiple of page_size "
                f"({page_size})")
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = max_seq // page_size
        if num_pages is None:
            num_pages = num_slots * self.pages_per_slot
        self.allocator = PageAllocator(num_pages)
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(page_size) if prefix_cache else None)
        self.table = np.full((num_slots, self.pages_per_slot), -1, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        # seed of the content-hash chain (see _chunk_keys): pages written
        # under different pool encodings must never alias
        self.content_salt = content_salt
        # bytes per physical page across k+v and all layers — set by
        # PagedKVCache from the actual device arrays; 0 = unknown (bare
        # PageTable use in tests)
        self.page_bytes = 0
        # per-slot registration cursor: (full pages hashed, chain hash) —
        # lets register_prefix resume mid-prompt instead of rehashing the
        # whole prefix on every prefill chunk
        self._reg_state: List[Tuple[int, int]] = [
            (0, content_salt)] * num_slots
        self._dev: Optional[jnp.ndarray] = None

    # -- capacity queries ---------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens."""
        return max(1, math.ceil(n_tokens / self.page_size))

    @property
    def available_pages(self) -> int:
        """Free pages plus parked cached pages (reclaimable on demand)."""
        extra = self.prefix.reclaimable if self.prefix is not None else 0
        return self.allocator.available + extra

    @property
    def pressure(self) -> float:
        """Pool pressure in ``[0, 1]``: the fraction of physical pages
        that could NOT be handed to a new allocation right now (live
        slot-referenced pages; parked prefix pages are reclaimable on
        demand and count as capacity). 1.0 = the pool cannot grow any
        sequence without preempting. The engine's degradation ladder
        (docs/robustness.md) steps on this signal."""
        return 1.0 - self.available_pages / self.allocator.num_pages

    def occupancy(self) -> str:
        """One-line pool accounting for capacity-error messages and
        preemption logs: live (slot-referenced), cached-parked (prefix
        LRU, reclaimable), and free pages — with the byte sizes behind
        them when :attr:`page_bytes` is known (pages of a quantized pool
        are 4-16x smaller than fp pages; page counts alone no longer
        describe HBM use)."""
        pages = (f"pool: {self.live_pages} live, "
                 f"{self.prefix.reclaimable if self.prefix else 0} "
                 f"cached-parked, {self.allocator.available} free of "
                 f"{self.allocator.num_pages} pages "
                 f"({self.page_size} tokens each)")
        if not self.page_bytes:
            return pages
        mib = self.page_bytes / (1 << 20)
        return (f"{pages}; {self.live_pages * mib:.2f} MiB live of "
                f"{self.allocator.num_pages * mib:.2f} MiB "
                f"({self.page_bytes} B/page)")

    def can_fit(self, n_tokens: int,
                match: Optional[PrefixMatch] = None) -> bool:
        """Whether ``n_tokens`` tokens' pages could be allocated now.

        With a ``match``, only the UNSHARED pages count against capacity
        — matched pages are mapped by reference — but matched pages that
        are currently parked stop being reclaimable once adopted, so they
        are deducted from the available side."""
        need = self.pages_for(n_tokens)
        avail = self.available_pages
        if match is not None and self.prefix is not None:
            need -= match.reused_pages
            parked = self.prefix._lru
            cand = match.pages + (
                [match.cow_page] if match.cow_page is not None else [])
            avail -= sum(1 for p in cand if p in parked)
        return need <= avail

    def check_admissible(self, n_tokens: int) -> None:
        """Raise if a request of ``n_tokens`` could NEVER be served.

        Catches both per-slot overflow (prompt longer than ``max_seq``) and
        pool overflow (prompt needs more pages than exist), so impossible
        requests fail loudly instead of deadlocking the admission queue.
        """
        if n_tokens > self.max_seq:
            raise PagePoolExhausted(
                f"request of {n_tokens} tokens exceeds max_seq="
                f"{self.max_seq} (pages_per_slot={self.pages_per_slot})")
        if self.pages_for(n_tokens) > self.allocator.num_pages:
            raise PagePoolExhausted(
                f"request of {n_tokens} tokens needs "
                f"{self.pages_for(n_tokens)} pages but the pool only has "
                f"{self.allocator.num_pages} ({self.occupancy()})")

    # -- mutation -----------------------------------------------------------
    def _alloc(self, n: int) -> List[int]:
        """Allocate ``n`` fresh pages, reclaiming parked cached pages
        (oldest first) when the free list runs short."""
        if self.prefix is not None:
            while (self.allocator.available < n
                   and self.prefix.reclaimable):
                self.allocator.restore(self.prefix.pop_lru())
        try:
            return self.allocator.alloc(n)
        except PagePoolExhausted as e:
            # re-raise with the pool accounting attached so capacity
            # failures are debuggable from the message alone
            raise PagePoolExhausted(f"{e} ({self.occupancy()})") from None

    def _retain(self, page: int) -> None:
        """Take a reference on a cached page: parked pages are revived
        out of the LRU, live pages are increfed."""
        if self.allocator.refcount(page) == 0:
            self.prefix.unpark(page)
            self.allocator.revive(page)
        else:
            self.allocator.incref(page)

    def _release_page(self, page: int) -> None:
        """Drop one reference; an unreferenced page is parked in the
        prefix LRU when indexed (content stays shareable) and returned
        to the free list otherwise."""
        if self.allocator.decref(page) == 0:
            if self.prefix is not None and self.prefix.is_registered(page):
                self.prefix.park(page)
            else:
                self.allocator.restore(page)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot ``slot`` to cover token positions ``[0, n_tokens)``.

        Allocates the missing logical pages (all-or-nothing); raises
        :class:`PagePoolExhausted` when the pool cannot supply them — the
        caller decides whether to wait, or preempt a slot.
        """
        need = self.pages_for(n_tokens)
        if need > self.pages_per_slot:
            raise PagePoolExhausted(
                f"slot {slot}: {n_tokens} tokens exceed max_seq="
                f"{self.max_seq} ({self.occupancy()})")
        have = len(self._slot_pages[slot])
        if need <= have:
            return
        new = self._alloc(need - have)
        for i, p in enumerate(new):
            self.table[slot, have + i] = p
        self._slot_pages[slot].extend(new)
        self._dev = None

    def release(self, slot: int) -> None:
        """Evict a slot: decref its pages, clear its row. Pages still
        referenced by another slot stay live; unreferenced indexed pages
        are parked for future prefix hits; the rest return to the pool."""
        if self._slot_pages[slot]:
            for p in self._slot_pages[slot]:
                self._release_page(p)
            self._slot_pages[slot] = []
            self.table[slot, :] = -1
            self._dev = None
        self._reg_state[slot] = (0, self.content_salt)

    def trim(self, slot: int, n_tokens: int) -> int:
        """Shrink a slot to the pages covering ``n_tokens`` tokens
        (speculative-decoding rollback: rejected draft rows beyond the
        accepted position may leave whole tail pages unused).

        Only pages wholly ABOVE the keep mark are dropped, each via
        :meth:`_release_page` — a page another slot still references
        merely loses this slot's reference, and an indexed page parks in
        the prefix LRU. In practice a draft tail page is always a fresh
        refcount-1 allocation: shared prefix pages sit below ``slot.pos``
        (prefill never rolls back), which the rollback property tests
        assert. Returns the number of pages dropped from the row."""
        keep = 0 if n_tokens <= 0 else self.pages_for(n_tokens)
        row = self._slot_pages[slot]
        if len(row) <= keep:
            return 0
        dropped = row[keep:]
        del row[keep:]
        for p in dropped:
            self._release_page(p)
        self.table[slot, keep:] = -1
        self._dev = None
        return len(dropped)

    # -- prefix caching -----------------------------------------------------
    def match_prefix(self, tokens) -> PrefixMatch:
        """Plan (read-only) the longest page-aligned prefix reuse for a
        prompt: consecutive indexed pages from position 0. The final
        prompt token always runs prefill — a full-prompt match converts
        its last page into a copy-on-write fork (see :class:`PrefixMatch`).
        """
        m = PrefixMatch()
        if self.prefix is None or len(tokens) <= 1:
            return m
        for key in _chunk_keys(tokens, self.page_size, self.content_salt):
            page = self.prefix.lookup(key)
            if page is None:
                break
            m.pages.append(page)
        m.tokens = len(m.pages) * self.page_size
        if m.pages and m.tokens >= len(tokens):
            m.cow_page = m.pages.pop()
            m.tokens = len(tokens) - 1
        return m

    def adopt_prefix(self, slot: int,
                     match: PrefixMatch) -> Optional[Tuple[int, int]]:
        """Map a :class:`PrefixMatch` into an empty slot row.

        Matched pages are increfed (revived out of the LRU when parked)
        and written into the row read-shared. A ``cow_page`` is forked:
        a fresh page is allocated in its place and ``(src, dst)`` is
        returned so the caller can copy the donor page's KV device-side;
        the donor keeps its index entry and loses only the transient
        reference. Raises :class:`PagePoolExhausted` (after rolling the
        row back) if the fork cannot be allocated."""
        if not match.pages and match.cow_page is None:
            return None
        assert not self._slot_pages[slot], \
            f"adopt_prefix on non-empty slot {slot}"
        row = self._slot_pages[slot]
        for p in match.pages:
            self._retain(p)
            self.table[slot, len(row)] = p
            row.append(p)
        pair = None
        if match.cow_page is not None:
            src = match.cow_page
            self._retain(src)        # pin: _alloc's reclaim must not take it
            try:
                dst = self._alloc(1)[0]
            except PagePoolExhausted:
                self._release_page(src)
                self.release(slot)   # roll back the shared mappings
                raise
            self.table[slot, len(row)] = dst
            row.append(dst)
            self._release_page(src)  # unpin (back to the LRU if unshared)
            pair = (src, dst)
        self._dev = None
        return pair

    def register_prefix(self, slot: int, tokens, n_covered: int) -> None:
        """Index the slot's fully written prompt pages by content key.

        ``n_covered`` tokens of ``tokens`` have complete KV (prefill
        progress); every full page below that mark becomes shareable.
        First writer wins: keys already indexed (including by this very
        slot's shared pages) are skipped. Incremental: the per-slot
        cursor resumes the hash chain where the previous chunk left it,
        so a whole prompt is hashed exactly once."""
        if self.prefix is None:
            return
        ps = self.page_size
        n_full = min(n_covered, len(tokens)) // ps
        done, h = self._reg_state[slot]
        if n_full <= done:
            return
        row = self._slot_pages[slot]
        for i in range(done, n_full):
            key = (h, tuple(tokens[i * ps:(i + 1) * ps]))
            h = hash(key)
            if self.prefix.lookup(key) is None \
                    and not self.prefix.is_registered(row[i]):
                self.prefix.register(key, row[i])
        self._reg_state[slot] = (n_full, h)

    # -- device view --------------------------------------------------------
    def device(self, sharding=None) -> jnp.ndarray:
        """(num_slots, pages_per_slot) int32 device copy (cached).

        ``sharding``: optional placement for the copy — the sharded serving
        engine passes a replicated ``NamedSharding`` so the table lands on
        every mesh device without a resharding step inside jit."""
        if self._dev is None:
            self._dev = (jnp.asarray(self.table) if sharding is None
                         else jax.device_put(self.table, sharding))
        return self._dev

    @property
    def live_pages(self) -> int:
        """Pages referenced by at least one slot. Parked cached pages
        (refcount 0, held only by the prefix LRU) are logically free
        capacity and are not counted."""
        parked = self.prefix.reclaimable if self.prefix is not None else 0
        return self.allocator.in_use - parked

    @property
    def cached_pages(self) -> int:
        """Pages currently indexed by the prefix cache (live + parked)."""
        return len(self.prefix._key_of) if self.prefix is not None else 0


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(data: Dict[str, jax.Array], src, dst) -> Dict[str, jax.Array]:
    """Copy one physical page's K/V rows (CoW fork). ``src``/``dst`` are
    traced scalars, so every fork reuses one compiled executable.

    Callers must pass ONLY the page-pool leaves (``{"k", "v"}``) — the
    axis-1 copy is meaningless for anything else (a quantized cache's
    codebook tables, say), and would silently corrupt it."""
    return jax.tree_util.tree_map(
        lambda t: t.at[:, dst].set(t[:, src]), data)


class PagedKVCache:
    """Device cache arrays + page table for one serving engine instance.

    ``data`` is the pytree returned by ``Model.init_paged_cache``:

      * attention families: ``{"k": (L, P+1, page, KVH, HD), "v": ...}``
        where ``P`` is the physical pool size and the final page is the
        trash page (see module docstring).
      * ssm: ``{"conv": (L, slots, K-1, C), "h": (L, slots, H, HP, N)}`` —
        slot-indexed recurrent state, recycled on eviction.
      * hybrid: ``{"mamba": {...}, "attn": {"k": (n_inv, slots, T, KVH,
        HD), ...}}`` — the handful of shared-attention invocations keep a
        slot-dense cache (documented trade-off in docs/serving.md).

    The engine passes ``data`` and ``table.device()`` into jitted
    prefill/decode functions and stores the updated ``data`` back.
    """

    def __init__(self, model, num_slots: int, max_seq: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=None, prefix_cache: bool = True, codebook=None):
        from repro.models.model import ATTN_FAMILIES
        self.cfg = model.cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.paged = model.cfg.family in ATTN_FAMILIES
        self.codebook = codebook
        # Prefix reuse needs *paged* state: Mamba2 / hybrid recurrent
        # state is a single evolving tensor per slot — there is no
        # page-granular unit of it to share, so those families always
        # report a zero reusable prefix (match_prefix below).
        # A quantized pool salts the content-hash chain with the codebook
        # fingerprint: its pages hold codes, not rows, and codes from
        # different codebooks must never satisfy each other's lookups.
        salt = codebook.fingerprint() if codebook is not None else 0
        self.table = PageTable(num_slots, max_seq, page_size, num_pages,
                               prefix_cache=prefix_cache and self.paged,
                               content_salt=salt)
        self.data: Dict[str, Any] = model.init_paged_cache(
            num_slots, max_seq, page_size,
            num_pages=self.table.allocator.num_pages, dtype=dtype,
            codebook=codebook)
        self.cow_forks = 0
        # set by Engine: CoW forks annotate the owning replica's trace
        # track (docs/observability.md); None outside an engine
        self.obs = None
        if self.paged:
            self.table.page_bytes = self.page_bytes

    # -- byte accounting ----------------------------------------------------
    @property
    def bytes_per_token(self) -> int:
        """HBM bytes ONE cached token occupies across k+v and all layers
        — computed from the actual pool arrays, so it reflects the pool
        encoding (fp rows vs uint8 codes) and dtype automatically."""
        if not self.paged:
            return 0
        total = 0
        for key in ("k", "v"):
            t = self.data[key]          # (L, P+1, page, KVH, W)
            l, _, _, kvh, w = t.shape
            total += l * kvh * w * t.dtype.itemsize
        return total

    @property
    def page_bytes(self) -> int:
        """Bytes one physical page pins across k+v and all layers."""
        return self.bytes_per_token * self.page_size

    @property
    def pool_bytes(self) -> int:
        """Total allocatable pool capacity in bytes (trash page
        excluded — it is never handed out)."""
        return self.page_bytes * self.table.allocator.num_pages \
            if self.paged else 0

    @property
    def live_bytes(self) -> int:
        """Bytes pinned by slot-referenced pages right now."""
        return self.page_bytes * self.table.live_pages \
            if self.paged else 0

    # Paging only applies to the attention families; ssm/hybrid slots hold
    # constant-size state, so capacity checks are trivially true there.
    def pages_for(self, n: int) -> int:
        return self.table.pages_for(n) if self.paged else 0

    def can_fit(self, n_tokens: int,
                match: Optional[PrefixMatch] = None) -> bool:
        return self.table.can_fit(n_tokens, match) if self.paged else True

    def check_admissible(self, n_tokens: int) -> None:
        if n_tokens > self.max_seq:
            raise PagePoolExhausted(
                f"request of {n_tokens} tokens exceeds max_seq="
                f"{self.max_seq}")
        if self.paged:
            self.table.check_admissible(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> None:
        if self.paged:
            self.table.ensure(slot, n_tokens)

    def release(self, slot: int) -> None:
        if self.paged:
            self.table.release(slot)

    def trim(self, slot: int, n_tokens: int) -> int:
        """Speculative rollback: drop tail pages past ``n_tokens``."""
        return self.table.trim(slot, n_tokens) if self.paged else 0

    def occupancy(self) -> str:
        return self.table.occupancy() if self.paged else \
            f"slot-dense cache ({self.num_slots} slots)"

    @property
    def pressure(self) -> float:
        """Pool pressure in ``[0, 1]`` (see :meth:`PageTable.pressure`).
        Non-paged families (ssm / hybrid recurrent state) hold O(1)
        state per slot — capacity pressure is a slot-count question the
        scheduler already answers, so they report 0.0 here."""
        return self.table.pressure if self.paged else 0.0

    # -- prefix caching -----------------------------------------------------
    def match_prefix(self, tokens) -> PrefixMatch:
        """Longest reusable page-aligned prefix for ``tokens`` (read-only
        probe; also the router's affinity metric). Non-paged families
        (ssm/hybrid recurrent state) always report zero reuse."""
        if not self.paged:
            return PrefixMatch()
        return self.table.match_prefix(tokens)

    def adopt_prefix(self, slot: int, match: PrefixMatch) -> int:
        """Map a match into ``slot`` and perform the device-side CoW copy
        when the plan forked a page. Returns the tokens covered."""
        if not self.paged or (not match.pages and match.cow_page is None):
            return 0
        pair = self.table.adopt_prefix(slot, match)
        if pair is not None:
            src, dst = pair
            # page leaves only: a quantized cache also carries the
            # codebook pytree, which has no page axis to copy along
            pages = {key: self.data[key] for key in ("k", "v")}
            copied = _copy_page(pages, jnp.int32(src), jnp.int32(dst))
            self.data = {**self.data, **copied}
            self.cow_forks += 1
            if self.obs is not None:
                self.obs.annotate("cow_fork", slot=slot, src=int(src),
                                  dst=int(dst))
        return match.tokens

    def register_prefix(self, slot: int, tokens, n_covered: int) -> None:
        if self.paged:
            self.table.register_prefix(slot, tokens, n_covered)

    def table_device(self, sharding=None) -> jnp.ndarray:
        return self.table.device(sharding)

    @property
    def live_pages(self) -> int:
        return self.table.live_pages if self.paged else 0

    @property
    def cached_pages(self) -> int:
        return self.table.cached_pages if self.paged else 0
