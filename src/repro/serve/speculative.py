"""Self-speculative decoding: draft cheap, verify with the target, roll back.

LUT-DLA's extreme low-bit LUT path runs at a fraction of the dense cost
with a modest accuracy drop — exactly the profile of a speculative-
decoding *drafter*. Because the same weights already exist in both forms
(``mode="dense"`` vs ``mode="lut_infer"`` via :class:`QuantConfig`), the
drafter needs no second checkpoint: it is the target model driven through
a coarser operating point (and optionally an early-exit layer prefix).

Round structure (docs/speculative.md has the lifecycle diagram):

  1. **draft** — ``k`` successive cheap decode steps propose tokens
     ``g_1..g_k`` per decoding slot. The drafter runs against the SHARED
     paged KV pool: its in-round writes land at rows ``>= slot.pos``,
     which attention never reads back for committed context (mask is
     ``kj < pos``) and which the verify step overwrites with
     target-computed KV. The transient "draft KV state" therefore costs
     zero extra pool pages beyond the round's lookahead.
  2. **verify** — ONE batched ``Model.verify_paged`` call scores the
     slot's pending token plus all ``k`` proposals at per-slot positions
     and scatters target-numerics KV over the draft rows.
  3. **accept / roll back** — greedy mode keeps the longest proposal
     prefix matching the target argmax and emits one correction/bonus
     token from the target distribution (token-identical to
     non-speculative greedy by construction). Temperature mode runs
     standard rejection sampling with the residual-distribution
     correction (Leviathan et al., 2023), so samples are distributed
     exactly as the target's. Rejected rows are rolled back by rewinding
     ``slot.pos`` and trimming page-table tail pages
     (:meth:`PageTable.trim`) — prefix-shared pages are never touched
     (they live below ``slot.pos`` by construction; property-tested in
     tests/test_speculative.py).

Drafters:
  * :class:`ModelDrafter` — the paper-aligned path: the target's own
    weights through a draft :class:`QuantConfig` (e.g. ``lut_infer``
    while the target serves dense — same codebooks, no extra params) and
    optionally only the first ``draft_layers`` of the stack (early-exit
    self-drafting; logits via the shared final norm + head).
  * :class:`NgramDrafter` — zero-model-cost prompt lookup: propose the
    continuation of an earlier occurrence of the current suffix n-gram
    (earliest occurrence wins — it has the most continuation ahead of
    it). No weights, no extra compute; acceptance tracks how repetitive
    the stream is. (With one verify call per >= 1 emitted
    token, it is also the deterministic baseline the smoke benchmark
    asserts its speedup on.)

Acceptance math lives in :func:`accept_tokens` — a pure host-side
function, unit-tested independently of the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import QuantConfig


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding operating point for :class:`~repro.serve.Engine`.

    Attributes:
      k: draft lookahead — proposals per round (per decoding slot). The
        verify call scores ``k + 1`` token columns; emitted tokens per
        round range from 1 (all rejected) to ``k + 1`` (all accepted +
        bonus).
      drafter: ``"model"`` (:class:`ModelDrafter`) or ``"ngram"``
        (:class:`NgramDrafter`).
      draft_qc: QuantConfig for the model drafter's forward passes;
        ``None`` = the engine's own ``qc``. The usual LUT-DLA deployment
        pairs a dense (or fine-LUT) target with a coarse ``lut_infer``
        drafter over the SAME params (run
        :func:`repro.core.precompute_model` first so the tables exist).
      draft_layers: early-exit depth for the model drafter — run only the
        first N layers and read logits through the shared final norm +
        head. ``None`` = full depth.
      ngram: max suffix length the ngram drafter matches on.

    Speculation is the first capability the engine sheds under page-pool
    pressure: at/above ``DegradationPolicy.spec_off`` the engine decodes
    one token at a time (no lookahead pages reserved) until pressure
    drops back below the hysteresis margin — see docs/robustness.md.
    """
    k: int = 4
    drafter: str = "model"
    draft_qc: Optional[QuantConfig] = None
    draft_layers: Optional[int] = None
    ngram: int = 3

    def build_drafter(self) -> "Drafter":
        if self.drafter == "model":
            return ModelDrafter(self.draft_qc, self.draft_layers)
        if self.drafter == "ngram":
            return NgramDrafter(self.ngram)
        raise ValueError(f"unknown drafter {self.drafter!r} "
                         "(expected 'model' or 'ngram')")


def _softmax(row: np.ndarray) -> np.ndarray:
    e = np.exp(row.astype(np.float64) - row.max())
    return e / e.sum()


def accept_tokens(draft: Sequence[int], logits: Optional[np.ndarray],
                  temperature: float, rng: np.random.Generator,
                  q_rows: Optional[Sequence[Optional[np.ndarray]]] = None,
                  targets: Optional[np.ndarray] = None,
                  ) -> Tuple[int, List[int]]:
    """Decide which proposals survive one verify round (host-side, pure).

    Args:
      draft: the ``n`` proposed tokens ``g_1..g_n``.
      logits: (n+1, V) target verify logits; row ``i`` is the target
        distribution AFTER consuming the slot's pending token and
        ``g_1..g_i``. May be ``None`` for a greedy slot when ``targets``
        is given (the engine computes the argmax on device and skips the
        full-logits device-to-host transfer for all-greedy rounds).
      temperature: the slot's sampling temperature (0 = greedy).
      rng: host PRNG for the accept coin flips + residual draws.
      q_rows: per-proposal draft distributions (each (V,) and summing to
        1), or ``None`` rows / ``None`` entirely for a deterministic
        drafter (one-hot: the proposal carried probability 1).
      targets: optional precomputed per-row argmax ids (>= n+1 entries);
        greedy mode uses them instead of ``np.argmax(logits)``.

    Returns ``(accepted, tokens)``: ``accepted`` proposals survived and
    ``tokens`` (length ``accepted + 1``) is what the round emits — the
    surviving proposals plus one token from the target distribution (the
    rejection-corrected residual draw, or the bonus token when everything
    was accepted). Greedy mode is exact prefix-matching against the
    target argmax, which makes the emitted stream token-identical to
    non-speculative greedy decoding.
    """
    n = len(draft)
    if temperature <= 0.0:
        if targets is None:
            targets = np.argmax(logits[:n + 1], axis=-1)
        assert len(targets) >= n + 1, (len(targets), n)
        a = 0
        while a < n and draft[a] == int(targets[a]):
            a += 1
        return a, [int(t) for t in draft[:a]] + [int(targets[a])]
    assert logits is not None and logits.shape[0] >= n + 1

    # temperature: standard speculative rejection sampling. p_i is the
    # target distribution that judges proposal g_{i+1}; q_i the draft
    # distribution it was sampled from (one-hot for deterministic
    # drafters). Accept with prob min(1, p(g)/q(g)); on rejection sample
    # from the residual max(p - q, 0) — the correction that makes the
    # combined procedure draw exactly from p (Leviathan et al., 2023).
    inv_t = 1.0 / max(temperature, 1e-6)
    for i in range(n):
        p = _softmax(logits[i] * inv_t)
        g = int(draft[i])
        q = None if q_rows is None else q_rows[i]
        q_g = 1.0 if q is None else float(q[g])
        if q_g > 0 and rng.random() < min(1.0, float(p[g]) / q_g):
            continue
        if q is None:                     # one-hot drafter: remove g's mass
            residual = p.copy()
            residual[g] = 0.0
        else:
            residual = np.maximum(p - q, 0.0)
        tot = residual.sum()
        if tot <= 0.0:                    # degenerate (p ⊆ q): fall back to p
            residual, tot = p, p.sum()
        tok = int(rng.choice(residual.shape[0], p=residual / tot))
        return i, [int(t) for t in draft[:i]] + [tok]
    p = _softmax(logits[n] * inv_t)       # everything accepted: bonus token
    tok = int(rng.choice(p.shape[0], p=p))
    return n, [int(t) for t in draft] + [tok]


class Drafter:
    """Proposal source for one speculative round.

    ``bind(engine)`` is called once by the engine; ``propose`` once per
    round. Subclasses may read engine state (params, paged cache, slots)
    but must only WRITE cache rows at positions ``>= slot.pos`` — the
    verify step owns everything below.

    ``writes_kv``: declare True when ``propose`` writes draft KV through
    the page tables (the engine then reserves lookahead pages BEFORE
    drafting; for host-side drafters it reserves after, so a round that
    proposes nothing allocates nothing). An undeclared writer is never
    unsafe — writes to unreserved rows redirect to the trash page — it
    just drafts against missing context.
    """

    writes_kv = False

    def bind(self, engine) -> None:                    # pragma: no cover
        pass

    def propose(self, engine, dslots, k_slot: Dict[int, int], k: int):
        """Return ``(g, n_prop, q_rows)`` for this round.

        g: (num_slots, k) int32 proposals (garbage outside live entries).
        n_prop: (num_slots,) int — proposals actually made per slot
          (``<= k_slot[idx]``).
        q_rows: per-step list of (num_slots, V) draft-probability arrays
          for temperature slots, or ``None`` for deterministic drafters.
        """
        raise NotImplementedError


class ModelDrafter(Drafter):
    """The target's own weights through a cheaper operating point.

    ``draft_qc`` switches the projection mode (the LUT-DLA move: coarse
    ``lut_infer`` drafting under a dense target — the tables come from
    ``precompute_model`` and share the target's codebooks);
    ``draft_layers`` truncates the stack to an early-exit prefix whose
    hidden state reads logits through the shared final norm + head.

    The drafter decodes against the shared paged pool: step ``t`` writes
    its (draft-numerics) KV at ``pos + t`` so step ``t+1`` can attend the
    in-round proposals; committed rows ``< pos`` are read but never
    written, and verify overwrites every draft row with target KV.
    With ``draft_layers`` only the first N layers' rows are written —
    the remaining layers' draft rows keep stale values, which is safe
    for the same reason (nothing below ``pos`` is affected).

    The ``k`` autoregressive draft steps run as ONE jitted
    ``lax.scan`` — a speculative round therefore costs two device
    dispatches (draft-k + verify) regardless of ``k``, which is what
    turns per-slot acceptance into wall-clock speedup on
    dispatch-latency-bound decode. Draft-token sampling happens inside
    the scan (greedy argmax, or per-slot-temperature categorical off the
    engine's PRNG key); rounds with a temperature slot additionally
    return the per-step draft distributions (``(k, num_slots, V)``) for
    rejection sampling, while all-greedy rounds run a separately
    compiled variant that never computes or materializes them.
    """

    writes_kv = True

    def __init__(self, draft_qc: Optional[QuantConfig] = None,
                 draft_layers: Optional[int] = None):
        self.draft_qc = draft_qc
        self.draft_layers = draft_layers
        self._draft_greedy = None
        self._draft_probs = None

    def bind(self, engine) -> None:
        model, qc = engine.model, self.draft_qc or engine.qc
        self.qc = qc
        n = self.draft_layers
        if n is not None and not (0 < n <= model.cfg.num_layers):
            raise ValueError(
                f"draft_layers={n} out of range for a "
                f"{model.cfg.num_layers}-layer target")
        if n == model.cfg.num_layers:
            n = None                       # full depth: skip the slicing
        draft_model = model if n is None \
            else type(model)(model.cfg.replace(num_layers=n))
        k = engine.spec.k

        def make_draft_k(with_probs: bool):
            """Two compiled variants: all-greedy rounds skip the full-
            vocab softmax/categorical work AND the (k, B, V) draft-
            probability output buffer entirely."""

            def draft_k(p, kv, table, first, positions, n_prop, temps,
                        key):
                b = first.shape[0]
                row_keys = jax.vmap(
                    lambda i: jax.random.fold_in(key, i))(jnp.arange(b))
                if n is None:
                    p_d, kv_d = p, kv
                else:
                    # the scan carries only the early-exit prefix's slice
                    # of the pool; the untouched deep layers are merged
                    # back once after the loop (one copy per ROUND, not
                    # per step)
                    p_d = dict(p)
                    p_d["blocks"] = jax.tree_util.tree_map(
                        lambda t: t[:n], p["blocks"])
                    kv_d = {key: kv[key][:n] for key in ("k", "v")}

                def body(carry, t):
                    kv_c, cur = carry
                    pos_t = jnp.where((positions >= 0) & (t < n_prop),
                                      positions + t, -1)
                    logits, kv_c = draft_model.decode_paged(
                        p_d, cur[:, None], kv_c, table, pos_t, qc)
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    if not with_probs:
                        return (kv_c, tok), tok
                    scaled = logits.astype(jnp.float32) \
                        / jnp.maximum(temps, 1e-6)[:, None]
                    probs = jax.nn.softmax(scaled, axis=-1)
                    keys = jax.vmap(jax.random.fold_in)(
                        row_keys, jnp.broadcast_to(t, (b,)))
                    sampled = jax.vmap(jax.random.categorical)(keys,
                                                               scaled)
                    tok = jnp.where(temps > 0.0,
                                    sampled.astype(jnp.int32), tok)
                    return (kv_c, tok), (tok, probs)

                (kv_f, _), ys = jax.lax.scan(
                    body, (kv_d, first), jnp.arange(k, dtype=jnp.int32))
                if n is not None:
                    kv_f = {key: kv[key].at[:n].set(kv_f[key])
                            for key in ("k", "v")}
                if not with_probs:
                    return ys, kv_f        # g (k, B)
                g, qp = ys
                return g, qp, kv_f         # g (k, B); qp (k, B, V)

            return draft_k

        if engine.mesh is None:
            self._draft_greedy = jax.jit(make_draft_k(False),
                                         donate_argnums=(1,))
            self._draft_probs = jax.jit(make_draft_k(True),
                                        donate_argnums=(1,))
        else:
            repl = engine._table_sharding
            in_sh = (engine._param_sharding, engine._cache_sharding,
                     repl, repl, repl, repl, repl, repl)
            self._draft_greedy = jax.jit(
                make_draft_k(False), in_shardings=in_sh,
                out_shardings=(repl, engine._cache_sharding),
                donate_argnums=(1,))
            self._draft_probs = jax.jit(
                make_draft_k(True), in_shardings=in_sh,
                out_shardings=(repl, repl, engine._cache_sharding),
                donate_argnums=(1,))

    def propose(self, engine, dslots, k_slot: Dict[int, int], k: int):
        b = engine.num_slots
        first = np.zeros((b,), np.int32)
        posv = np.full((b,), -1, np.int32)
        n_prop = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        need_q = False
        for s in dslots:
            first[s.idx] = s.next_token
            posv[s.idx] = s.pos
            n_prop[s.idx] = k_slot[s.idx]
            if k_slot[s.idx] > 0 and s.req.temperature > 0.0:
                temps[s.idx] = s.req.temperature
                need_q = True
        if n_prop.max() == 0:
            return np.zeros((b, k), np.int32), n_prop, None
        engine.key, sub = jax.random.split(engine.key)
        args = (engine.params, engine.kv.data,
                engine.kv.table_device(engine._table_sharding),
                jnp.asarray(first), jnp.asarray(posv),
                jnp.asarray(n_prop), jnp.asarray(temps), sub)
        qp = None
        with engine._mesh_scope():
            if need_q:
                g, qp, engine.kv.data = self._draft_probs(*args)
            else:                  # all-greedy: no draft-prob work at all
                g, engine.kv.data = self._draft_greedy(*args)
        # one batched transfer for the whole round: draft ids, plus the
        # per-step draft probabilities only when rejection sampling
        # actually needs them
        got = engine._device_read((g, qp) if need_q else (g,))
        q_rows = list(got[1]) if need_q else None
        return got[0].T.copy(), n_prop, q_rows  # (B, k)


class NgramDrafter(Drafter):
    """Prompt-lookup drafting: continue an earlier occurrence of the
    current suffix n-gram (longest suffix first; EARLIEST occurrence
    wins, since it has the most continuation ahead of it — see
    :meth:`_lookup`). Zero model cost — one host-side scan of the slot's
    token history per round — and deterministic (the draft distribution
    is one-hot, so temperature-mode acceptance degrades gracefully to an
    accept-with-prob-p(g) test)."""

    def __init__(self, ngram: int = 3):
        if ngram < 1:
            raise ValueError(f"ngram must be >= 1, got {ngram}")
        self.ngram = ngram

    @staticmethod
    def _lookup(hist: List[int], k: int, nmax: int) -> List[int]:
        """Continuation of the best earlier match of a suffix n-gram.

        Longest suffix first; within one suffix length the EARLIEST
        occurrence wins (it has the most continuation ahead of it — the
        most recent occurrence sits right before the suffix itself and
        would only ever yield one proposal). A shorter suffix is tried
        when a longer one cannot fill the ``k`` lookahead, so constant
        runs propose the whole budget instead of their tail."""
        best: List[int] = []
        for n in range(min(nmax, len(hist) - 1), 0, -1):
            pat = hist[-n:]
            for i in range(0, len(hist) - n):
                if hist[i:i + n] == pat:
                    cont = hist[i + n:i + n + k]   # >= 1 token by range
                    if len(cont) > len(best):
                        best = cont
                    break                          # earliest i for this n
            if len(best) >= k:
                break
        return best

    def propose(self, engine, dslots, k_slot: Dict[int, int], k: int):
        b = engine.num_slots
        g = np.zeros((b, k), np.int32)
        n_prop = np.zeros((b,), np.int32)
        for s in dslots:
            kk = k_slot[s.idx]
            if kk <= 0:
                continue
            # true token stream regardless of preemption re-queues
            hist = list(s.req.tokens) + list(s.req.out_tokens)
            cont = self._lookup(hist, kk, self.ngram)
            g[s.idx, :len(cont)] = cont
            n_prop[s.idx] = len(cont)
        return g, n_prop, None
