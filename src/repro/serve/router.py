"""Data-parallel replica routing over continuous-batching engines.

Tensor parallelism lives INSIDE one :class:`~repro.serve.engine.Engine`
(params + paged KV pool sharded over a mesh's ``model`` axis; see
``Engine(mesh=...)``). Data parallelism is replica-level: each replica
group owns a full engine — its own :class:`SlotScheduler`, page pool and
jitted prefill/decode steps — and the :class:`ReplicaRouter` dispatches
each incoming request to the least-loaded replica, FIFO within a replica.

Replica-level DP (rather than widening one engine's batch over a ``data``
axis) keeps the scheduler's host-side state machine per-replica: admission,
eviction and preemption decisions never need a cross-replica barrier, and a
replica that is busy compiling or preempting cannot stall its neighbours.
This mirrors how LUT-based accelerator deployments scale out — more
identical lookup units, not wider ones.

Fault tolerance (docs/robustness.md has the state machine diagram):
each replica carries a health state::

    HEALTHY ──step exception──▶ DEGRADED ──repeated / crash / stall──▶ DEAD
       ▲                           │                                    │
       └──── clean steps ◀─────────┘            in-flight requests ─────┘
                                                requeued w/ backoff onto
                                                healthy replicas
    HEALTHY/DEGRADED ──drain()──▶ DRAINING (no new admissions, finishes
                                  in-flight) ──undrain()──▶ HEALTHY

A step-level watchdog wraps every ``Engine.step``: an exception counts a
failure (DEGRADED; DEAD after ``max_step_failures`` consecutive ones or a
:class:`~repro.serve.faults.ReplicaCrashed`), and a replica whose
progress marker does not move for ``stall_steps`` while it has work is
declared DEAD too. A dead replica's in-flight requests are drained
host-side and requeued onto the surviving replicas with capped
exponential backoff — re-prefill through each replica's prefix cache
makes the requeue cheap, and greedy output stays token-identical because
recompute resumption is exact (``docs/serving.md``).

Known limitation: :meth:`ReplicaRouter.step` steps replicas sequentially,
and each engine step ends in a blocking device→host sample sync, so on a
single host driver the replicas do not overlap in wall-clock — the router
adds capacity and isolation, not single-driver throughput. Overlapping
them (dispatch every replica's jitted step before syncing any samples, or
one driver thread per replica) is future work.

``ReplicaRouter.from_mesh`` carves a ``(data, model)`` mesh into one
tensor-parallel submesh per index along the leading data axis, so
``2 × 2 = 4`` devices serve as 2 replicas × TP-2 from a single entry
point::

    mesh = make_test_mesh((2, 2), ("data", "model"))
    router = ReplicaRouter.from_mesh(model, params, qc, mesh=mesh,
                                     batch_size=4, max_seq=512)
    router.run(requests)
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import logging
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.lut import DENSE, QuantConfig
from repro.obs import Obs

from .engine import Engine
from .faults import ReplicaCrashed
from .kv_cache import PagePoolExhausted
from .scheduler import Request

log = logging.getLogger(__name__)


class ReplicaHealth(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"    # recent step failures; still serving
    DRAINING = "draining"    # no new admissions; finishing in-flight
    DEAD = "dead"            # out of rotation; requests were requeued


#: Health states that accept new requests.
ADMITTING = (ReplicaHealth.HEALTHY, ReplicaHealth.DEGRADED)


@dataclasses.dataclass
class ReplicaStatus:
    """Watchdog bookkeeping for one replica (host-side only)."""
    health: ReplicaHealth = ReplicaHealth.HEALTHY
    consecutive_failures: int = 0
    total_failures: int = 0
    clean_steps: int = 0           # successful steps since the last failure
    last_progress_step: int = 0    # router step the marker last moved at
    last_marker: int = 0           # engine.progress_marker snapshot
    recovered_requests: int = 0    # requests drained out at death
    death_reason: Optional[str] = None


class ReplicaRouter:
    """Prefix-affine, least-loaded dispatch of requests to engine replicas,
    with per-replica health tracking and crash recovery.

    Dispatch: each replica's prefix cache is local — pages cached on
    replica 0 are invisible to replica 1 — so dispatch probes every
    ADMITTING replica's page index and routes a request to the replica
    holding the LONGEST cached prefix of its prompt (cache-hit tokens
    beat a small load imbalance: they skip whole prefill chunks).
    Requests with no cached prefix anywhere fall back to least-loaded,
    FIFO within a replica; ties pick the lowest replica index. Replicas
    with waiting-queue room are preferred over full ones (a request is
    load-shed only when EVERY admitting replica's queue is full), and
    HEALTHY replicas over DEGRADED ones. Pass ``prefix_affinity=False``
    for pure least-loaded dispatch (e.g. to measure the affinity win).

    All replicas must be configured identically (same ``max_seq``, page
    pool, ...): admissibility is checked against whichever replica a
    request is dispatched to. An oversized request raises
    :class:`~repro.serve.kv_cache.PagePoolExhausted` at :meth:`submit`
    only after every admitting replica refused it — a replica-level
    refusal (e.g. injected pool exhaustion) falls through to the
    next-best replica instead of escaping to the caller.

    Watchdog knobs:
      max_step_failures: consecutive step exceptions before a replica is
        declared dead (a :class:`ReplicaCrashed` kills it immediately).
      stall_steps: router steps without progress (while the replica has
        work) before it is declared dead. ``None`` disables.
      recover_after: clean steps for DEGRADED to return to HEALTHY.
      retry_backoff / retry_backoff_cap: a recovered request re-enters
        dispatch after ``min(cap, backoff · 2^(retries-1))`` router steps
        — capped exponential backoff keyed on the request's own retry
        count.
    """

    def __init__(self, engines: Sequence[Engine],
                 prefix_affinity: bool = True,
                 affinity_load_slack: Optional[int] = None,
                 max_step_failures: int = 3,
                 stall_steps: Optional[int] = 16,
                 recover_after: int = 3,
                 retry_backoff: int = 1,
                 retry_backoff_cap: int = 16,
                 obs: Optional[Obs] = None):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines: List[Engine] = list(engines)
        # Router-level observability: its own registry for cluster-wide
        # tallies (retries, health transitions); per-replica counters
        # stay in each engine's registry. Each engine's trace track gets
        # the replica index as pid (docs/observability.md).
        self.obs = obs if obs is not None else Obs()
        met = self.obs.metrics
        self._c_retried = met.counter("router.retried_requests",
                                      unit="requests")
        self._c_health = {
            h: met.counter(f"router.health.to_{h.value}",
                           unit="transitions") for h in ReplicaHealth}
        for i, e in enumerate(self.engines):
            e.obs.pid = i
            e.obs.tracer.name_process(i, f"replica {i}")
        self.prefix_affinity = prefix_affinity
        # Affinity must not collapse DP onto one hot replica: only
        # replicas within `slack` load of the least-loaded are affinity
        # candidates. One slot-batch of queueing is roughly where waiting
        # starts to cost more than the prefill a cache hit saves.
        self.affinity_load_slack = (affinity_load_slack
                                    if affinity_load_slack is not None
                                    else self.engines[0].num_slots)
        self.max_step_failures = max_step_failures
        self.stall_steps = stall_steps
        self.recover_after = recover_after
        self.retry_backoff = retry_backoff
        self.retry_backoff_cap = retry_backoff_cap
        self.status: List[ReplicaStatus] = [ReplicaStatus()
                                            for _ in self.engines]
        self.step_count = 0
        # (ready_step, seq, request) — seq keeps heap order deterministic
        self._retries: List[Tuple[int, int, Request]] = []
        self._retry_seq = itertools.count()

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def build(cls, model, params, qc: QuantConfig = DENSE, *,
              replicas: int, mesh=None, router_kw: Optional[dict] = None,
              **engine_kw) -> "ReplicaRouter":
        """``replicas`` identical engines; each gets ``mesh`` (usually a
        per-replica TP submesh is wanted instead — see :meth:`from_mesh`;
        passing one shared mesh here replicates serving work, it does not
        split it). ``router_kw`` forwards to the router constructor
        (watchdog/backoff knobs)."""
        return cls([Engine(model, params, qc, mesh=mesh, **engine_kw)
                    for _ in range(replicas)], **(router_kw or {}))

    @classmethod
    def from_mesh(cls, model, params, qc: QuantConfig = DENSE, *, mesh,
                  router_kw: Optional[dict] = None,
                  **engine_kw) -> "ReplicaRouter":
        """One tensor-parallel engine per data-slice of ``mesh``.

        ``mesh`` must carry a trailing ``model`` axis; every other (data)
        axis is flattened into replica groups
        (``launch.mesh.replica_submeshes``). A ``(2, 16, 16)`` pod mesh
        therefore yields 32 replicas of TP-16; params are placed per
        replica (each group holds its own copy — that IS data
        parallelism's memory cost).
        """
        from repro.launch.mesh import replica_submeshes
        return cls([Engine(model, params, qc, mesh=sub, **engine_kw)
                    for sub in replica_submeshes(mesh)],
                   **(router_kw or {}))

    # ------------------------------------------------------------------
    # health
    # ------------------------------------------------------------------
    def health(self, i: int) -> ReplicaHealth:
        return self.status[i].health

    @property
    def retried_requests(self) -> int:
        return self._c_retried.value

    def _set_health(self, i: int, health: ReplicaHealth,
                    note: str = "") -> None:
        """THE health-transition funnel: counts the flip and annotates
        the replica's trace track; no-op when the state is unchanged."""
        st = self.status[i]
        if st.health is health:
            return
        self._c_health[health].inc()
        self.engines[i].obs.annotate("health", frm=st.health.value,
                                     to=health.value, note=note)
        st.health = health

    @property
    def alive_replicas(self) -> List[int]:
        return [i for i, st in enumerate(self.status)
                if st.health is not ReplicaHealth.DEAD]

    def _admitting(self) -> List[int]:
        return [i for i, st in enumerate(self.status)
                if st.health in ADMITTING]

    def drain(self, i: int) -> None:
        """Graceful drain: replica ``i`` stops admitting new requests and
        finishes (only) its in-flight work — queued and slotted requests
        keep stepping. Use before a planned replica restart; check
        :meth:`drained` for completion, :meth:`undrain` to restore."""
        st = self.status[i]
        if st.health is ReplicaHealth.DEAD:
            raise ValueError(f"replica {i} is dead, nothing to drain")
        log.info("draining replica %d (%s, load %d)", i,
                 st.health.value, self.engines[i].load)
        self._set_health(i, ReplicaHealth.DRAINING, "drain()")

    def drained(self, i: int) -> bool:
        """Whether a draining replica has finished its in-flight work."""
        return (self.status[i].health is ReplicaHealth.DRAINING
                and not self.engines[i].scheduler.has_work)

    def undrain(self, i: int) -> None:
        """Return a draining replica to rotation."""
        st = self.status[i]
        if st.health is not ReplicaHealth.DRAINING:
            raise ValueError(
                f"replica {i} is {st.health.value}, not draining")
        self._set_health(i, ReplicaHealth.HEALTHY, "undrain()")
        st.consecutive_failures = 0
        st.clean_steps = 0
        st.last_progress_step = self.step_count

    def _mark_dead(self, i: int, reason: str) -> None:
        """Declare replica ``i`` dead and requeue its in-flight requests
        onto the surviving replicas (capped exponential backoff)."""
        eng, st = self.engines[i], self.status[i]
        self._set_health(i, ReplicaHealth.DEAD, reason)
        st.death_reason = reason
        reqs = eng.scheduler.drain_requests(eng.kv)
        st.recovered_requests += len(reqs)
        log.warning("replica %d marked dead (%s); requeueing %d in-flight "
                    "request(s)", i, reason, len(reqs))
        for r in reqs:
            r.retries += 1
            delay = min(self.retry_backoff_cap,
                        self.retry_backoff * (1 << (r.retries - 1)))
            heapq.heappush(self._retries,
                           (self.step_count + delay,
                            next(self._retry_seq), r))

    def _record_failure(self, i: int, exc: BaseException) -> None:
        st = self.status[i]
        st.total_failures += 1
        st.consecutive_failures += 1
        st.clean_steps = 0
        crashed = isinstance(exc, ReplicaCrashed)
        if crashed or st.consecutive_failures >= self.max_step_failures:
            self._mark_dead(
                i, f"{type(exc).__name__}: {exc}" if crashed else
                f"{st.consecutive_failures} consecutive step failures "
                f"(last: {type(exc).__name__}: {exc})")
        else:
            if st.health is ReplicaHealth.HEALTHY:
                log.warning("replica %d degraded: step failed (%s: %s)",
                            i, type(exc).__name__, exc)
                self._set_health(i, ReplicaHealth.DEGRADED,
                                 f"{type(exc).__name__}: {exc}")

    def _watch_progress(self, i: int) -> None:
        """Stall detection + degraded-replica recovery after a clean step."""
        eng, st = self.engines[i], self.status[i]
        st.consecutive_failures = 0
        st.clean_steps += 1
        marker = eng.progress_marker
        if marker != st.last_marker:
            st.last_marker = marker
            st.last_progress_step = self.step_count
            if (st.health is ReplicaHealth.DEGRADED
                    and st.clean_steps >= self.recover_after):
                log.info("replica %d recovered (healthy)", i)
                self._set_health(i, ReplicaHealth.HEALTHY,
                                 f"{st.clean_steps} clean steps")
        elif (self.stall_steps is not None
              and eng.scheduler.has_work
              and self.step_count - st.last_progress_step
              >= self.stall_steps):
            self._mark_dead(
                i, f"stalled: no progress in {self.stall_steps} steps "
                f"with work pending")

    def stats(self) -> Dict[str, object]:
        """Health / load / failure surface for dashboards and tests."""
        return {
            "step": self.step_count,
            "retried_requests": self.retried_requests,
            "pending_retries": len(self._retries),
            "replicas": [
                {"health": st.health.value, "load": e.load,
                 "mode": e.mode, "pressure": round(e.pressure, 3),
                 "total_failures": st.total_failures,
                 "recovered_requests": st.recovered_requests,
                 "death_reason": st.death_reason}
                for e, st in zip(self.engines, self.status)],
        }

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._retries) or any(
            e.scheduler.has_work for i, e in enumerate(self.engines)
            if self.status[i].health is not ReplicaHealth.DEAD)

    @property
    def load(self) -> int:
        return sum(e.load for e in self.engines)

    def _ranked_replicas(self, req: Request) -> List[Engine]:
        """Admitting replicas, best-first.

        Order: queue room beats a full queue (shedding is a last resort),
        HEALTHY beats DEGRADED, then longest cached prompt prefix among
        near-idle replicas (affinity is bounded: a replica more than
        ``affinity_load_slack`` requests busier than the least-loaded one
        is skipped even on a hit — otherwise a workload where EVERY
        request shares one system prompt would serialize onto the first
        replica that cached it while the rest sit idle), then load, then
        index. The probe (``kv.match_prefix``) is read-only — no pages
        are retained until the chosen replica's scheduler actually admits
        the request (it re-matches then, so a probe gone stale by
        eviction only costs the affinity, never correctness)."""
        cand = self._admitting()
        if not cand:
            return []
        tokens = list(req.tokens) + list(req.out_tokens)
        load_cap = min(self.engines[i].load for i in cand) \
            + self.affinity_load_slack
        keys = []
        for i in cand:
            eng = self.engines[i]
            hit = 0
            if self.prefix_affinity and eng.load <= load_cap:
                hit = eng.kv.match_prefix(tokens).tokens
            keys.append((0 if eng.scheduler.queue_room > 0 else 1,
                         0 if self.status[i].health
                         is ReplicaHealth.HEALTHY else 1,
                         -hit, eng.load, i))
        return [self.engines[i] for *_, i in sorted(keys)]

    def submit(self, req: Request) -> Engine:
        """Dispatch ``req`` to the best admitting replica (see
        :meth:`_ranked_replicas`). Returns the engine it landed on — note
        a full cluster may land it as a ``LoadShedded`` result (every
        admitting replica's queue full; the chosen engine sheds by
        priority). Raises :class:`PagePoolExhausted` only when EVERY
        admitting replica refused the request — a single replica's
        refusal falls through to the next-best one — and
        :class:`RuntimeError` when no replica admits at all (all
        draining / dead)."""
        ranked = self._ranked_replicas(req)
        last_err: Optional[PagePoolExhausted] = None
        for eng in ranked:
            try:
                eng.submit(req)
                return eng
            except PagePoolExhausted as e:
                last_err = e
        if last_err is not None:
            raise last_err
        raise RuntimeError("no admitting replicas (all draining or dead)")

    def _dispatch_retries(self) -> None:
        """Re-admit recovered requests whose backoff expired. Uses the
        bound-exempt :meth:`Engine.requeue` path: a request the cluster
        already accepted is never load-shed by the act of rescuing it."""
        while self._retries and self._retries[0][0] <= self.step_count:
            _, _, req = heapq.heappop(self._retries)
            if req.done:               # expired while waiting
                continue
            ranked = self._ranked_replicas(req)
            if not ranked:
                raise RuntimeError(
                    "cannot recover request: no admitting replicas "
                    "(all draining or dead)")
            ranked[0].requeue(req)
            self._c_retried.inc()
            log.info("requeued recovered request (retry %d) onto "
                     "replica %d", req.retries,
                     self.engines.index(ranked[0]))

    def step(self) -> bool:
        """One engine iteration on every live replica with work, under
        the watchdog: a step exception degrades (or kills) the replica
        instead of propagating, and a dead replica's in-flight requests
        are requeued with backoff. Returns whether any replica did work.
        """
        self.step_count += 1
        self._dispatch_retries()
        progressed = False
        for i, e in enumerate(self.engines):
            st = self.status[i]
            if st.health is ReplicaHealth.DEAD or not e.scheduler.has_work:
                continue
            try:
                progressed = e.step() or progressed
            except Exception as exc:       # watchdog: contain the blast
                self._record_failure(i, exc)
                continue
            self._watch_progress(i)
        return progressed

    # Steps tolerated with work pending but nothing progressing before
    # run_until_idle errors out — must cover a retry-backoff window plus
    # a stall-watchdog window (transient faults stall legitimately).
    STALL_LIMIT = 512

    def run_until_idle(self) -> None:
        stalled = 0
        while self.has_work:
            if self.step():
                stalled = 0
            else:
                stalled += 1
                if stalled > self.STALL_LIMIT:
                    raise RuntimeError(
                        f"router made no progress in {stalled} steps "
                        f"({self.stats()})")

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests to completion across the replicas."""
        for r in requests:
            self.submit(r)
        self.run_until_idle()
        return requests
