"""Data-parallel replica routing over continuous-batching engines.

Tensor parallelism lives INSIDE one :class:`~repro.serve.engine.Engine`
(params + paged KV pool sharded over a mesh's ``model`` axis; see
``Engine(mesh=...)``). Data parallelism is replica-level: each replica
group owns a full engine — its own :class:`SlotScheduler`, page pool and
jitted prefill/decode steps — and the :class:`ReplicaRouter` dispatches
each incoming request to the least-loaded replica, FIFO within a replica.

Replica-level DP (rather than widening one engine's batch over a ``data``
axis) keeps the scheduler's host-side state machine per-replica: admission,
eviction and preemption decisions never need a cross-replica barrier, and a
replica that is busy compiling or preempting cannot stall its neighbours.
This mirrors how LUT-based accelerator deployments scale out — more
identical lookup units, not wider ones.

Known limitation: :meth:`ReplicaRouter.step` steps replicas sequentially,
and each engine step ends in a blocking device→host sample sync, so on a
single host driver the replicas do not overlap in wall-clock — the router
adds capacity and isolation, not single-driver throughput. Overlapping
them (dispatch every replica's jitted step before syncing any samples, or
one driver thread per replica) is future work.

``ReplicaRouter.from_mesh`` carves a ``(data, model)`` mesh into one
tensor-parallel submesh per index along the leading data axis, so
``2 × 2 = 4`` devices serve as 2 replicas × TP-2 from a single entry
point::

    mesh = make_test_mesh((2, 2), ("data", "model"))
    router = ReplicaRouter.from_mesh(model, params, qc, mesh=mesh,
                                     batch_size=4, max_seq=512)
    router.run(requests)
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.lut import DENSE, QuantConfig

from .engine import Engine
from .scheduler import Request


class ReplicaRouter:
    """Prefix-affine, least-loaded dispatch of requests to engine replicas.

    Each replica's prefix cache is local — pages cached on replica 0 are
    invisible to replica 1 — so dispatch probes every replica's page
    index and routes a request to the replica holding the LONGEST cached
    prefix of its prompt (cache-hit tokens beat a small load imbalance:
    they skip whole prefill chunks). Requests with no cached prefix
    anywhere fall back to least-loaded, FIFO within a replica; ties pick
    the lowest replica index. Pass ``prefix_affinity=False`` for pure
    least-loaded dispatch (e.g. to measure the affinity win).

    All replicas must be configured identically (same ``max_seq``, page
    pool, ...): admissibility is checked against whichever replica a
    request is dispatched to, so an oversized request raises
    :class:`~repro.serve.kv_cache.PagePoolExhausted` at :meth:`submit`
    regardless of the replica it would have landed on, exactly like a
    single engine.
    """

    def __init__(self, engines: Sequence[Engine],
                 prefix_affinity: bool = True,
                 affinity_load_slack: Optional[int] = None):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines: List[Engine] = list(engines)
        self.prefix_affinity = prefix_affinity
        # Affinity must not collapse DP onto one hot replica: only
        # replicas within `slack` load of the least-loaded are affinity
        # candidates. One slot-batch of queueing is roughly where waiting
        # starts to cost more than the prefill a cache hit saves.
        self.affinity_load_slack = (affinity_load_slack
                                    if affinity_load_slack is not None
                                    else self.engines[0].num_slots)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def build(cls, model, params, qc: QuantConfig = DENSE, *,
              replicas: int, mesh=None, **engine_kw) -> "ReplicaRouter":
        """``replicas`` identical engines; each gets ``mesh`` (usually a
        per-replica TP submesh is wanted instead — see :meth:`from_mesh`;
        passing one shared mesh here replicates serving work, it does not
        split it)."""
        return cls([Engine(model, params, qc, mesh=mesh, **engine_kw)
                    for _ in range(replicas)])

    @classmethod
    def from_mesh(cls, model, params, qc: QuantConfig = DENSE, *, mesh,
                  **engine_kw) -> "ReplicaRouter":
        """One tensor-parallel engine per data-slice of ``mesh``.

        ``mesh`` must carry a trailing ``model`` axis; every other (data)
        axis is flattened into replica groups
        (``launch.mesh.replica_submeshes``). A ``(2, 16, 16)`` pod mesh
        therefore yields 32 replicas of TP-16; params are placed per
        replica (each group holds its own copy — that IS data
        parallelism's memory cost).
        """
        from repro.launch.mesh import replica_submeshes
        return cls([Engine(model, params, qc, mesh=sub, **engine_kw)
                    for sub in replica_submeshes(mesh)])

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return any(e.scheduler.has_work for e in self.engines)

    @property
    def load(self) -> int:
        return sum(e.load for e in self.engines)

    def _least_loaded(self) -> Engine:
        return min(self.engines, key=lambda e: e.load)

    def _best_replica(self, req: Request) -> Engine:
        """Longest cached prompt prefix wins among near-idle replicas;
        load breaks ties.

        Affinity is bounded: a replica more than ``affinity_load_slack``
        requests busier than the least-loaded one is skipped even on a
        hit — otherwise a workload where EVERY request shares one system
        prompt would serialize onto the first replica that cached it
        while the rest sit idle (the spilled replica warms its own cache
        on the first miss, restoring affinity there).

        The probe (``kv.match_prefix``) is read-only — no pages are
        retained until the chosen replica's scheduler actually admits
        the request (it re-matches then, so a probe gone stale by
        eviction only costs the affinity, never correctness)."""
        if not self.prefix_affinity:
            return self._least_loaded()
        tokens = list(req.tokens) + list(req.out_tokens)
        load_cap = min(e.load for e in self.engines) \
            + self.affinity_load_slack
        best, best_key = None, None
        for i, eng in enumerate(self.engines):
            probe = eng.kv.match_prefix(tokens)
            hit = probe.tokens if eng.load <= load_cap else 0
            key = (-hit, eng.load, i)
            if best_key is None or key < best_key:
                best, best_key = eng, key
        return best

    def submit(self, req: Request) -> Engine:
        """Dispatch ``req`` to the replica whose cache holds the longest
        prefix of its prompt, falling back to least-loaded (ties: lowest
        index). Returns the engine it landed on. Raises
        :class:`PagePoolExhausted` for never-servable requests, exactly
        like ``Engine.submit``."""
        eng = self._best_replica(req)
        eng.submit(req)
        return eng

    def step(self) -> bool:
        """One engine iteration on every replica with work."""
        progressed = False
        for e in self.engines:
            if e.scheduler.has_work:
                progressed = e.step() or progressed
        return progressed

    def run_until_idle(self) -> None:
        while self.has_work:
            if not self.step():
                raise RuntimeError("router made no progress")  # unreachable

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests to completion across the replicas."""
        for r in requests:
            self.submit(r)
        self.run_until_idle()
        return requests
