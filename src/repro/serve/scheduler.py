"""Slot-level scheduler for continuous batching.

The scheduler is pure host-side policy: it never touches device arrays.
It owns a FIFO waiting queue and ``num_slots`` slots, each a small state
machine::

    FREE ──admit──▶ PREFILL ──last chunk──▶ DECODE ──EOS/max_new──▶ FREE
                       ▲                       │
                       └────── preempt ◀───────┘   (pages reclaimed,
                                                    request re-queued with
                                                    its generated tokens
                                                    folded into the prompt)

Admission happens *the moment a slot frees* — including mid-decode — as
long as the page pool can hold the request's prompt. Prefill is chunked
(the engine interleaves one chunk with one decode step), so a long prompt
never stalls decoding for the slots already running.

Eviction rules (``docs/serving.md`` has the worked trace):
  * EOS sampled (when ``eos_id`` is configured)         → evict, free pages.
  * ``len(out_tokens) == max_new_tokens``               → evict, free pages.
  * sequence hit ``max_seq``                            → evict (truncated).
  * page pool exhausted mid-decode                      → preempt the
    youngest decoding slot (recompute-style: its prompt + generated tokens
    re-enter the waiting queue, nothing is lost).
"""
from __future__ import annotations

import dataclasses
import enum
import logging
from collections import deque
from typing import Deque, List, Optional

from .kv_cache import PagedKVCache, PagePoolExhausted

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    """One generation request.

    Attributes:
      tokens: prompt token ids.
      max_new_tokens: generation budget.
      temperature: 0 = greedy; >0 = categorical over logits/T.
      out_tokens: generated ids (appended by the engine).
      done: set once the request finishes (EOS / budget / truncation).
      arrival / first_token_step / finish_step: engine-step timestamps for
        latency reporting (arrival is caller-settable; see serve_demo).
      cached_tokens: prompt tokens served from the prefix cache instead of
        being prefilled, accumulated across (re-)admissions — the
        per-request cache-hit stat surfaced in results.
    """
    tokens: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    arrival: Optional[int] = None
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    cached_tokens: int = 0


class SlotPhase(enum.Enum):
    FREE = "free"
    PREFILL = "prefill"
    DECODE = "decode"


@dataclasses.dataclass
class Slot:
    """One batch lane. ``pos`` counts the tokens whose KV/state is cached;
    ``next_token`` is the sampled-but-not-yet-decoded token id;
    ``prompt`` is the admission-time prompt (request tokens + any
    re-queued generated tokens), built once instead of per chunk."""
    idx: int
    phase: SlotPhase = SlotPhase.FREE
    req: Optional[Request] = None
    pos: int = 0
    prefill_len: int = 0          # prompt length incl. re-queued tokens
    prompt: List[int] = dataclasses.field(default_factory=list)
    next_token: Optional[int] = None

    @property
    def free(self) -> bool:
        return self.phase is SlotPhase.FREE


class SlotScheduler:
    """Admission / eviction / preemption policy over a fixed slot set."""

    def __init__(self, num_slots: int):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.waiting: Deque[Request] = deque()

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request (FIFO)."""
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(not s.free for s in self.slots)

    def prefill_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.phase is SlotPhase.PREFILL]

    def decode_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.phase is SlotPhase.DECODE]

    # -- admission ----------------------------------------------------------
    def admit(self, kv: PagedKVCache) -> List[Slot]:
        """Move waiting requests into free slots while pages allow.

        Called at the top of every engine step, so a request is admitted on
        the very step its slot was evicted (admission mid-decode). Stops at
        the first request whose prompt pages don't fit *right now* (FIFO —
        no reordering, so no starvation). Raises :class:`PagePoolExhausted`
        via ``check_admissible`` for requests that could never fit.

        Prefix caching: the request's prompt is probed against the page
        index first; matched pages are mapped read-shared (only UNSHARED
        pages count against capacity) and the slot starts prefill at the
        first uncached token (``slot.pos``).
        """
        admitted: List[Slot] = []
        for slot in self.slots:
            if not self.waiting:
                break
            if not slot.free:
                continue
            req = self.waiting[0]
            prompt = list(req.tokens) + list(req.out_tokens)
            # The prompt itself must fit; a prompt of exactly max_seq is
            # still servable (it truncates after its first sampled token —
            # the engine's eviction rule), and a preempted request can
            # legitimately come back at that boundary.
            kv.check_admissible(len(prompt))
            match = kv.match_prefix(prompt)
            if not kv.can_fit(len(prompt), match):
                break                              # wait for evictions
            self.waiting.popleft()
            matched = kv.adopt_prefix(slot.idx, match)
            kv.ensure(slot.idx, len(prompt))
            slot.req = req
            slot.phase = SlotPhase.PREFILL
            slot.pos = matched           # prefill starts past the reuse
            slot.prefill_len = len(prompt)
            slot.prompt = prompt
            slot.next_token = None
            req.cached_tokens += matched
            admitted.append(slot)
        if (self.waiting and not admitted
                and all(s.free for s in self.slots)):
            # nothing running, nothing admitted: the head request can never
            # be served (pool fragmentation is impossible — pages are unit-
            # size — so this is a genuine capacity error).
            req = self.waiting[0]
            n = len(req.tokens) + len(req.out_tokens)
            raise PagePoolExhausted(
                f"request with {n} prompt tokens cannot be admitted on an "
                f"idle engine ({kv.occupancy()})" if kv.paged else
                f"request with {n} prompt tokens cannot be admitted "
                f"(max_seq={kv.max_seq})")
        return admitted

    # -- prefill ------------------------------------------------------------
    def next_prefill(self) -> Optional[Slot]:
        """Slot to run the next prefill chunk for (lowest remaining first,
        so short prompts reach decode — and free their lane — sooner)."""
        cands = self.prefill_slots()
        if not cands:
            return None
        return min(cands, key=lambda s: (s.prefill_len - s.pos, s.idx))

    def prompt_chunk(self, slot: Slot, chunk: int) -> List[int]:
        """The next ``chunk`` prompt tokens for a PREFILL slot (unpadded).

        A preempted request's already-generated tokens are part of the
        prompt here (``slot.prompt``, built once at admission) —
        recompute-style resumption."""
        return slot.prompt[slot.pos:slot.pos + chunk]

    def finish_prefill(self, slot: Slot, first_token: int) -> None:
        """Prefill complete: switch to DECODE with the sampled token."""
        slot.phase = SlotPhase.DECODE
        slot.next_token = int(first_token)

    # -- eviction / preemption ----------------------------------------------
    def evict(self, slot: Slot, kv: PagedKVCache) -> None:
        """Release a finished slot: decref its pages, slot FREE.

        A page shared with another slot stays live (its refcount is still
        positive); an unreferenced page that the prefix cache indexes is
        parked for future reuse; everything else returns to the free
        list. The Mamba2 state needs no reset here — the next occupant's
        first prefill chunk reads zeros (``Model._slot_state_view``)."""
        kv.release(slot.idx)
        slot.req = None
        slot.phase = SlotPhase.FREE
        slot.pos = 0
        slot.prefill_len = 0
        slot.prompt = []
        slot.next_token = None

    def preempt_youngest(self, kv: PagedKVCache,
                         exclude: Optional[int] = None) -> Optional[Slot]:
        """Reclaim pages by preempting the occupied slot with the fewest
        cached tokens (least recompute lost) — decoding or still
        prefilling (a prefilling slot can hold several prompt pages and
        must be preemptible, or a decode step that needs one page with
        only prefill neighbours would dead-end). The request re-enters
        the waiting queue at the FRONT, keeping FIFO completion order
        close; generated tokens (if any) are folded into the prompt on
        re-admission, so nothing is lost.

        exclude: slot index that must not be preempted (the slot the pages
        are being reclaimed *for*)."""
        cands = [s for s in self.slots
                 if not s.free and s.idx != exclude]
        if not cands:
            return None
        victim = min(cands, key=lambda s: (s.pos, -s.idx))
        req = victim.req
        log.info(
            "preempting slot %d (%s, %d cached tokens) to reclaim pages; %s",
            victim.idx, victim.phase.value, victim.pos, kv.occupancy())
        self.waiting.appendleft(req)
        self.evict(victim, kv)
        return victim
