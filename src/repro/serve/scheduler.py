"""Slot-level scheduler for continuous batching.

The scheduler is pure host-side policy: it never touches device arrays.
It owns a bounded, priority-ordered waiting queue and ``num_slots``
slots, each a small state machine::

    FREE ──admit──▶ PREFILL ──last chunk──▶ DECODE ──EOS/max_new──▶ FREE
                       ▲                       │
                       └────── preempt ◀───────┘   (pages reclaimed,
                                                    request re-queued with
                                                    its generated tokens
                                                    folded into the prompt)

Admission happens *the moment a slot frees* — including mid-decode — as
long as the page pool can hold the request's prompt. Prefill is chunked
(the engine interleaves one chunk with one decode step), so a long prompt
never stalls decoding for the slots already running.

Fault tolerance (docs/robustness.md):
  * the waiting queue is ordered by ``(-priority, submission order)`` —
    higher-priority requests admit first, FIFO within a priority class;
  * the queue is bounded (``max_queue``): overflow sheds the
    lowest-priority / newest request with a clean
    ``finish_reason = LoadShedded`` result instead of raising — no
    request is ever silently lost;
  * requests carry a ``deadline_steps`` budget; the engine evicts
    past-deadline slots (and expires queued requests) with
    ``finish_reason = FinishReason.DEADLINE``;
  * ``retries`` counts re-admissions (preemption, replica crash
    recovery) — the :class:`~repro.serve.router.ReplicaRouter` uses it
    for capped exponential requeue backoff.

Eviction rules (``docs/serving.md`` has the worked trace):
  * EOS sampled (when ``eos_id`` is configured)         → evict, free pages.
  * ``len(out_tokens) == max_new_tokens``               → evict, free pages.
  * sequence hit ``max_seq``                            → evict (truncated).
  * deadline expired                                    → evict (expired).
  * page pool exhausted mid-decode                      → preempt the
    youngest decoding slot (recompute-style: its prompt + generated tokens
    re-enter the waiting queue, nothing is lost).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import logging
import time
from collections import deque
from typing import Deque, List, Optional

from repro.obs import Obs

from .kv_cache import PagedKVCache, PagePoolExhausted

log = logging.getLogger(__name__)

_SUBMIT_SEQ = itertools.count()


class FinishReason(enum.Enum):
    """Why a request's ``done`` flag was set (``Request.finish_reason``).

    Every request an engine or router ever accepted ends with exactly one
    of these — the fault-tolerance contract is that *no request is
    silently lost*; chaos tests assert it.
    """
    COMPLETED = "completed"     # EOS sampled or max_new_tokens reached
    TRUNCATED = "truncated"     # max_seq / pool can never grow the sequence
    LOAD_SHED = "load_shed"     # dropped by bounded-queue admission control
    DEADLINE = "deadline"       # deadline_steps expired before completion


#: Alias for the shed outcome — ``req.finish_reason is LoadShedded``.
LoadShedded = FinishReason.LOAD_SHED


@dataclasses.dataclass
class Request:
    """One generation request.

    Attributes:
      tokens: prompt token ids.
      max_new_tokens: generation budget.
      temperature: 0 = greedy; >0 = categorical over logits/T.
      priority: admission priority (higher admits first; load shedding
        drops the lowest first). Default 0.
      deadline_steps: optional completion deadline in engine steps,
        relative to ``arrival``: a request still unfinished once
        ``step - arrival >= deadline_steps`` is evicted with
        ``finish_reason = FinishReason.DEADLINE`` (its partial
        ``out_tokens`` are kept). ``None`` = no deadline.
      out_tokens: generated ids (appended by the engine).
      done: set once the request finishes (see ``finish_reason``).
      finish_reason: why ``done`` was set (:class:`FinishReason`).
      arrival / first_token_step / finish_step: engine-step timestamps for
        latency reporting (arrival is caller-settable; ``Engine.submit``
        stamps the current step when unset, which also anchors the
        deadline clock).
      cached_tokens: prompt tokens served from the prefix cache instead of
        being prefilled, accumulated across (re-)admissions — the
        per-request cache-hit stat surfaced in results.
      retries: re-admissions of this request — preemption requeues and
        replica-crash recoveries. The router's requeue backoff is
        ``min(cap, base · 2^(retries-1))`` router steps.
      arrival_ts / first_token_ts / finish_ts: wall-clock
        (``perf_counter``) twins of the step stamps, taken at the same
        already-host points — the ``req.*_s`` latency families in
        ``obs.metrics`` come from these (docs/observability.md).
    """
    tokens: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    priority: int = 0
    deadline_steps: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[FinishReason] = None
    arrival: Optional[int] = None
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    cached_tokens: int = 0
    retries: int = 0
    arrival_ts: Optional[float] = dataclasses.field(default=None,
                                                    repr=False)
    first_token_ts: Optional[float] = dataclasses.field(default=None,
                                                        repr=False)
    finish_ts: Optional[float] = dataclasses.field(default=None,
                                                   repr=False)
    # queue tiebreaker: submission order within a priority class
    _seq: int = dataclasses.field(default=-1, repr=False, compare=False)

    def finish(self, reason: FinishReason, step: Optional[int]) -> None:
        """Stamp a terminal outcome (exactly once — first reason wins)."""
        if self.done:
            return
        self.done = True
        self.finish_reason = reason
        self.finish_ts = time.perf_counter()
        if self.finish_step is None:
            self.finish_step = step

    def past_deadline(self, step: int) -> bool:
        """Whether ``deadline_steps`` expired at engine step ``step``."""
        return (self.deadline_steps is not None
                and self.arrival is not None
                and step - self.arrival >= self.deadline_steps)

    @property
    def shed(self) -> bool:
        return self.finish_reason is LoadShedded


class SlotPhase(enum.Enum):
    FREE = "free"
    PREFILL = "prefill"
    DECODE = "decode"


@dataclasses.dataclass
class Slot:
    """One batch lane. ``pos`` counts the tokens whose KV/state is cached;
    ``next_token`` is the sampled-but-not-yet-decoded token id;
    ``prompt`` is the admission-time prompt (request tokens + any
    re-queued generated tokens), built once instead of per chunk."""
    idx: int
    phase: SlotPhase = SlotPhase.FREE
    req: Optional[Request] = None
    pos: int = 0
    prefill_len: int = 0          # prompt length incl. re-queued tokens
    prompt: List[int] = dataclasses.field(default_factory=list)
    next_token: Optional[int] = None

    @property
    def free(self) -> bool:
        return self.phase is SlotPhase.FREE


class SlotScheduler:
    """Admission / eviction / preemption policy over a fixed slot set.

    ``max_queue`` bounds the waiting queue: a ``submit`` that would
    overflow it sheds the lowest-priority (newest within a class)
    request — possibly the incoming one — and returns it so the caller
    can surface the :data:`LoadShedded` outcome. ``None`` = unbounded
    (the pre-fault-tolerance behaviour). Requeues of already-admitted
    work (preemption, crash recovery) are exempt from the bound — a
    request that made it into a slot is never shed on its way back.
    """

    def __init__(self, num_slots: int, max_queue: Optional[int] = None,
                 obs: Optional[Obs] = None):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.waiting: Deque[Request] = deque()
        self.max_queue = max_queue
        # The engine passes its Obs so scheduler tallies land in the same
        # registry; a standalone scheduler gets a private one — the
        # counters below are live either way (tests construct bare
        # schedulers and read shed_count/expired_count).
        self.obs = obs if obs is not None else Obs()
        met = self.obs.metrics
        self._c_shed = met.counter("sched.shed_requests", unit="requests",
                                   desc="requests dropped by the bounded "
                                        "admission queue")
        self._c_expired = met.counter("sched.expired_requests",
                                      unit="requests",
                                      desc="requests past deadline_steps")
        self._c_preempt = met.counter("sched.preemptions",
                                      unit="preemptions")

    @property
    def shed_count(self) -> int:
        return self._c_shed.value

    @property
    def expired_count(self) -> int:
        return self._c_expired.value

    @property
    def preemptions(self) -> int:
        return self._c_preempt.value

    # -- queue --------------------------------------------------------------
    def _insert(self, req: Request) -> None:
        """Keep ``waiting`` sorted by (-priority, submission seq)."""
        if req._seq < 0:
            req._seq = next(_SUBMIT_SEQ)
        key = (-req.priority, req._seq)
        for i, r in enumerate(self.waiting):
            if (-r.priority, r._seq) > key:
                self.waiting.insert(i, req)
                return
        self.waiting.append(req)

    def submit(self, req: Request) -> Optional[Request]:
        """Enqueue a request (priority order, FIFO within a class).

        Returns the request shed to stay within ``max_queue`` (``None``
        when nothing was dropped). The shed request — the lowest-priority,
        newest one, possibly ``req`` itself — comes back marked
        ``done`` with ``finish_reason = LoadShedded``; the caller stamps
        its ``finish_step``."""
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            if req._seq < 0:
                req._seq = next(_SUBMIT_SEQ)
            # shed the least valuable: lowest priority, then newest
            victim = min([*self.waiting, req],
                         key=lambda r: (r.priority, -r._seq))
            if victim is not req:
                self.waiting.remove(victim)
                self._insert(req)
            victim.finish(LoadShedded, None)
            self._c_shed.inc()
            log.info("load-shed request (priority=%d, queue=%d/%s)",
                     victim.priority, len(self.waiting), self.max_queue)
            return victim
        self._insert(req)
        return None

    def requeue(self, req: Request, front: bool = True,
                count_retry: bool = True) -> None:
        """Re-enter an already-admitted request (preemption / crash
        recovery): exempt from the queue bound, placed at the FRONT by
        default to keep completion order close to FIFO. Counts a retry
        unless the caller already did (``count_retry=False``)."""
        if count_retry:
            req.retries += 1
        if front:
            self.waiting.appendleft(req)
        else:
            self._insert(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(not s.free for s in self.slots)

    @property
    def queue_room(self) -> float:
        """Free waiting-queue capacity (``inf`` when unbounded)."""
        if self.max_queue is None:
            return float("inf")
        return max(0, self.max_queue - len(self.waiting))

    def prefill_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.phase is SlotPhase.PREFILL]

    def decode_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.phase is SlotPhase.DECODE]

    def occupied_slots(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    # -- deadlines ----------------------------------------------------------
    def expire_deadlines(self, step: int, kv: PagedKVCache) -> List[Request]:
        """Evict slots and drop queued requests whose deadline passed.

        Returns the expired requests (each finished with
        ``FinishReason.DEADLINE``; partial output is kept). Called at the
        top of every engine step, before admission — an expired queued
        request never wastes prefill work."""
        expired: List[Request] = []
        for slot in self.occupied_slots():
            if slot.req.past_deadline(step):
                req = slot.req
                req.finish(FinishReason.DEADLINE, step)
                log.info("deadline expired in slot %d after %d tokens",
                         slot.idx, len(req.out_tokens))
                self.evict(slot, kv)
                expired.append(req)
        if self.waiting:
            keep: List[Request] = []
            for req in self.waiting:
                if req.past_deadline(step):
                    req.finish(FinishReason.DEADLINE, step)
                    expired.append(req)
                else:
                    keep.append(req)
            if len(keep) != len(self.waiting):
                self.waiting = deque(keep)
        self._c_expired.inc(len(expired))
        return expired

    # -- admission ----------------------------------------------------------
    def admit(self, kv: PagedKVCache) -> List[Slot]:
        """Move waiting requests into free slots while pages allow.

        Called at the top of every engine step, so a request is admitted on
        the very step its slot was evicted (admission mid-decode). Stops at
        the first request whose prompt pages don't fit *right now* (the
        queue is priority-ordered; no skipping within it, so no starvation
        inside a priority class). Raises :class:`PagePoolExhausted` via
        ``check_admissible`` for requests that could never fit.

        Prefix caching: the request's prompt is probed against the page
        index first; matched pages are mapped read-shared (only UNSHARED
        pages count against capacity) and the slot starts prefill at the
        first uncached token (``slot.pos``).
        """
        admitted: List[Slot] = []
        for slot in self.slots:
            if not self.waiting:
                break
            if not slot.free:
                continue
            req = self.waiting[0]
            prompt = list(req.tokens) + list(req.out_tokens)
            # The prompt itself must fit; a prompt of exactly max_seq is
            # still servable (it truncates after its first sampled token —
            # the engine's eviction rule), and a preempted request can
            # legitimately come back at that boundary.
            kv.check_admissible(len(prompt))
            match = kv.match_prefix(prompt)
            if not kv.can_fit(len(prompt), match):
                break                              # wait for evictions
            self.waiting.popleft()
            matched = kv.adopt_prefix(slot.idx, match)
            kv.ensure(slot.idx, len(prompt))
            slot.req = req
            slot.phase = SlotPhase.PREFILL
            slot.pos = matched           # prefill starts past the reuse
            slot.prefill_len = len(prompt)
            slot.prompt = prompt
            slot.next_token = None
            req.cached_tokens += matched
            admitted.append(slot)
        if (self.waiting and not admitted
                and all(s.free for s in self.slots)):
            # Nothing running, nothing admitted. With unit-size pages an
            # idle pool can always satisfy any statically-servable
            # request, so this is either a genuine capacity error
            # (check_admissible raises with the pool accounting) or pages
            # are transiently held OUTSIDE the scheduler (fault
            # injection / an external holder) — then wait, don't error.
            req = self.waiting[0]
            kv.check_admissible(len(req.tokens) + len(req.out_tokens))
        return admitted

    # -- prefill ------------------------------------------------------------
    def next_prefill(self) -> Optional[Slot]:
        """Slot to run the next prefill chunk for (lowest remaining first,
        so short prompts reach decode — and free their lane — sooner)."""
        cands = self.prefill_slots()
        if not cands:
            return None
        return min(cands, key=lambda s: (s.prefill_len - s.pos, s.idx))

    def prompt_chunk(self, slot: Slot, chunk: int) -> List[int]:
        """The next ``chunk`` prompt tokens for a PREFILL slot (unpadded).

        A preempted request's already-generated tokens are part of the
        prompt here (``slot.prompt``, built once at admission) —
        recompute-style resumption."""
        return slot.prompt[slot.pos:slot.pos + chunk]

    def finish_prefill(self, slot: Slot, first_token: int) -> None:
        """Prefill complete: switch to DECODE with the sampled token."""
        slot.phase = SlotPhase.DECODE
        slot.next_token = int(first_token)

    # -- eviction / preemption ----------------------------------------------
    def evict(self, slot: Slot, kv: PagedKVCache) -> None:
        """Release a finished slot: decref its pages, slot FREE.

        A page shared with another slot stays live (its refcount is still
        positive); an unreferenced page that the prefix cache indexes is
        parked for future reuse; everything else returns to the free
        list. The Mamba2 state needs no reset here — the next occupant's
        first prefill chunk reads zeros (``Model._slot_state_view``)."""
        kv.release(slot.idx)
        slot.req = None
        slot.phase = SlotPhase.FREE
        slot.pos = 0
        slot.prefill_len = 0
        slot.prompt = []
        slot.next_token = None

    def preempt(self, slot: Slot, kv: PagedKVCache) -> Request:
        """Preempt one occupied slot: pages reclaimed, request re-queued
        at the front with its generated tokens folded into the prompt on
        re-admission (recompute-style — nothing is lost, greedy output
        stays token-identical). Idempotent with respect to request state:
        everything the resumed prefill needs is derivable from
        ``req.tokens + req.out_tokens``; ``arrival`` / ``cached_tokens``
        / ``first_token_step`` stamps are untouched."""
        req = slot.req
        log.info(
            "preempting slot %d (%s, %d cached tokens) to reclaim pages; %s",
            slot.idx, slot.phase.value, slot.pos, kv.occupancy())
        self._c_preempt.inc()
        self.obs.annotate("preempt", slot=slot.idx,
                          phase=slot.phase.value, cached=slot.pos)
        self.evict(slot, kv)
        self.requeue(req, front=True)
        return req

    def preempt_youngest(self, kv: PagedKVCache,
                         exclude: Optional[int] = None) -> Optional[Slot]:
        """Reclaim pages by preempting the occupied slot with the fewest
        cached tokens (least recompute lost) — decoding or still
        prefilling (a prefilling slot can hold several prompt pages and
        must be preemptible, or a decode step that needs one page with
        only prefill neighbours would dead-end). The request re-enters
        the waiting queue at the FRONT, keeping FIFO completion order
        close; generated tokens (if any) are folded into the prompt on
        re-admission, so nothing is lost.

        exclude: slot index that must not be preempted (the slot the pages
        are being reclaimed *for*)."""
        cands = [s for s in self.slots
                 if not s.free and s.idx != exclude]
        if not cands:
            return None
        victim = min(cands, key=lambda s: (s.pos, -s.idx))
        self.preempt(victim, kv)
        return victim

    # -- crash recovery -----------------------------------------------------
    def drain_requests(self, kv: PagedKVCache) -> List[Request]:
        """Pull every in-flight request out of this scheduler (crash
        recovery: the router requeues them onto healthy replicas).

        Slots are evicted (host-side page bookkeeping — harmless even
        when the device state is gone) and the waiting queue is cleared.
        Returns the unfinished requests in deterministic order: waiting
        queue first (they were next in line nowhere else), then slots by
        index. ``out_tokens`` / ``arrival`` / ``cached_tokens`` stamps
        travel with each request — re-prefill on the adopting replica
        folds the generated tokens into the prompt exactly like a
        preemption requeue, so greedy output is token-identical."""
        out: List[Request] = [r for r in self.waiting if not r.done]
        self.waiting.clear()
        for slot in self.occupied_slots():
            req = slot.req
            try:
                self.evict(slot, kv)
            except Exception:          # crashed replica: best-effort cleanup
                log.exception("evict during crash recovery failed "
                              "(slot %d)", slot.idx)
                slot.req, slot.phase = None, SlotPhase.FREE
                slot.pos, slot.prefill_len = 0, 0
                slot.prompt, slot.next_token = [], None
            if req is not None and not req.done:
                out.append(req)
        # a request can appear once only (a slot's req is never queued),
        # but be defensive about double-recovery
        seen, uniq = set(), []
        for r in out:
            if id(r) not in seen:
                seen.add(id(r))
                uniq.append(r)
        return uniq
