"""Serving: batched request engine over prefill/decode steps."""
from .engine import Engine, Request
