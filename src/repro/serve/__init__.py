"""Serving: continuous-batching engine over a paged LUT-aware KV cache.

Public surface:
  * :class:`Engine` — slot-scheduled continuous batching (the default).
  * :class:`BatchToCompletionEngine` — legacy fixed-batch baseline.
  * :class:`Request` — one generation request.
  * :class:`PagedKVCache` / :class:`PageAllocator` /
    :class:`PagePoolExhausted` — the paged cache memory system.
  * :class:`SlotScheduler` — admission / eviction / preemption policy.

See docs/serving.md for the engine lifecycle and cache layout.
"""
from .engine import BatchToCompletionEngine, Engine, greedy_generate
from .kv_cache import (PageAllocator, PagePoolExhausted, PagedKVCache,
                       PageTable)
from .scheduler import Request, Slot, SlotPhase, SlotScheduler

__all__ = [
    "BatchToCompletionEngine", "Engine", "greedy_generate",
    "PageAllocator", "PagePoolExhausted", "PagedKVCache", "PageTable",
    "Request", "Slot", "SlotPhase", "SlotScheduler",
]
