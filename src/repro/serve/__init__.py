"""Serving: continuous-batching engine over a paged LUT-aware KV cache.

Public surface:
  * :class:`Engine` — slot-scheduled continuous batching (the default);
    pass ``mesh=`` for tensor-parallel serving over a device mesh.
  * :class:`ReplicaRouter` — data-parallel dispatch across engine
    replicas (``from_mesh`` carves a (data, model) mesh into TP groups).
  * :class:`BatchToCompletionEngine` — legacy fixed-batch baseline.
  * :class:`Request` — one generation request.
  * :class:`PagedKVCache` / :class:`PageAllocator` /
    :class:`PagePoolExhausted` — the paged cache memory system.
  * :class:`SlotScheduler` — admission / eviction / preemption policy.

See docs/serving.md for the engine lifecycle, cache layout and the
sharded-serving mesh recipes.
"""
from .engine import BatchToCompletionEngine, Engine, greedy_generate
from .kv_cache import (PageAllocator, PagePoolExhausted, PagedKVCache,
                       PageTable)
from .router import ReplicaRouter
from .scheduler import Request, Slot, SlotPhase, SlotScheduler

__all__ = [
    "BatchToCompletionEngine", "Engine", "greedy_generate",
    "PageAllocator", "PagePoolExhausted", "PagedKVCache", "PageTable",
    "ReplicaRouter", "Request", "Slot", "SlotPhase", "SlotScheduler",
]
