"""Serving: continuous-batching engine over a paged LUT-aware KV cache.

Public surface:
  * :class:`Engine` — slot-scheduled continuous batching (the default);
    pass ``mesh=`` for tensor-parallel serving over a device mesh.
  * :class:`ReplicaRouter` — data-parallel dispatch across engine
    replicas (``from_mesh`` carves a (data, model) mesh into TP groups).
  * :class:`BatchToCompletionEngine` — legacy fixed-batch baseline.
  * :class:`Request` — one generation request.
  * :class:`PagedKVCache` / :class:`PageAllocator` /
    :class:`PagePoolExhausted` — the paged cache memory system, with
    ref-counted pages and automatic shared-prefix reuse
    (:class:`PrefixCache` / :class:`PrefixMatch`).
  * :class:`SlotScheduler` — admission / eviction / preemption policy.

See docs/serving.md for the engine lifecycle, cache layout, prefix
caching, and the sharded-serving mesh recipes; docs/speculative.md for
the self-speculative draft/verify/rollback loop
(``Engine(spec_decode=SpecConfig(...))``).
"""
from .engine import BatchToCompletionEngine, Engine, greedy_generate
from .kv_cache import (PageAllocator, PagePoolExhausted, PagedKVCache,
                       PageTable, PrefixCache, PrefixMatch)
from .router import ReplicaRouter
from .scheduler import Request, Slot, SlotPhase, SlotScheduler
from .speculative import (Drafter, ModelDrafter, NgramDrafter, SpecConfig,
                          accept_tokens)

__all__ = [
    "BatchToCompletionEngine", "Drafter", "Engine", "greedy_generate",
    "ModelDrafter", "NgramDrafter", "PageAllocator", "PagePoolExhausted",
    "PagedKVCache", "PageTable", "PrefixCache", "PrefixMatch",
    "ReplicaRouter", "Request", "Slot", "SlotPhase", "SlotScheduler",
    "SpecConfig", "accept_tokens",
]
