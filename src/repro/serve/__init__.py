"""Serving: continuous-batching engine over a paged LUT-aware KV cache.

Public surface:
  * :class:`Engine` — slot-scheduled continuous batching (the default);
    pass ``mesh=`` for tensor-parallel serving over a device mesh.
  * :class:`ReplicaRouter` — data-parallel dispatch across engine
    replicas (``from_mesh`` carves a (data, model) mesh into TP groups),
    with per-replica health tracking (:class:`ReplicaHealth`), a step
    watchdog, graceful drain, and crash recovery.
  * :class:`BatchToCompletionEngine` — legacy fixed-batch baseline.
  * :class:`Request` — one generation request (``priority``,
    ``deadline_steps``, ``finish_reason``: :class:`FinishReason`).
  * :class:`PagedKVCache` / :class:`PageAllocator` /
    :class:`PagePoolExhausted` — the paged cache memory system, with
    ref-counted pages and automatic shared-prefix reuse
    (:class:`PrefixCache` / :class:`PrefixMatch`).
  * :class:`SlotScheduler` — admission / eviction / preemption policy,
    priority-ordered bounded queue, deadline expiry, load shedding.
  * :class:`DegradationPolicy` — pressure-driven degradation ladder
    (spec off → prefill shrink → admission stop, with hysteresis).
  * :class:`FaultSchedule` / :class:`FaultInjector` — deterministic
    fault injection (crashes, step errors, pool squeezes) for chaos
    tests and ``serve_bench --chaos``.

See docs/serving.md for the engine lifecycle, cache layout, prefix
caching, and the sharded-serving mesh recipes; docs/speculative.md for
the self-speculative draft/verify/rollback loop
(``Engine(spec_decode=SpecConfig(...))``); docs/robustness.md for the
fault-tolerance layer (health states, degradation, fault cookbook).
"""
from .engine import (BatchToCompletionEngine, DegradationPolicy, Engine,
                     MODE_NO_SPEC, MODE_NORMAL, MODE_SHRINK_PREFILL,
                     MODE_STOP_ADMIT, greedy_generate)
from .faults import Fault, FaultInjector, FaultSchedule, ReplicaCrashed
from .kv_cache import (PageAllocator, PagePoolExhausted, PagedKVCache,
                       PageTable, PrefixCache, PrefixMatch)
from .router import ReplicaHealth, ReplicaRouter, ReplicaStatus
from .scheduler import (FinishReason, LoadShedded, Request, Slot, SlotPhase,
                        SlotScheduler)
from .speculative import (Drafter, ModelDrafter, NgramDrafter, SpecConfig,
                          accept_tokens)

__all__ = [
    "BatchToCompletionEngine", "DegradationPolicy", "Drafter", "Engine",
    "Fault", "FaultInjector", "FaultSchedule", "FinishReason",
    "LoadShedded", "MODE_NORMAL", "MODE_NO_SPEC", "MODE_SHRINK_PREFILL",
    "MODE_STOP_ADMIT", "ModelDrafter", "NgramDrafter", "PageAllocator",
    "PagePoolExhausted", "PagedKVCache", "PageTable", "PrefixCache",
    "PrefixMatch", "ReplicaCrashed", "ReplicaHealth", "ReplicaRouter",
    "ReplicaStatus", "Request", "Slot", "SlotPhase", "SlotScheduler",
    "SpecConfig", "accept_tokens", "greedy_generate",
]
