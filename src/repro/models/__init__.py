"""Model zoo: every assigned architecture, with LUT-DLA projections."""
from .config import ModelConfig
