"""Shared model building blocks (RMSNorm, RoPE, attention, SwiGLU MLP).

Every projection routes through :func:`proj` — a LutLinear — so the paper's
VQ-AMM technique is a first-class switch for all architectures. Functions
return ``(out, recon)`` where ``recon`` is the accumulated reconstruction
loss (non-zero only in ``lut_train`` mode).

Attention masks are *parametric* (q_offset / window / prefix_len scalars),
never materialised as (S, T) tensors outside the score computation — this is
what lets the 32k/500k shapes lower with bounded memory (the chunked
online-softmax path builds only (S, chunk) mask tiles per scan step).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lut import QuantConfig, lut_linear_apply, lut_linear_init
from repro.kernels.flash_decode import flash_decode_paged

Params = Dict


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x (B, S, H, D), positions (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, S, half)
    cos = jnp.cos(angles)[..., None, :]                          # (B, S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def proj(p: Params, x: jax.Array, qc: QuantConfig) -> Tuple[jax.Array, jax.Array]:
    """One LutLinear projection (out, recon)."""
    return lut_linear_apply(p, x, qc)


def init_proj(key, k, n, qc: QuantConfig, bias=False, dtype=jnp.float32):
    return lut_linear_init(key, k, n, qc, bias=bias, dtype=dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def mask_tile(qi: jax.Array, kj: jax.Array, window, prefix_len) -> jax.Array:
    """(s, t) boolean attention mask from absolute positions.

    qi (s,), kj (t,): query/key absolute positions. window: 0 = global,
    >0 = sliding window. prefix_len: positions < prefix_len attend
    bidirectionally within the prefix (prefix-LM / VLM image tokens).
    """
    m = kj[None, :] <= qi[:, None]
    win = jnp.asarray(window)
    m = m & jnp.where(win > 0, kj[None, :] > qi[:, None] - win, True)
    pl = jnp.asarray(prefix_len)
    m = m | ((qi[:, None] < pl) & (kj[None, :] < pl))
    return m


def _trivial_start(kv_start) -> bool:
    """True when ``kv_start`` is the static no-op value (int 0).

    ``kv_start`` masks out key positions ``< kv_start`` — the left-pad
    convention for batch-to-completion serving (prompts right-aligned, pad
    ids occupying cache rows ``[0, pad_len)``). Keeping the zero case a
    *Python* check preserves the exact HLO (and bit-identical outputs) of
    every pre-existing call site.
    """
    return isinstance(kv_start, int) and kv_start == 0


def _start_mask(kv_start, kj: jax.Array, b: int) -> jax.Array:
    """(B, t) boolean mask keeping keys at positions >= kv_start.

    kv_start: scalar or (B,) first *valid* key position per row.
    """
    ks = jnp.broadcast_to(jnp.asarray(kv_start, jnp.int32), (b,))
    return kj[None, :] >= ks[:, None]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg, qc: QuantConfig, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": init_proj(ks[0], d, h * hd, qc, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_proj(ks[1], d, kvh * hd, qc, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_proj(ks[2], d, kvh * hd, qc, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_proj(ks[3], h * hd, d, qc, dtype=dtype),
        "norm": jnp.zeros((d,), dtype),
    }


def _sdpa(q, k, v, q_offset, window, prefix_len, impl="naive", chunk=1024,
          ulysses=None, kv_start=0):
    """Grouped-query SDPA. q (B,S,H,D), k/v (B,T,KVH,D).

    kv_start: scalar or (B,) — key positions < kv_start are masked out
    (left-padded batched prompts; see :func:`_trivial_start`).
    """
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    scale = d ** -0.5
    if impl == "chunked" and t > chunk:
        out = _sdpa_chunked(qg, k, v, scale, chunk, q_offset, window,
                            prefix_len, ulysses, kv_start=kv_start)
        return out.reshape(b, s, h, d)
    qi = jnp.arange(s) + q_offset
    kj = jnp.arange(t)
    mask = mask_tile(qi, kj, window, prefix_len)                 # (s, t)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if _trivial_start(kv_start):
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    else:                                        # (B, s, t) per-row mask
        mb = mask[None] & _start_mask(kv_start, kj, b)[:, None, :]
        scores = jnp.where(mb[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)


def _sdpa_chunked(qg, k, v, scale, chunk, q_offset, window, prefix_len,
                  ulysses=None, kv_start=0):
    """Online-softmax attention scanning KV chunks (flash-style memory)."""
    b, s, kvh, g, d = qg.shape
    t = k.shape[1]
    pad = (-t) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nchunks = k.shape[1] // chunk
    kc = jnp.moveaxis(k.reshape(b, nchunks, chunk, kvh, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nchunks, chunk, kvh, d), 1, 0)
    qi = jnp.arange(s) + q_offset

    def _c(x, spec):
        return jax.lax.with_sharding_constraint(x, spec) \
            if ulysses is not None else x

    def step(carry, inp):
        m_run, l_run, acc = carry
        ci, kb, vb = inp
        kj = ci * chunk + jnp.arange(chunk)
        mk = mask_tile(qi, kj, window, prefix_len)               # (s, chunk)
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, kb,
                        preferred_element_type=jnp.float32) * scale
        if _trivial_start(kv_start):
            sc = jnp.where(mk[None, None, None], sc, -1e30)
        else:
            mb = mk[None] & _start_mask(kv_start, kj, b)[:, None, :]
            sc = jnp.where(mb[:, None, None], sc, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        # keep the V stream in its storage dtype (bf16): casting vb to f32
        # here hoists a whole-cache f32 convert out of the scan (2× cache
        # HBM traffic + f32 collectives). The MXU accumulates in f32 via
        # preferred_element_type; only the (small) p tile is cast.
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, s, d), jnp.float32)
    if ulysses is not None:
        # pin the online-softmax carries to the query's seq-sharding, or
        # GSPMD replicates the carry and all-gathers the probs per chunk
        b_ax = ulysses["q"][0]
        m0 = _c(m0, jax.sharding.PartitionSpec(b_ax, None, None, "model"))
        l0 = _c(l0, jax.sharding.PartitionSpec(b_ax, None, None, "model"))
        acc0 = _c(acc0, jax.sharding.PartitionSpec(
            b_ax, None, None, "model", None))
    (_, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).astype(qg.dtype)              # (b,s,kvh,g,d)


def rope_interleaved_hd(x: jax.Array, positions: jax.Array,
                        theta: float) -> jax.Array:
    """Interleaved (GPT-J pairing) RoPE for hd-major layout.

    x (B, S, D, H): pairs are (2i, 2i+1) along D, so the rotation is local
    to any even-sized shard of D — no cross-shard halves like the classic
    rotate-half form."""
    b, s, d, h = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (B?,S,half)
    cos = jnp.cos(ang)[..., None]                                 # (B?,S,half,1)
    sin = jnp.sin(ang)[..., None]
    xr = x.astype(jnp.float32).reshape(b, s, half, 2, h)
    x1, x2 = xr[..., 0, :], xr[..., 1, :]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-2)
    return out.reshape(b, s, d, h).astype(x.dtype)


def _sdpa_hd(q, k, v, q_offset, window, prefix_len, impl="naive",
             chunk=1024):
    """GQA SDPA in hd-major layout. q (B,S,D,H), k/v (B,T,D,KVH)."""
    b, s, d, h = q.shape
    t, kvh = k.shape[1], k.shape[3]
    g = h // kvh
    qg = q.reshape(b, s, d, kvh, g)
    scale = d ** -0.5
    if impl == "chunked" and t > chunk:
        out = _sdpa_hd_chunked(qg, k, v, scale, chunk, q_offset, window,
                               prefix_len)                        # (b,s,k,g,d)
    else:
        qi = jnp.arange(s) + q_offset
        kj = jnp.arange(t)
        mask = mask_tile(qi, kj, window, prefix_len)
        scores = jnp.einsum("bsdkg,btdk->bkgst", qg, k,
                            preferred_element_type=jnp.float32) * scale
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btdk->bkgsd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = jnp.moveaxis(out, 3, 1)                             # (b,s,k,g,d)
    # back to hd-major flat (B, S, D·H)
    return jnp.transpose(out, (0, 1, 4, 2, 3)).reshape(b, s, d * h) \
        .astype(q.dtype)


def _sdpa_hd_chunked(qg, k, v, scale, chunk, q_offset, window, prefix_len):
    """Online-softmax over KV chunks, hd-major layout. Returns
    (b, s, kvh, g, d) fp32."""
    b, s, d, kvh, g = qg.shape
    t = k.shape[1]
    pad = (-t) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nchunks = k.shape[1] // chunk
    kc = jnp.moveaxis(k.reshape(b, nchunks, chunk, d, kvh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nchunks, chunk, d, kvh), 1, 0)
    qi = jnp.arange(s) + q_offset

    def step(carry, inp):
        m_run, l_run, acc = carry
        ci, kb, vb = inp
        kj = ci * chunk + jnp.arange(chunk)
        mk = mask_tile(qi, kj, window, prefix_len)
        sc = jnp.einsum("bsdkg,btdk->bkgst", qg, kb,
                        preferred_element_type=jnp.float32) * scale
        sc = jnp.where(mk[None, None, None], sc, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btdk->bkgsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, s, d), jnp.float32)
    (_, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1)                                # (b,s,k,g,d)


def _sdpa_local(q, k, v, window: int):
    """Block-local sliding-window attention (q_offset=0, S % window == 0).

    Each query block of W positions attends only to its own and the
    previous key block — S×2W work instead of S×T. For gemma3's 5:1
    local:global pattern this removes ~16× of the attention compute and
    score traffic on 5/6 of the layers at 32k context. [§Perf I8]
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    w = window
    nb = s // w
    scale = d ** -0.5
    qb = q.reshape(b, nb, w, kvh, g, d)
    kb = k.reshape(b, nb, w, kvh, d)
    vb = v.reshape(b, nb, w, kvh, d)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kcat = jnp.concatenate([kprev, kb], axis=2)          # (b, nb, 2w, kvh, d)
    vcat = jnp.concatenate([vprev, vb], axis=2)
    scores = jnp.einsum("bnwkgd,bntkd->bnkgwt", qb, kcat,
                        preferred_element_type=jnp.float32) * scale
    # relative mask: query abs = n·w + i, key abs = n·w + (t − w)
    qi = jnp.arange(w)[:, None]
    kt = jnp.arange(2 * w)[None, :] - w
    rel = qi - kt
    mask = (rel >= 0) & (rel < w)                         # causal ∧ window
    first = (jnp.arange(nb) == 0)[:, None, None]          # block −1 invalid
    mask = mask[None] & ~(first & (kt < 0)[None])         # (nb, w, 2w)
    scores = jnp.where(mask[None, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnkgwt,bntkd->bnwkgd", probs.astype(v.dtype), vcat,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, d).astype(q.dtype)


def _sdpa_verify(q, k_cache, v_cache, k_new, v_new, pos, window):
    """Multi-token verify over a read-only cache + the proposed tokens.

    The speculative-decoding verify step scores S proposed tokens per row
    in one call: query t of row b sits at absolute position pos[b] + t and
    attends (a) committed cache rows < pos[b] and (b) the proposed tokens
    0..t themselves, whose K/V arrive fresh — they are NOT in the cache
    yet (the caller scatters the slab afterwards, exactly like decode).
    Cache rows >= pos[b] are masked: they hold draft-phase or stale KV.

    q (B,S,H,D); k_cache/v_cache (B,T,KVH,D); k_new/v_new (B,S,KVH,D).
    pos: (B,) per-row committed lengths (-1 = inactive lane: every cache
    row is masked and the row attends only its own fresh tokens — the
    output is discarded by the caller). Returns (B, S, H·D).
    """
    b, s, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    scale = d ** -0.5
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))
    win = jnp.asarray(window)
    qi = jnp.arange(s)                                   # in-round index
    # cache part: kj < pos[b], windowed against absolute query positions
    kj = jnp.arange(t)
    mc = kj[None, None, :] < pos_b[:, None, None]        # (B, 1, T)
    q_abs = pos_b[:, None] + qi[None, :]                 # (B, S)
    mc = mc & jnp.where(win > 0,
                        kj[None, None, :] > q_abs[:, :, None] - win, True)
    # self part: fresh token j visible to query t iff j <= t (causal)
    ms = qi[None, :] <= qi[:, None]                      # (S, S)
    ms = ms & jnp.where(win > 0, qi[None, :] > qi[:, None] - win, True)
    k_all = jnp.concatenate([k_cache, k_new], axis=1)    # (B, T+S, KVH, D)
    v_all = jnp.concatenate([v_cache, v_new], axis=1)
    mask = jnp.concatenate(
        [jnp.broadcast_to(mc, (b, s, t)),
         jnp.broadcast_to(ms[None], (b, s, s))], axis=-1)  # (B, S, T+S)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k_all,
                    preferred_element_type=jnp.float32) * scale
    sc = jnp.where(mask[:, None, None], sc, -1e30)
    probs = jax.nn.softmax(sc, axis=-1).astype(v_all.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_all)
    return out.reshape(b, s, h * d).astype(q.dtype)


def _sdpa_decode_combine(q, k_cache, v_cache, k_new, v_new, pos, window,
                         kv_start=0):
    """Single-token decode over an *unmodified* cache + the new token.

    Two-part online softmax: the cache part (positions < pos) and the self
    term (the new token), combined without ever materialising an updated
    cache — the caller writes the (tiny) new-token slab back once per step
    outside the layer loop. [§Perf I5]

    q (B,1,H,D); k_cache/v_cache (B,T,KVH,D); k_new/v_new (B,1,KVH,D).
    pos: scalar, or (B,) per-row sequence lengths (continuous batching —
    each slot decodes at its own position). kv_start: scalar or (B,) first
    valid cache row (left-padded batch-to-completion prompts).
    """
    b, _, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    scale = d ** -0.5
    kj = jnp.arange(t)
    pos_a = jnp.asarray(pos)
    per_row = pos_a.ndim > 0 or not _trivial_start(kv_start)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    if per_row:
        pos_b = jnp.broadcast_to(pos_a, (b,))
        mask = kj[None, :] < pos_b[:, None]                      # (B, T)
        win = jnp.asarray(window)
        mask = mask & jnp.where(
            win > 0, kj[None, :] > pos_b[:, None] - win, True)
        if not _trivial_start(kv_start):
            mask = mask & _start_mask(kv_start, kj, b)
        sc = jnp.where(mask[:, None, None, :], sc, -1e30)
    else:
        mask = (kj < pos)
        win = jnp.asarray(window)
        mask = mask & jnp.where(win > 0, kj > pos - win, True)   # (T,)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    s_new = jnp.einsum("bkgd,bkd->bkg", qg, k_new[:, 0],
                       preferred_element_type=jnp.float32) * scale
    m = jnp.maximum(jnp.max(sc, axis=-1), s_new)                 # (b,k,g)
    p_old = jnp.exp(sc - m[..., None])
    p_new = jnp.exp(s_new - m)
    denom = jnp.sum(p_old, axis=-1) + p_new
    out = (jnp.einsum("bkgt,btkd->bkgd", p_old.astype(v_cache.dtype),
                      v_cache, preferred_element_type=jnp.float32)
           + p_new[..., None] * v_new[:, 0, :, None, :])
    out = out / denom[..., None]
    return out.reshape(b, 1, h * d).astype(q.dtype)


def _ulysses_specs(q, k):
    """Sequence-parallel (DeepSpeed-Ulysses) resharding decision.

    When the kv heads don't divide the model axis, head/hd sharding of the
    S×T score contraction makes GSPMD all-reduce full score tensors
    (hundreds of GB at 32k). Instead, reshard Q/K/V to *sequence*-sharded
    over the model axis (an all-to-all), attend locally with full heads,
    and reshard back. Returns (spec, out_spec) or (None, None) when not
    applicable / no ambient mesh. [§Perf I6]
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        names = getattr(am, "axis_names", ())
        if "model" not in names:
            return None, None
        msize = am.shape["model"]
        if msize <= 1:
            return None, None
        kvh = k.shape[2]
        b, s, t = q.shape[0], q.shape[1], k.shape[1]
        if kvh % msize == 0:
            return None, None                  # heads shard fine: no need
        if s % msize or s <= msize or t % msize:
            return None, None
        from jax.sharding import PartitionSpec as _P
        b_ax = "data" if ("data" in names and b % am.shape["data"] == 0
                          and b >= am.shape["data"]) else None
        return {
            "q": _P(b_ax, "model", None, None),      # queries: seq-sharded
            "kv": _P(b_ax, None, None, None),        # keys/values: gathered
            "out": _P(b_ax, None, None, "model"),    # back to hd-sharded
        }, True
    except Exception:
        return None, None


def attention(p: Params, x: jax.Array, cfg, qc: QuantConfig,
              q_offset=0, window=0, prefix_len=0,
              cache: Optional[Params] = None,
              decode_slab: bool = False,
              kv_start=0,
              paged_phys: Optional[jax.Array] = None,
              flash_impl: str = "ref",
              ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Pre-norm GQA attention block. Returns (out, recon, new_cache).

    Args:
      p: layer params {"wq","wk","wv","wo","norm"} (LutLinear pytrees).
      x: (B, S, D) residual-stream input.
      q_offset: absolute position of the first query — a scalar, or a
        (B,) array of per-row positions (continuous-batching decode,
        where every slot sits at a different sequence length). Per-row
        offsets are only supported on the ``decode_slab`` path.
      window: 0 = global attention, >0 = sliding window of that width.
      prefix_len: positions < prefix_len attend bidirectionally (VLM).
      cache: KV cache per cfg.head_layout —
        "heads": {"k": (B, T, KVH, HD), ...}; "hd": {"k": (B, T, HD, KVH)}.
        New K/V are written at q_offset (scalar offsets only).
      decode_slab: single-token decode — the cache is consumed strictly
        read-only and new_cache is just the new-token
        {"k": (B, 1, KVH, HD), "v": ...} slab (the caller owns the write,
        e.g. a paged-cache scatter at per-slot positions).
      kv_start: scalar or (B,) — cache rows < kv_start are masked out
        (the left-pad convention: batch-to-completion engines right-align
        prompts, so rows [0, pad_len) hold pad garbage that must never be
        attended; see docs/serving.md).
      paged_phys: (B, NP) trash-redirected physical page ids. When set
        (single-token ``decode_slab`` only), ``cache`` is one layer's
        slice of the paged POOL ``{"k": (P+1, page, KVH, HD), ...}`` and
        decode runs the flash kernel straight off the pages — no dense
        per-slot view exists (see kernels/flash_decode.py).
      flash_impl: "pallas" | "ref" — concrete flash impl (the "auto" /
        "gather" resolution happens in ``model.decode_paged``).

    Returns: (out (B, S, D), recon scalar, new_cache or slab or None).
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, r1 = proj(p["wq"], xn, qc)
    k, r2 = proj(p["wk"], xn, qc)
    v, r3 = proj(p["wv"], xn, qc)
    qo = jnp.asarray(q_offset)
    if qo.ndim == 0:
        positions = (jnp.arange(s) + q_offset)[None, :]          # (1, S)
    else:                                                        # (B, S)
        positions = qo[:, None] + jnp.arange(s)[None, :]
    if cfg.head_layout == "hd":
        # hd-major: projection columns are (hd, head) ordered; the reshape
        # is shard-aligned with the column-parallel weight sharding.
        q = rope_interleaved_hd(q.reshape(b, s, hd, h), positions,
                                cfg.rope_theta)
        k = rope_interleaved_hd(k.reshape(b, s, hd, kvh), positions,
                                cfg.rope_theta)
        v = v.reshape(b, s, hd, kvh)
    else:
        q = rope(q.reshape(b, s, h, hd), positions, cfg.rope_theta)
        k = rope(k.reshape(b, s, kvh, hd), positions, cfg.rope_theta)
        v = v.reshape(b, s, kvh, hd)
    if decode_slab and cache is not None and cfg.head_layout != "hd":
        # vector-quantized pool: cache["k"/"v"] are uint8 codes and the
        # per-layer codebook slice rides along; the fresh slab stays fp
        # (model._encode_rows quantizes at the scatter site) and the
        # kernel dequantizes / LUT-accumulates the pool in place.
        cb = cache.get("codebook")
        if s == 1:
            if paged_phys is not None:
                # paged flash decode: cache is the raw page pool slice;
                # the kernel walks it through the page table in place.
                out = flash_decode_paged(
                    q, cache["k"], cache["v"], k, v, paged_phys,
                    q_offset, window=window, kv_start=kv_start,
                    impl=flash_impl, codebook=cb,
                    interpret=jax.default_backend() != "tpu")
            else:
                out = _sdpa_decode_combine(
                    q, cache["k"].astype(x.dtype),
                    cache["v"].astype(x.dtype),
                    k.astype(x.dtype), v.astype(x.dtype),
                    q_offset, window, kv_start=kv_start)
        else:
            # multi-token verify (speculative decoding): the cache stays
            # read-only; the S proposed tokens attend committed rows
            # < q_offset[b] plus each other causally (kv_start is the
            # paged engine's static 0 here).
            out = _sdpa_verify(q, cache["k"].astype(x.dtype),
                               cache["v"].astype(x.dtype),
                               k.astype(x.dtype), v.astype(x.dtype),
                               q_offset, window)
        out, r4 = proj(p["wo"], out, qc)
        if cb is not None:      # quantized pool: slab must stay fp
            slab = {"k": k, "v": v}
        else:
            slab = {"k": k.astype(cache["k"].dtype),
                    "v": v.astype(cache["v"].dtype)}
        return out, r1 + r2 + r3 + r4, slab

    k_fresh, v_fresh = k, v
    new_cache = None
    if cache is not None:
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), q_offset, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), q_offset, axis=1)
        new_cache = {"k": kc, "v": vc}
        k, v = kc.astype(x.dtype), vc.astype(x.dtype)
    # block-local fast path: static window + q_offset==0 (train/prefill).
    # All keys a window can see are inside the current sequence, so the
    # fresh (pre-cache) K/V suffice. [§Perf I8]
    if (isinstance(window, int) and window > 0 and s > 1
            and isinstance(q_offset, int) and q_offset == 0
            and s % window == 0 and isinstance(prefix_len, int)
            and prefix_len == 0 and cfg.head_layout != "hd"
            and _trivial_start(kv_start)):
        out = _sdpa_local(q, k_fresh, v_fresh, window).reshape(b, s, h * hd)
        out, r4 = proj(p["wo"], out, qc)
        return out, r1 + r2 + r3 + r4, new_cache

    # decode (s==1): the full score row is tiny — use the naive path. The
    # chunked path would reshape the (possibly seq-sharded) T dim, forcing
    # GSPMD to all-gather the whole cache; the naive einsum instead reduces
    # over the sharded T (flash-decoding semantics for free). [§Perf I4]
    impl = "naive" if s == 1 else cfg.attn_impl
    if cfg.head_layout == "hd":
        if not _trivial_start(kv_start):
            raise NotImplementedError(
                "kv_start masking is not supported for head_layout='hd'")
        out = _sdpa_hd(q, k, v, q_offset, window, prefix_len,
                       impl, cfg.attn_chunk)
    else:
        specs, apply_u = (None, False)
        if s > 1:                              # prefill / train
            specs, apply_u = _ulysses_specs(q, k)
        if apply_u:
            q = jax.lax.with_sharding_constraint(q, specs["q"])
            k = jax.lax.with_sharding_constraint(k, specs["kv"])
            v = jax.lax.with_sharding_constraint(v, specs["kv"])
        out = _sdpa(q, k, v, q_offset, window, prefix_len,
                    impl, cfg.attn_chunk,
                    ulysses=specs if apply_u else None, kv_start=kv_start)
        if apply_u:                            # all-to-all back to hd-shard
            out = jax.lax.with_sharding_constraint(out, specs["out"])
        out = out.reshape(b, s, h * hd)
    out, r4 = proj(p["wo"], out, qc)
    return out, r1 + r2 + r3 + r4, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, cfg, qc: QuantConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wg": init_proj(ks[0], d, f, qc, dtype=dtype),
        "wu": init_proj(ks[1], d, f, qc, dtype=dtype),
        "wd": init_proj(ks[2], f, d, qc, dtype=dtype),
        "norm": jnp.zeros((d,), dtype),
    }


def mlp(p: Params, x: jax.Array, cfg, qc: QuantConfig):
    """Pre-norm SwiGLU MLP. Returns (out, recon)."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    g, r1 = proj(p["wg"], xn, qc)
    u, r2 = proj(p["wu"], xn, qc)
    d_, r3 = proj(p["wd"], jax.nn.silu(g) * u, qc)
    return d_, r1 + r2 + r3
