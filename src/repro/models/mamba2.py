"""Mamba2 block — SSD (state-space duality) chunked algorithm (arXiv:2405.21060).

Train/prefill use the chunked block-decomposition: intra-chunk "attention-like"
quadratic term + inter-chunk linear state recurrence (associative over chunks).
Decode is the O(1)-per-token recurrent update on a (B, H, P, N) state.

Projections (in_proj / out_proj) are LutLinear — the dominant FLOPs of an SSM
block are these dense GEMMs, so LUT-DLA applies directly (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lut import QuantConfig
from .layers import init_proj, proj, rms_norm

Params = Dict


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., l) -> (..., l, l): S[i, j] = sum_{j < k <= i} x[k], -inf above
    the diagonal. exp(segsum) is the decay matrix L."""
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    l = x.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def init_mamba2(key, cfg, qc: QuantConfig, dtype):
    d = cfg.d_model
    din = cfg.d_inner
    n, g, h = cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    conv_dim = din + 2 * g * n
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * din + 2 * g * n + h
    return {
        "norm": jnp.zeros((d,), dtype),
        "in_proj": init_proj(ks[0], d, d_in_proj, qc, dtype=dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim))
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(1e-3, 0.1, h)) - 1.0).astype(dtype),  # softplus^-1
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "gate_norm": jnp.zeros((din,), dtype),
        "out_proj": init_proj(ks[2], din, d, qc, dtype=dtype),
    }


def _split_in_proj(zxbcdt, cfg):
    din = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * gn]
    dt = zxbcdt[..., din + din + 2 * gn:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None,
                 valid_len: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. xbc (B, S, C), w (K, C).

    state (B, K-1, C) carries the trailing inputs for decode continuity.
    valid_len: optional scalar — when the tail of xbc is right-padding
    (chunked prefill), the carried state must be the trailing K-1 *real*
    inputs, i.e. the window ending at position valid_len, not at S.
    Returns (out (B, S, C), new_state (B, K-1, C))."""
    kk = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], kk - 1, xbc.shape[-1]), xbc.dtype)
    xpad = jnp.concatenate([state, xbc], axis=1)               # (B, S+K-1, C)
    out = sum(xpad[:, i:i + xbc.shape[1], :] * w[i][None, None]
              for i in range(kk))
    if kk <= 1:
        new_state = state
    elif valid_len is None:
        new_state = xpad[:, -(kk - 1):, :]
    else:
        # real inputs occupy xpad[:, :valid_len + kk - 1]; keep its tail
        new_state = jax.lax.dynamic_slice_in_dim(
            xpad, valid_len, kk - 1, axis=1)
    return jax.nn.silu(out + b[None, None]), new_state


def ssd_chunked(x, dt, a_log, bmat, cmat, d_skip, chunk: int = 128,
                h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x    (B, S, H, P)   inputs per head
    dt   (B, S, H)      softplus-ed timestep
    a_log(H,)           A = -exp(a_log)
    bmat (B, S, G, N)   input->state projection
    cmat (B, S, G, N)   state->output projection
    d_skip (H,)         skip connection
    h0   (B, H, P, N)   optional initial state
    Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nch = x.shape[1] // chunk

    a = (-jnp.exp(a_log.astype(jnp.float32)))[None, None] * dt   # (B, S', H)
    xw = x * dt[..., None]                                        # dt-weighted

    def r(t, extra=()):  # (B, S', ...) -> (B, nch, chunk, ...)
        return t.reshape(b, nch, chunk, *t.shape[2:])
    xc, ac = r(xw), r(a)
    bc = jnp.repeat(r(bmat), rep, axis=3) if rep > 1 else r(bmat)
    cc = jnp.repeat(r(cmat), rep, axis=3) if rep > 1 else r(cmat)
    # with g==h after repeat: (B, nch, chunk, H, N)

    acs = jnp.cumsum(ac, axis=2)                                  # (B,nch,l,H)
    # 1) intra-chunk (diagonal blocks)
    dmat = jnp.exp(_segsum(jnp.moveaxis(ac, -1, 2)))              # (B,nch,H,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", cc, bc)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * dmat, xc)
    # 2) chunk final states
    decay_states = jnp.exp(acs[:, :, -1:, :] - acs)               # (B,nch,l,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bc, decay_states, xc)
    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(acs[:, :, -1, :])                       # (B,nch,H)

    def scan_fn(hprev, inp):
        st, dec = inp                                             # (B,H,P,N),(B,H)
        hnew = dec[..., None, None] * hprev + st
        return hnew, hprev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                         # (B,nch,H,P,N)
    # 4) state->output within chunk
    state_decay = jnp.exp(acs)                                    # (B,nch,l,H)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", cc,
                       h_prevs.astype(cc.dtype), state_decay.astype(cc.dtype))
    y = (y_diag + y_off).reshape(b, nch * chunk, h, p)[:, :s]
    y = y + x[:, :s] * d_skip[None, None, :, None]
    return y.astype(x.dtype), h_final


def mamba2_block(p: Params, x: jax.Array, cfg, qc: QuantConfig,
                 cache: Optional[Params] = None,
                 valid_len: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Full Mamba2 block (train/prefill path). x (B, S, D).

    cache: {"conv": (B, K-1, C), "h": (B, H, P, N)} — carried for prefill
    continuity and populated for subsequent decode.
    valid_len: optional scalar — positions >= valid_len are right-padding
    (chunked prefill). Unlike attention (where pads are masked out of the
    score matrix), an SSM *integrates* every input into its state, so pads
    must be made recurrence-neutral: their dt is forced to 0 (decay
    ``exp(-A·0) = 1``, input weight ``dt·x = 0`` — an exact no-op on ``h``)
    and the conv window state is taken at the last real token.
    Returns (out, recon, new_cache).
    """
    b, s, d = x.shape
    h, pdim, n, g = (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state,
                     cfg.ssm_ngroups)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt, r1 = proj(p["in_proj"], xn, qc)
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state,
                                 valid_len=valid_len)
    xs = xbc[..., :cfg.d_inner].reshape(b, s, h, pdim)
    bmat = xbc[..., cfg.d_inner:cfg.d_inner + g * n].reshape(b, s, g, n)
    cmat = xbc[..., cfg.d_inner + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,S,H)
    if valid_len is not None:
        live = (jnp.arange(s) < valid_len)[None, :, None]        # (1,S,1)
        dt = jnp.where(live, dt, 0.0)
    h0 = cache["h"] if cache is not None else None
    y, h_final = ssd_chunked(xs, dt, p["A_log"], bmat, cmat, p["D"],
                             chunk=128, h0=h0)
    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out, r2 = proj(p["out_proj"], y, qc)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_final}
    return out, r1 + r2, new_cache


def mamba2_decode(p: Params, x: jax.Array, cfg, qc: QuantConfig,
                  cache: Params) -> Tuple[jax.Array, jax.Array, Params]:
    """Single-token recurrent step. x (B, 1, D), cache {"conv","h"}."""
    b, _, d = x.shape
    h, pdim, n, g = (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state,
                     cfg.ssm_ngroups)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt, r1 = proj(p["in_proj"], xn, qc)
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)
    # conv via cached window
    xpad = jnp.concatenate([cache["conv"], xbc], axis=1)        # (B, K, C)
    kk = p["conv_w"].shape[0]
    conv_out = jnp.einsum("bkc,kc->bc", xpad[:, -kk:], p["conv_w"])
    xbc1 = jax.nn.silu(conv_out + p["conv_b"])[:, None]          # (B,1,C)
    new_conv = xpad[:, -(kk - 1):]
    xs = xbc1[..., :cfg.d_inner].reshape(b, h, pdim)
    bmat = xbc1[..., cfg.d_inner:cfg.d_inner + g * n].reshape(b, g, n)
    cmat = xbc1[..., cfg.d_inner + g * n:].reshape(b, g, n)
    rep = h // g
    bmat = jnp.repeat(bmat, rep, axis=1)                         # (B,H,N)
    cmat = jnp.repeat(cmat, rep, axis=1)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32)[:, 0]
                          + p["dt_bias"].astype(jnp.float32))    # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32))[None] * dt1)
    hs = cache["h"]                                              # (B,H,P,N)
    hnew = (a[..., None, None] * hs
            + jnp.einsum("bh,bhp,bhn->bhpn", dt1, xs.astype(jnp.float32),
                         bmat.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", hnew, cmat.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out, r2 = proj(p["out_proj"], y, qc)
    return out, r1 + r2, {"conv": new_conv, "h": hnew}
