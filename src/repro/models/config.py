"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default: d_model // num_heads
    qkv_bias: bool = False
    # attention pattern (gemma3): every `global_every`-th layer is global,
    # the rest use `sliding_window`. 0 = all layers global (full causal).
    global_every: int = 0
    sliding_window: int = 0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4

    # hybrid (zamba2): one *shared* attention block applied every N layers
    shared_attn_every: int = 0

    # audio (musicgen): number of parallel codebook heads; inputs are
    # precomputed frame embeddings from the (stubbed) EnCodec frontend.
    num_codebooks: int = 0

    # vlm (paligemma): number of precomputed patch embeddings prepended to
    # the token sequence (SigLIP frontend is a stub).
    num_patches: int = 0

    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    # "heads": classic (B,S,H,HD) layout, half-rotation RoPE.
    # "hd": head_dim-major (B,S,HD,H) layout + interleaved RoPE — head_dim
    #       TP-shards cleanly (projection columns are hd-major contiguous)
    #       and the interleaved rotation is local to any even-sized hd
    #       shard, eliminating resharding collectives (see EXPERIMENTS.md
    #       §Perf iteration I2).
    head_layout: str = "heads"
    dtype: str = "float32"           # params/activations dtype
    tie_embeddings: bool = True
    # attention softmax/score implementation: "naive" or "chunked"
    attn_impl: str = "naive"
    attn_chunk: int = 1024
    remat: bool = False              # activation checkpointing per block

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def pure_full_attention(self) -> bool:
        """True if every layer is unwindowed full attention (no SSM/local
        structure) — these archs skip the long_500k shape (see DESIGN.md)."""
        if self.family in ("ssm", "hybrid"):
            return False
        return self.global_every == 0 or self.sliding_window == 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_is_global(self, i: int) -> bool:
        if self.global_every <= 0:
            return True
        return (i % self.global_every) == (self.global_every - 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        emb = self.vocab_size * d
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        mlp = 3 * d * f
        if self.family == "moe":
            mlp = (self.num_experts + self.num_shared_experts) * 3 * d * f \
                + d * self.num_experts
        if self.family == "ssm":
            return emb + L * self._ssm_block_params()
        if self.family == "hybrid":
            # shared attention block counted once
            return emb + L * self._ssm_block_params() + (attn + 3 * d * f)
        per_layer = attn + mlp
        total = emb + L * per_layer
        if self.family == "audio":
            total += self.num_codebooks * self.vocab_size * d
        return total

    def _ssm_block_params(self) -> int:
        d = self.d_model
        din = self.d_inner
        n = self.ssm_state
        g = self.ssm_ngroups
        h = self.ssm_nheads
        in_proj = d * (2 * din + 2 * g * n + h)
        out_proj = din * d
        conv = (din + 2 * g * n) * self.ssm_conv
        return in_proj + out_proj + conv + 2 * h + din

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        emb = self.vocab_size * d
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        mlp_active = (self.top_k + self.num_shared_experts) * 3 * d * f \
            + d * self.num_experts
        return emb + L * (attn + mlp_active)
