"""Mixture-of-Experts FFN with top-k routing + optional shared experts.

Dispatch uses the capacity-slotted formulation (MaxText/Switch style): each
(token, choice) is assigned a slot within its expert's capacity buffer via a
cumulative one-hot count; tokens overflowing capacity are dropped. The expert
buffers are sharded on the expert dimension across the ``model`` mesh axis
(expert parallelism) — GSPMD turns the dispatch/combine einsums into
all-to-alls.

Expert FFNs support LUT-DLA quantisation with *per-expert* codebooks and
LUTs (shape (E, nc, c, v) / (E, nc, c, N)) — the paper's technique extended
to the MoE family (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.lut import QuantConfig
from repro.core.similarity import ste_quantize_subspaces
from repro.kernels import ops as kops
from .layers import rms_norm

Params = Dict


def init_expert_proj(key, e: int, k: int, n: int, qc: QuantConfig, dtype):
    kw, kz = jax.random.split(key)
    p = {"w": (jax.random.normal(kw, (e, k, n)) / (k ** 0.5)).astype(dtype)}
    if qc.is_lut:
        nc = k // qc.v
        p["z"] = (0.02 * jax.random.normal(kz, (e, nc, qc.c, qc.v))
                  ).astype(dtype)
    return p


def expert_proj(p: Params, x: jax.Array, qc: QuantConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """Batched per-expert projection.

    Args:
      p: {"w": (E, K, N)} plus, in LUT modes, "z" (E, nc, c, v) and — after
        ``precompute_model`` — "lut" (E, nc, c, N) / "lut_scale" (E, N).
      x: (E, Cap, K) capacity-slotted expert buffers.
      qc: operating point; in ``lut_infer`` the per-expert codebooks ride
        the same fused/two-pass kernel dispatch as every other projection.

    Returns: ((E, Cap, N) outputs, scalar recon loss — nonzero only in
    ``lut_train``). Mirrors ``lut_linear_apply`` vmapped over experts.
    """
    zero = jnp.zeros((), jnp.float32)
    if qc.mode == "dense" or "z" not in p:
        return jnp.einsum("ecd,edf->ecf", x, p["w"]), zero
    e, cap, k = x.shape
    xs = x.reshape(e, cap, k // qc.v, qc.v)
    if qc.mode == "lut_train":
        x_hat = jax.vmap(
            lambda xx, zz: ste_quantize_subspaces(xx, zz, qc.metric)
        )(xs, p["z"]).reshape(e, cap, k).astype(x.dtype)
        out_q = jnp.einsum("ecd,edf->ecf", x_hat, p["w"])
        out_d = jnp.einsum("ecd,edf->ecf", x, p["w"])
        sg = jax.lax.stop_gradient
        recon = (jnp.mean((sg(out_q) - out_d) ** 2)
                 + jnp.mean((out_q - sg(out_d)) ** 2)).astype(jnp.float32)
        return out_d + sg(out_q - out_d), recon
    # lut_infer — per-expert codebooks through the shared kernel dispatch,
    # so experts ride the same Pallas/fused paths as every other projection.
    lut = p.get("lut")
    if lut is None:
        lut = jax.vmap(lambda w, z: jnp.einsum(
            "kcv,kvn->kcn", z.astype(jnp.float32),
            w.reshape(z.shape[0], qc.v, -1).astype(jnp.float32)))(
                p["w"], p["z"])
    scale = p.get("lut_scale")           # (E, N) when the LUT is int8
    s_ax = None if scale is None else 0  # None is an empty pytree under vmap
    if qc.fuse:
        out = jax.vmap(
            lambda xx, zz, ll, ss: kops.vq_amm(
                xx, zz, ll, ss, qc.metric, impl=qc.impl),
            in_axes=(0, 0, 0, s_ax))(xs, p["z"], lut, scale)
    else:
        idx = jax.vmap(lambda xx, zz: kops.vq_assign(
            xx, zz, qc.metric, impl=qc.impl))(xs, p["z"])
        out = jax.vmap(
            lambda ii, ll, ss: kops.lut_matmul(ii, ll, ss, impl=qc.impl),
            in_axes=(0, 0, s_ax))(idx, lut, scale)
    return out.astype(x.dtype), zero


def init_moe(key, cfg, qc: QuantConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 8)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) / (d ** 0.5)).astype(dtype),
        "wg": init_expert_proj(ks[1], e, d, f, qc, dtype),
        "wu": init_expert_proj(ks[2], e, d, f, qc, dtype),
        "wd": init_expert_proj(ks[3], e, f, d, qc, dtype),
        "norm": jnp.zeros((d,), dtype),
    }
    if cfg.num_shared_experts:
        se = cfg.num_shared_experts
        p["shared_wg"] = init_expert_proj(ks[4], se, d, f, qc, dtype)
        p["shared_wu"] = init_expert_proj(ks[5], se, d, f, qc, dtype)
        p["shared_wd"] = init_expert_proj(ks[6], se, f, d, qc, dtype)
    return p


def moe_ffn(p: Params, x: jax.Array, cfg, qc: QuantConfig
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routed MoE. x (B, S, D) -> (out (B, S, D), recon, aux_loss).

    aux_loss is the standard load-balancing loss (mean_e f_e * p_e * E).

    Serving note: the capacity floor below makes tiny token counts —
    decode steps from the serving engines, including the continuous
    engine's (num_slots, 1) batches and (1, chunk) prefill chunks —
    drop-free (cap == T guarantees every (token, choice) gets a slot), so
    decode logits match the full-sequence forward exactly.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    tokens = xn.reshape(b * s, d)
    t = b * s

    logits = (tokens @ p["router"].astype(tokens.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)           # renormalise

    # load-balancing aux loss
    me = jnp.mean(probs, axis=0)                               # (E,)
    onehot_any = jax.nn.one_hot(gate_idx, e).sum(1)            # (T, E)
    ce = jnp.mean(onehot_any, axis=0) / k
    aux = e * jnp.sum(me * ce)

    # capacity: standard cf·T·k/E for large T; floored so that tiny-T
    # regimes (decode steps) are drop-free (cap == T guarantees no drop).
    cap = int(cfg.capacity_factor * t * k / e)
    cap = min(t, max(cap, 8))

    # slot assignment: position of each (token, choice) within its expert
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)          # (T, k, E)
    flat_oh = oh.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat_oh, axis=0) - flat_oh           # (T*k, E)
    slot = jnp.sum(pos_in_e * flat_oh, axis=-1)                # (T*k,)
    eid = gate_idx.reshape(t * k)
    keep = slot < cap
    gates_flat = gate_vals.reshape(t * k) * keep

    # dispatch: scatter tokens into (E, Cap, D) buffers
    tok_rep = jnp.repeat(tokens, k, axis=0)                    # (T*k, D)
    slot_c = jnp.where(keep, slot, cap - 1)
    buf = jnp.zeros((e, cap, d), tokens.dtype)
    buf = buf.at[eid, slot_c].add(tok_rep * keep[:, None].astype(tokens.dtype))

    # expert computation (per-expert SwiGLU, LUT-capable)
    g, r1 = expert_proj(p["wg"], buf, qc)
    u, r2 = expert_proj(p["wu"], buf, qc)
    y, r3 = expert_proj(p["wd"], jax.nn.silu(g) * u, qc)       # (E, Cap, D)

    # combine: gather each (token, choice)'s result, weight, sum over k
    out_flat = y[eid, slot_c] * gates_flat[:, None].astype(y.dtype)
    out = jnp.sum(out_flat.reshape(t, k, d), axis=1)

    recon = r1 + r2 + r3
    # shared experts (deepseek-moe): always-on, summed
    if "shared_wg" in p:
        se = p["shared_wg"]["w"].shape[0]
        xin = jnp.broadcast_to(tokens[None], (se, t, d))
        sg_, r4 = expert_proj(p["shared_wg"], xin, qc)
        su, r5 = expert_proj(p["shared_wu"], xin, qc)
        sy, r6 = expert_proj(p["shared_wd"], jax.nn.silu(sg_) * su, qc)
        out = out + jnp.sum(sy, axis=0)
        recon = recon + r4 + r5 + r6

    return out.reshape(b, s, d), recon, aux.astype(jnp.float32)
