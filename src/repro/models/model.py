"""Unified model: one class covering all six assigned architecture families.

Layer stacks are ``jax.lax.scan`` over stacked block parameters (leading
layer axis) — this keeps HLO size and CPU compile time tractable at
62-layer × 512-device dry-run scale. Per-layer heterogeneity (gemma3's 5:1
local:global pattern, zamba2's shared attention block) is expressed as
per-layer scalars fed through the scan.

API (all functional, params are plain pytrees):

  init(key, qc)                     -> params
  forward(params, batch, qc)        -> (logits, aux)
  loss(params, batch, qc)           -> (scalar, metrics)
  init_cache(batch, max_seq)        -> cache
  prefill(params, batch, cache, qc) -> (next_logits, cache)
  decode(params, tokens, cache, qc) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kv_codebook import (CODEBOOK_KEY, kv_decode_stacked,
                                    kv_encode_stacked)
from repro.core.lut import DENSE, QuantConfig
from repro.kernels.flash_decode import resolve_flash_impl
from .config import ModelConfig
from .layers import (attention, init_attention, init_mlp, mlp, rms_norm)
from .mamba2 import init_mamba2, mamba2_block, mamba2_decode
from .moe import init_moe, moe_ffn

Params = Dict[str, Any]

ATTN_FAMILIES = ("dense", "moe", "audio", "vlm")


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)

    def _init_block(self, key, qc: QuantConfig):
        cfg, dtype = self.cfg, self.dtype
        if cfg.family in ("ssm", "hybrid"):
            return init_mamba2(key, cfg, qc, dtype)
        ka, kf = jax.random.split(key)
        block = {"attn": init_attention(ka, cfg, qc, dtype)}
        if cfg.family == "moe":
            block["moe"] = init_moe(kf, cfg, qc, dtype)
        else:
            block["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff, cfg, qc, dtype)
        return block

    def init(self, key: jax.Array, qc: QuantConfig = DENSE) -> Params:
        cfg, dtype = self.cfg, self.dtype
        ke, kb, kh, ks = jax.random.split(key, 4)
        params: Params = {"final_norm": jnp.zeros((cfg.d_model,), dtype)}
        if cfg.family != "audio":
            params["embed"] = (0.02 * jax.random.normal(
                ke, (cfg.vocab_size, cfg.d_model))).astype(dtype)
        layer_keys = jax.random.split(kb, cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: self._init_block(k, qc))(layer_keys)
        if cfg.family == "hybrid":
            ka, km = jax.random.split(ks)
            params["shared_attn"] = {
                "attn": init_attention(ka, cfg, qc, dtype),
                "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg, qc, dtype),
            }
        if cfg.family == "audio":
            params["heads"] = (0.02 * jax.random.normal(
                kh, (cfg.num_codebooks, cfg.d_model, cfg.vocab_size))
            ).astype(dtype)
            # audio inputs are stub frame embeddings; a learned input proj
            # stands in for the EnCodec codebook-sum embedding.
            params["in_proj"] = (0.02 * jax.random.normal(
                ke, (cfg.d_model, cfg.d_model))).astype(dtype)
        elif not cfg.tie_embeddings:
            params["head"] = (0.02 * jax.random.normal(
                kh, (cfg.d_model, cfg.vocab_size))).astype(dtype)
        return params

    # ------------------------------------------------------------------
    # embedding / head per family
    # ------------------------------------------------------------------
    def _embed(self, params: Params, batch: Dict) -> Tuple[jax.Array, int]:
        """Returns (x (B, S, D), prefix_len)."""
        cfg = self.cfg
        if cfg.family == "audio":
            x = batch["embeds"].astype(self.dtype) @ params["in_proj"]
            return x, 0
        if cfg.family == "vlm":
            tok = params["embed"][batch["tokens"]]
            patches = batch["patch_embeds"].astype(self.dtype)
            return jnp.concatenate([patches, tok], axis=1), cfg.num_patches
        return params["embed"][batch["tokens"]], 0

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            return jnp.einsum("bsd,qdv->bsqv", x, params["heads"])
        if cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["head"]

    # ------------------------------------------------------------------
    # per-layer static metadata
    # ------------------------------------------------------------------
    def _windows(self) -> jax.Array:
        cfg = self.cfg
        return jnp.array(
            [0 if cfg.layer_is_global(i) else cfg.sliding_window
             for i in range(cfg.num_layers)], jnp.int32)

    def _attn_slot_list(self):
        """Hybrid: shared-attention invocation slot per layer (-1 = none)."""
        cfg = self.cfg
        slots, s = [], 0
        for i in range(cfg.num_layers):
            if cfg.shared_attn_every and (i % cfg.shared_attn_every
                                          == cfg.shared_attn_every - 1):
                slots.append(s)
                s += 1
            else:
                slots.append(-1)
        return slots

    def _attn_slots(self) -> jax.Array:
        return jnp.array(self._attn_slot_list(), jnp.int32)

    @property
    def num_attn_slots(self) -> int:
        return sum(1 for s in self._attn_slot_list() if s >= 0)

    # ------------------------------------------------------------------
    # block runners
    # ------------------------------------------------------------------
    def _run_blocks(self, params: Params, x: jax.Array, qc: QuantConfig,
                    q_offset, prefix_len,
                    cache: Optional[Params] = None,
                    kv_start=0, valid_len=None, return_slabs: bool = False,
                    multi_slab: bool = False,
                    paged_phys=None, flash_impl: str = "ref"):
        """Scan over the layer stack. Returns (x, recon, moe_aux, new_cache).

        q_offset: scalar, or (B,) per-row decode positions (paged serving).
        kv_start: scalar or (B,) — mask cache rows < kv_start (left-padded
          batch prompts; see ``layers.attention``).
        valid_len: scalar — right-padded chunked prefill; only the SSM path
          consumes it (attention pads are handled by causality + the
          caller's write masking).
        return_slabs: single-token decode only — return the per-layer
          new-token KV slabs instead of writing them into ``cache`` at
          ``q_offset`` (the paged-cache caller scatters them itself; this
          is what makes per-slot write positions possible).
        multi_slab: treat a MULTI-token input like the decode slab path
          (speculative verify): the cache stays read-only, per-row
          q_offset positions are honoured, and each layer emits a
          (B, S, KVH, HD) fresh-KV slab. Attention families only;
          requires ``return_slabs``.
        paged_phys / flash_impl: single-token decode only — ``cache`` is
          the raw paged POOL (``{"k": (L, P+1, page, KVH, HD), ...}``)
          and each layer's attention runs the flash-decode kernel
          through the page table instead of over a gathered view (see
          ``decode_paged`` and kernels/flash_decode.py).
        """
        cfg = self.cfg
        windows = self._windows()
        decode = cache is not None and (x.shape[1] == 1 or multi_slab)

        if cfg.family in ATTN_FAMILIES:
            # Cache handling [§Perf I3/I5]:
            #  * decode: the cache is a scan INVARIANT (read-only per-layer
            #    slices are free); each layer emits only its new-token KV
            #    slab via ys, and the cache is updated ONCE after the scan.
            #  * prefill: the cache travels in the carry and each layer
            #    updates its slice in place — streaming it through xs/ys
            #    would rebuild the full stacked buffer every layer.
            # Layer grouping [§Perf I8]: local:global patterns (gemma3) scan
            # over groups of `global_every` with the window STATIC per
            # sub-layer, enabling the block-local attention fast path.
            slab_mode = decode and cfg.head_layout != "hd"

            def layer_fn(h, recon, aux, c_full, p_l, win, li):
                src = cache if slab_mode else c_full
                c_l = None
                if src is not None:
                    c_l = jax.tree_util.tree_map(
                        lambda t: jax.lax.dynamic_index_in_dim(
                            t, li, 0, keepdims=False), src)
                a, r1, new_c = attention(p_l["attn"], h, cfg, qc,
                                         q_offset=q_offset, window=win,
                                         prefix_len=prefix_len, cache=c_l,
                                         decode_slab=slab_mode,
                                         kv_start=kv_start,
                                         paged_phys=paged_phys,
                                         flash_impl=flash_impl)
                h = h + a
                if cfg.family == "moe":
                    f, r2, a2 = moe_ffn(p_l["moe"], h, cfg, qc)
                    aux = aux + a2
                else:
                    f, r2 = mlp(p_l["mlp"], h, cfg, qc)
                h = h + f
                slab = None
                if slab_mode:
                    slab = new_c
                elif c_full is not None:
                    c_full = jax.tree_util.tree_map(
                        lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                            full, upd.astype(full.dtype), li, 0),
                        c_full, new_c)
                return h, recon + r1 + r2, aux, c_full, slab

            ge = cfg.global_every
            # grouping pays off where the static window enables the
            # block-local path (train/prefill); decode keeps the flat scan
            # (slab path) — grouping there only perturbs fusion patterns.
            grouped = (ge > 1 and cfg.sliding_window > 0
                       and cfg.num_layers >= ge and not decode)
            carry_cache = cache is not None and not slab_mode
            z0 = jnp.zeros((), jnp.float32)

            if grouped:
                n_groups, tail = divmod(cfg.num_layers, ge)
                gp = jax.tree_util.tree_map(
                    lambda t: t[:n_groups * ge].reshape(
                        n_groups, ge, *t.shape[1:]), params["blocks"])
                tail_p = jax.tree_util.tree_map(
                    lambda t: t[n_groups * ge:], params["blocks"])

                def gbody(carry, xs):
                    if carry_cache:
                        h, recon, aux, c_full = carry
                    else:
                        h, recon, aux = carry
                        c_full = None
                    g_params, gid = xs
                    slabs = []
                    for j in range(ge):
                        p_l = jax.tree_util.tree_map(
                            lambda t: t[j], g_params)
                        win = 0 if j == ge - 1 else cfg.sliding_window
                        li = gid * ge + j
                        h, recon, aux, c_full, slab = layer_fn(
                            h, recon, aux, c_full, p_l, win, li)
                        slabs.append(slab)
                    ys = (jax.tree_util.tree_map(
                        lambda *t: jnp.stack(t), *slabs)
                        if slab_mode else None)
                    if carry_cache:
                        return (h, recon, aux, c_full), ys
                    return (h, recon, aux), ys

                if cfg.remat:
                    gbody = jax.checkpoint(gbody)
                gids = jnp.arange(n_groups, dtype=jnp.int32)
                carry0 = (x, z0, z0, cache) if carry_cache else (x, z0, z0)
                carry, ys = jax.lax.scan(gbody, carry0, (gp, gids))
                if carry_cache:
                    x, recon, aux, new_cache = carry
                else:
                    x, recon, aux = carry
                    new_cache = cache if slab_mode else None
                slab_list = []
                if slab_mode and ys is not None:
                    flat = jax.tree_util.tree_map(
                        lambda t: t.reshape(-1, *t.shape[2:]), ys)
                    slab_list.append(flat)
                # tail layers (num_layers % global_every), unscanned
                c_full = new_cache if carry_cache else None
                for j in range(tail):
                    li = n_groups * ge + j
                    p_l = jax.tree_util.tree_map(lambda t: t[j], tail_p)
                    win = 0 if cfg.layer_is_global(li) else \
                        cfg.sliding_window
                    x, recon, aux, c_full, slab = layer_fn(
                        x, recon, aux, c_full, p_l, win, jnp.int32(li))
                    if slab_mode:
                        slab_list.append(jax.tree_util.tree_map(
                            lambda t: t[None], slab))
                if carry_cache:
                    new_cache = c_full
                if slab_mode:
                    slabs = jax.tree_util.tree_map(
                        lambda *t: jnp.concatenate(t, 0), *slab_list)
                    new_cache = {
                        key: jax.lax.dynamic_update_slice_in_dim(
                            cache[key], slabs[key].astype(cache[key].dtype),
                            q_offset, axis=2)
                        for key in ("k", "v")}
                return x, recon, aux, new_cache

            def body(carry, xs):
                if carry_cache:
                    h, recon, aux, c_full = carry
                else:
                    h, recon, aux = carry
                    c_full = None
                p_l, win, li = xs
                h, recon, aux, c_full, slab = layer_fn(
                    h, recon, aux, c_full, p_l, win, li)
                if carry_cache:
                    return (h, recon, aux, c_full), slab
                return (h, recon, aux), slab

            if cfg.remat:
                body = jax.checkpoint(body)
            layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
            xs = (params["blocks"], windows, layer_ids)
            carry0 = (x, z0, z0, cache) if carry_cache else (x, z0, z0)
            carry, slabs = jax.lax.scan(body, carry0, xs)
            if carry_cache:
                x, recon, aux, new_cache = carry
                return x, recon, aux, new_cache
            x, recon, aux = carry
            if slab_mode:
                if return_slabs:          # (L, B, 1, KVH, HD) per key
                    return x, recon, aux, slabs
                new_cache = {
                    key: jax.lax.dynamic_update_slice_in_dim(
                        cache[key], slabs[key], q_offset, axis=2)
                    for key in ("k", "v")}
                return x, recon, aux, new_cache
            return x, recon, aux, None

        # ssm / hybrid. Mamba states are FULLY replaced every step, so the
        # optimal cache movement is xs/ys streaming (one read + one write of
        # each layer's state); carry-DUS would rebuild the stacked buffer
        # per layer. (The attention KV cache is the opposite case — see the
        # slab path above.) [§Perf I7]
        slots = self._attn_slots() if cfg.family == "hybrid" else None
        shared = params.get("shared_attn")

        def body(carry, xs):
            if cfg.family == "hybrid":
                h, recon, aux, attn_cache = carry
                if cache is None:
                    p_l, slot, li = xs
                    c_l = None
                else:
                    p_l, slot, li, c_l = xs
            else:
                h, recon, aux = carry
                attn_cache = None
                if cache is None:
                    p_l, li = xs
                    c_l = None
                else:
                    p_l, li, c_l = xs
            if decode:
                o, r, new_c = mamba2_decode(p_l, h, cfg, qc, c_l)
            else:
                o, r, new_c = mamba2_block(p_l, h, cfg, qc, c_l,
                                           valid_len=valid_len)
            h = h + o
            recon = recon + r

            if cfg.family == "hybrid":
                # decode: attn cache is read-only; each invocation emits a
                # new-token slab through ys (zeros on non-attn layers), and
                # the slot rows are written back once after the scan. [I5b]
                slab_mode = decode and attn_cache is not None \
                    and cfg.head_layout != "hd"

                def with_attn(operand):
                    h, attn_cache, recon = operand
                    if attn_cache is None:
                        c_a = None
                    else:
                        c_a = jax.tree_util.tree_map(
                            lambda t: jax.lax.dynamic_index_in_dim(
                                t, jnp.maximum(slot, 0), 0, keepdims=False),
                            attn_cache)
                    a, r1, new_a = attention(shared["attn"], h, cfg, qc,
                                             q_offset=q_offset, window=0,
                                             prefix_len=prefix_len, cache=c_a,
                                             decode_slab=slab_mode,
                                             kv_start=kv_start)
                    h = h + a
                    f, r2 = mlp(shared["mlp"], h, cfg, qc)
                    h = h + f
                    if attn_cache is not None and not slab_mode:
                        attn_cache = jax.tree_util.tree_map(
                            lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                                full, upd.astype(full.dtype),
                                jnp.maximum(slot, 0), 0),
                            attn_cache, new_a)
                    if slab_mode:
                        return h, attn_cache, recon + r1 + r2, new_a
                    return h, attn_cache, recon + r1 + r2, None

                def no_attn(operand):
                    h, attn_cache, recon = operand
                    if slab_mode:
                        b = h.shape[0]
                        kvh, hd = cfg.num_kv_heads, cfg.head_dim
                        dt = attn_cache["k"].dtype
                        zero_slab = {
                            "k": jnp.zeros((b, 1, kvh, hd), dt),
                            "v": jnp.zeros((b, 1, kvh, hd), dt)}
                        return h, attn_cache, recon, zero_slab
                    return h, attn_cache, recon, None

                h, attn_cache, recon, slab = jax.lax.cond(
                    slot >= 0, with_attn, no_attn, (h, attn_cache, recon))
                return (h, recon, aux, attn_cache), (new_c, slab)
            return (h, recon, aux), new_c

        if cfg.remat:
            body = jax.checkpoint(body)

        z0 = jnp.zeros((), jnp.float32)
        layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        if cfg.family == "hybrid":
            attn_cache0 = cache["attn"] if cache is not None else None
            xs = (params["blocks"], slots, layer_ids)
            if cache is not None:
                xs = xs + (cache["mamba"],)
            (x, recon, aux, attn_cache), (new_mamba, slabs) = jax.lax.scan(
                body, (x, z0, z0, attn_cache0), xs)
            if decode and attn_cache0 is not None \
                    and cfg.head_layout != "hd":
                # gather the slab rows at the attn layers (static indices)
                # and write all slots' new-token KV in one update
                slot_layers = jnp.array(
                    [i for i, s in enumerate(self._attn_slot_list())
                     if s >= 0], jnp.int32)
                slab_rows = {
                    key: slabs[key][slot_layers].astype(
                        attn_cache0[key].dtype)
                    for key in ("k", "v")}       # (n_inv, B, 1, KVH, HD)
                if return_slabs:
                    return x, recon, aux, {"mamba": new_mamba,
                                           "attn_slab": slab_rows}
                attn_cache = {
                    key: jax.lax.dynamic_update_slice_in_dim(
                        attn_cache0[key], slab_rows[key], q_offset, axis=2)
                    for key in ("k", "v")}
            new_cache = (None if cache is None
                         else {"mamba": new_mamba, "attn": attn_cache})
            return x, recon, aux, new_cache

        xs = (params["blocks"], layer_ids)
        if cache is not None:
            xs = xs + (cache,)
        (x, recon, aux), new_cache = jax.lax.scan(body, (x, z0, z0), xs)
        return x, recon, aux, new_cache

    # ------------------------------------------------------------------
    # train forward + loss
    # ------------------------------------------------------------------
    def forward(self, params: Params, batch: Dict, qc: QuantConfig = DENSE):
        x, prefix_len = self._embed(params, batch)
        x, recon, moe_aux, _ = self._run_blocks(
            params, x, qc, q_offset=0, prefix_len=prefix_len)
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = self._head(params, x)
        return logits, {"recon": recon, "moe_aux": moe_aux}

    def loss(self, params: Params, batch: Dict, qc: QuantConfig = DENSE):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, qc)
        if cfg.family == "audio":
            labels = batch["labels"]                    # (B, S, Q)
            lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(
                lp, labels[:, 1:, :, None], axis=-1)[..., 0]
            ce = jnp.mean(nll)
        elif cfg.family == "vlm":
            # loss only over the text region (after the image prefix)
            p = cfg.num_patches
            text_logits = logits[:, p - 1:-1]           # predicts tokens
            labels = batch["tokens"]
            lp = jax.nn.log_softmax(text_logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
            ce = jnp.mean(nll)
        else:
            labels = batch["tokens"][:, 1:]
            lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
            ce = jnp.mean(nll)
        total = (ce + qc.recon_weight * aux["recon"]
                 + 0.01 * aux["moe_aux"])
        metrics = {"ce": ce, "recon": aux["recon"], "moe_aux": aux["moe_aux"],
                   "loss": total}
        return total, metrics

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_seq: int,
                   dtype=None) -> Params:
        cfg = self.cfg
        dtype = dtype or self.dtype
        l, b, t = cfg.num_layers, batch_size, max_seq
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        pos = jnp.zeros((), jnp.int32)
        if cfg.family in ATTN_FAMILIES:
            return {"layers": {
                "k": jnp.zeros((l, b, t, kvh, hd), dtype),
                "v": jnp.zeros((l, b, t, kvh, hd), dtype)},
                "pos": pos}
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        mamba = {
            "conv": jnp.zeros((l, b, cfg.ssm_conv - 1, conv_dim), dtype),
            "h": jnp.zeros((l, b, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32)}
        if cfg.family == "ssm":
            return {"layers": mamba, "pos": pos}
        n_inv = self.num_attn_slots
        return {"layers": {
            "mamba": mamba,
            "attn": {"k": jnp.zeros((n_inv, b, t, kvh, hd), dtype),
                     "v": jnp.zeros((n_inv, b, t, kvh, hd), dtype)}},
            "pos": pos}

    def prefill(self, params: Params, batch: Dict, cache: Params,
                qc: QuantConfig = DENSE, pad_lens=None):
        """Process the prompt; returns (next-token logits (B, V...), cache).

        Args:
          batch: {"tokens": (B, S)} (audio: "embeds"; vlm: + patch_embeds).
          cache: dense cache from :meth:`init_cache`.
          pad_lens: (B,) — prompts are LEFT-padded (right-aligned, the
            batch-to-completion convention); cache rows < pad_lens[b] are
            masked out of attention for row b. The continuous engine does
            not use this entry point — its RIGHT-padded chunked prefill
            goes through :meth:`prefill_paged`.
        """
        x, prefix_len = self._embed(params, batch)
        s = x.shape[1]
        kv_start = pad_lens if pad_lens is not None else 0
        x, _, _, new_layers = self._run_blocks(
            params, x, qc, q_offset=0, prefix_len=prefix_len,
            cache=cache["layers"], kv_start=kv_start)
        x = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = self._head(params, x)[:, 0]
        return logits, {"layers": new_layers,
                        "pos": jnp.asarray(s, jnp.int32)}

    def decode(self, params: Params, tokens: jax.Array, cache: Params,
               qc: QuantConfig = DENSE, pad_lens=None):
        """One decode step. tokens (B, 1) int32 (audio: embeds (B, 1, D);
        vlm: text token ids). Returns (logits (B, V...), cache).

        pad_lens: (B,) — left-pad widths from a right-aligned batched
        prefill; cache rows < pad_lens[b] stay masked during decode."""
        cfg = self.cfg
        pos = cache["pos"]
        if cfg.family == "audio":
            x = tokens.astype(self.dtype) @ params["in_proj"]
        else:
            x = params["embed"][tokens]
        kv_start = pad_lens if pad_lens is not None else 0
        x, _, _, new_layers = self._run_blocks(
            params, x, qc, q_offset=pos, prefix_len=0, cache=cache["layers"],
            kv_start=kv_start)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)[:, 0]
        return logits, {"layers": new_layers, "pos": pos + 1}

    # ------------------------------------------------------------------
    # paged serving (continuous batching; see src/repro/serve/)
    # ------------------------------------------------------------------
    def init_paged_cache(self, num_slots: int, max_seq: int, page_size: int,
                         num_pages: int, dtype=None, codebook=None) -> Params:
        """Physical cache storage for the paged serving engine.

        Attention families return a page pool ``{"k": (L, num_pages+1,
        page_size, KVH, HD), "v": ...}`` — one extra *trash* page (the
        last id) absorbs writes from padded / inactive positions. SSM
        state is O(1) per sequence, so it stays slot-indexed
        (``(L, num_slots, ...)``) and is recycled on eviction; the hybrid
        family keeps its few shared-attention invocations slot-dense.

        codebook: optional :class:`repro.core.kv_codebook.KVCodebook` —
        the pool then stores uint8 per-subspace centroid indices
        ``(L, num_pages+1, page_size, KVH, nc)`` instead of fp rows, and
        the codebook pytree rides the cache under ``"codebook"`` (every
        paged entry point detects quantization by that key). Attention
        families only.
        """
        cfg = self.cfg
        dtype = dtype or self.dtype
        l, kvh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        if codebook is not None and cfg.family not in ATTN_FAMILIES:
            raise NotImplementedError(
                "KV quantization applies to paged attention pools only; "
                f"the {cfg.family!r} family has recurrent state")
        if cfg.family in ATTN_FAMILIES:
            if codebook is not None:
                if (codebook.num_layers, codebook.head_dim) != (l, hd):
                    raise ValueError(
                        f"codebook (L={codebook.num_layers}, "
                        f"HD={codebook.head_dim}) does not match model "
                        f"(L={l}, HD={hd})")
                shape = (l, num_pages + 1, page_size, kvh, codebook.nc)
                # each cache owns PRIVATE copies of the codebook leaves:
                # the serving jits donate the cache pytree, and donation
                # deletes buffers — sharing one KVCodebook's arrays across
                # caches would let one engine's step invalidate another's.
                cb_tree = {key: jnp.array(leaf, copy=True)
                           for key, leaf in codebook.tree().items()}
                return {"k": jnp.zeros(shape, jnp.uint8),
                        "v": jnp.zeros(shape, jnp.uint8),
                        CODEBOOK_KEY: cb_tree}
            shape = (l, num_pages + 1, page_size, kvh, hd)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        mamba = {
            "conv": jnp.zeros((l, num_slots, cfg.ssm_conv - 1, conv_dim),
                              dtype),
            "h": jnp.zeros((l, num_slots, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32)}
        if cfg.family == "ssm":
            return mamba
        # hybrid: slot-dense shared-attn cache with one extra TRASH row
        # (index max_seq) absorbing writes from non-decoding lanes — the
        # slot-dense analogue of the attention pool's trash page. Rows
        # >= pos are never attended (kj < pos mask), so the extra row is
        # invisible to reads.
        n_inv = self.num_attn_slots
        shape = (n_inv, num_slots, max_seq + 1, kvh, hd)
        return {"mamba": mamba,
                "attn": {"k": jnp.zeros(shape, dtype),
                         "v": jnp.zeros(shape, dtype)}}

    def _paged_view(self, kv: Params, phys: jax.Array):
        """Gather pages into a dense (L, B, NP*page, KVH, HD) KV view.

        phys: (B, NP) physical page ids (already trash-redirected).
        A quantized pool (``"codebook"`` in kv) is gathered as uint8
        codes and decoded to an fp view — the returned dict is always
        plain ``{"k", "v"}`` fp, so every gathered-view consumer
        (prefill, verify, the legacy gather decode path) reuses the
        dense attention math unchanged."""
        l = kv["k"].shape[0]
        ps = kv["k"].shape[2]
        b, np_ = phys.shape
        kvh, w = kv["k"].shape[3], kv["k"].shape[4]

        def view(pages):
            return pages[:, phys].reshape(l, b, np_ * ps, kvh, w)
        cb = kv.get(CODEBOOK_KEY)
        if cb is None:
            return {"k": view(kv["k"]), "v": view(kv["v"])}
        dt = self.dtype
        return {"k": kv_decode_stacked(view(kv["k"]), cb["zk"], cb["sk"], dt),
                "v": kv_decode_stacked(view(kv["v"]), cb["zv"], cb["sv"], dt)}

    def _encode_rows(self, kv: Params, key: str, rows: jax.Array):
        """Fresh fp K/V rows -> pool representation for stream ``key``.

        Identity on an fp pool; per-subspace codebook assignment (uint8
        codes) on a quantized one. rows (L, ..., KVH, HD)."""
        cb = kv.get(CODEBOOK_KEY)
        if cb is None:
            return rows
        z, s = (cb["zk"], cb["sk"]) if key == "k" else (cb["zv"], cb["sv"])
        return kv_encode_stacked(rows, z, s)

    def prefill_paged(self, params: Params, tokens: jax.Array, kv: Params,
                      page_table: jax.Array, slot, pos, valid_len,
                      qc: QuantConfig = DENSE, act_sharding=None):
        """One RIGHT-padded prefill chunk for a single slot.

        Args:
          tokens: (1, C) int32 — chunk of the prompt, right-padded to the
            static chunk width C; only the first ``valid_len`` are real.
          kv: paged cache pytree from :meth:`init_paged_cache`.
          page_table: (num_slots, pages_per_slot) int32, -1 = unallocated.
            Pages covering positions [0, pos+valid_len) of ``slot`` must
            already be allocated.
          slot: scalar slot index; pos: scalar absolute start position.
          act_sharding: optional sharding (``NamedSharding``) pinned onto
            the embedded activations — the sharded serving engine passes a
            replicated spec so GSPMD keeps activations whole and partitions
            the projections (column-parallel LUT lookups shard N, row-
            parallel ones shard subspaces and all-reduce partial sums).

        Returns (logits (1, V) at the last real token, updated kv).
        Padded positions scatter to the trash page; the SSM path makes
        them recurrence-neutral via ``valid_len`` (see mamba2_block).
        """
        cfg = self.cfg
        if cfg.head_layout == "hd":
            raise NotImplementedError("paged serving requires head_layout="
                                      "'heads'")
        if cfg.family in ("audio", "vlm"):
            raise NotImplementedError(
                "paged serving covers token-prompt families only")
        x = params["embed"][tokens]
        if act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        c = tokens.shape[1]
        if cfg.family in ATTN_FAMILIES:
            trash = kv["k"].shape[1] - 1
            ps = kv["k"].shape[2]
            row = jax.lax.dynamic_index_in_dim(page_table, slot, 0,
                                               keepdims=False)    # (NP,)
            phys = jnp.where(row >= 0, row, trash)
            view = self._paged_view(kv, phys[None])
            x, _, _, new_view = self._run_blocks(
                params, x, qc, q_offset=pos, prefix_len=0, cache=view)
            # extract this chunk's fresh K/V rows and scatter them to pages
            tok_pos = pos + jnp.arange(c)
            page, off = tok_pos // ps, tok_pos % ps
            live = jnp.arange(c) < valid_len
            tgt = jnp.where(live, phys[page], trash)              # (C,)
            new_kv = dict(kv)       # codebook (if any) passes through
            for key in ("k", "v"):
                rows = jax.lax.dynamic_slice_in_dim(
                    new_view[key][:, 0], pos, c, axis=1)          # (L,C,..)
                rows = self._encode_rows(kv, key, rows)
                new_kv[key] = kv[key].at[:, tgt, off].set(rows)
        else:
            cache_view, write_back = self._slot_state_view(kv, slot, pos)
            x, _, _, new_state = self._run_blocks(
                params, x, qc, q_offset=pos, prefix_len=0,
                cache=cache_view, valid_len=valid_len)
            new_kv = write_back(new_state)
        x_last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
        x_last = rms_norm(x_last, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x_last)[:, 0]
        return logits, new_kv

    def _slot_state_view(self, kv: Params, slot, pos):
        """(B=1) view of one slot's recurrent state + write-back closure.

        The first chunk of a new occupant (``pos == 0``) reads zeros
        instead of the previous occupant's state — this is how evicted
        Mamba2 state slots are recycled without a separate reset pass.
        """
        continuing = pos > 0                  # pos == 0 → recycled slot

        def take(t):                          # (L, slots, ...) -> (L, 1, ...)
            sl = jax.lax.dynamic_slice_in_dim(t, slot, 1, axis=1)
            return jnp.where(continuing, sl, jnp.zeros_like(sl))

        def put(t, new):
            return jax.lax.dynamic_update_slice_in_dim(
                t, new.astype(t.dtype), slot, axis=1)

        if self.cfg.family == "ssm":
            view = {"conv": take(kv["conv"]), "h": take(kv["h"])}

            def write_back(new):
                return {"conv": put(kv["conv"], new["conv"]),
                        "h": put(kv["h"], new["h"])}
            return view, write_back

        # hybrid: recurrent mamba state + slot-dense shared-attn KV. The
        # attention rows need no zeroing: chunk writes land at q_offset
        # before any read, and decode masks rows >= pos.
        attn_view = {key: jax.lax.dynamic_slice_in_dim(
            kv["attn"][key], slot, 1, axis=1) for key in ("k", "v")}
        view = {"mamba": {"conv": take(kv["mamba"]["conv"]),
                          "h": take(kv["mamba"]["h"])},
                "attn": attn_view}

        def write_back(new):
            return {"mamba": {"conv": put(kv["mamba"]["conv"],
                                          new["mamba"]["conv"]),
                              "h": put(kv["mamba"]["h"], new["mamba"]["h"])},
                    "attn": {key: put(kv["attn"][key], new["attn"][key])
                             for key in ("k", "v")}}
        return view, write_back

    def decode_paged(self, params: Params, tokens: jax.Array, kv: Params,
                     page_table: jax.Array, positions: jax.Array,
                     qc: QuantConfig = DENSE, act_sharding=None):
        """One decode step over ALL slots at per-slot positions.

        Args:
          tokens: (num_slots, 1) int32 — inactive lanes carry a dummy id.
          positions: (num_slots,) int32 sequence length of each DECODING
            slot; lanes that are not decoding this step (free slots, but
            also slots mid-prefill — whose pages hold real prompt KV that
            must not be clobbered) carry -1. Row b's query gets RoPE
            position positions[b] and attends cache rows < positions[b]
            (none, for -1).
          page_table: (num_slots, pages_per_slot) int32, -1 = unallocated.
          act_sharding: optional sharding constraint for the embedded
            activations (see :meth:`prefill_paged`).

        Returns (logits (num_slots, V), updated kv). The new-token KV slab
        is scattered at each decoding slot's own (page, offset); lanes
        with positions < 0 scatter to the trash page (attention pool) /
        trash row (hybrid slot-dense cache). SSM states of inactive lanes
        do get garbage updates — harmless, because admission re-reads
        them as zeros (see :meth:`_slot_state_view`).
        """
        cfg = self.cfg
        if cfg.head_layout == "hd":
            raise NotImplementedError("paged serving requires head_layout="
                                      "'heads'")
        if cfg.family in ("audio", "vlm"):
            raise NotImplementedError(
                "paged serving covers token-prompt families only")
        b = tokens.shape[0]
        x = params["embed"][tokens]
        if act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        live = positions >= 0                 # decoding lanes only
        pos_c = jnp.maximum(positions, 0)
        if cfg.family in ATTN_FAMILIES:
            trash = kv["k"].shape[1] - 1
            ps = kv["k"].shape[2]
            phys = jnp.where(page_table >= 0, page_table, trash)  # (B, NP)
            # dispatch knob (mirrors QuantConfig.fuse): "gather" is the
            # legacy dense-view path; "pallas"/"ref" run flash decode
            # straight off the page pool (kernels/flash_decode.py).
            flash = resolve_flash_impl(qc.flash)
            if flash == "gather":
                view = self._paged_view(kv, phys)
                x, _, _, slabs = self._run_blocks(
                    params, x, qc, q_offset=positions, prefix_len=0,
                    cache=view, return_slabs=True)
            else:
                x, _, _, slabs = self._run_blocks(
                    params, x, qc, q_offset=positions, prefix_len=0,
                    cache=kv, return_slabs=True,
                    paged_phys=phys, flash_impl=flash)
            page, off = pos_c // ps, pos_c % ps
            # non-decoding lanes MUST NOT write through their page table:
            # a mid-prefill slot's pages hold real prompt KV.
            tgt = jnp.where(live, phys[jnp.arange(b), page], trash)
            new_kv = dict(kv)
            for key in ("k", "v"):
                slab = self._encode_rows(kv, key, slabs[key][:, :, 0])
                new_kv[key] = kv[key].at[:, tgt, off].set(slab)
        elif cfg.family == "ssm":
            x, _, _, upd = self._run_blocks(
                params, x, qc, q_offset=positions, prefix_len=0, cache=kv)
            # recurrent state is live for EVERY occupied lane (a slot
            # mid-prefill carries real state between chunks): lanes that
            # are not decoding keep their old state.
            new_kv = _merge_live_states(kv, upd, live)
        else:                                 # hybrid
            x, _, _, upd = self._run_blocks(
                params, x, qc, q_offset=positions, prefix_len=0,
                cache=kv, return_slabs=True)
            trash_row = kv["attn"]["k"].shape[2] - 1   # see init_paged_cache
            row = jnp.where(live, pos_c, trash_row)
            attn = {key: kv["attn"][key].at[:, jnp.arange(b), row].set(
                        upd["attn_slab"][key][:, :, 0])
                    for key in ("k", "v")}
            new_kv = {"mamba": _merge_live_states(kv["mamba"], upd["mamba"],
                                                  live),
                      "attn": attn}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)[:, 0]
        return logits, new_kv

    def verify_paged(self, params: Params, tokens: jax.Array, kv: Params,
                     page_table: jax.Array, positions: jax.Array,
                     n_live: jax.Array, qc: QuantConfig = DENSE,
                     act_sharding=None):
        """Score T proposed tokens per slot in ONE call (speculative verify).

        Row b feeds tokens[b, 0:T] at absolute positions positions[b] ..
        positions[b]+T-1: column 0 is the slot's committed-but-undecoded
        next token, columns 1.. are draft proposals. Token t's query
        attends committed cache rows < positions[b] plus proposed tokens
        0..t (their K/V computed fresh in this call — target numerics),
        so logits[b, t] is exactly the target distribution after
        consuming tokens[b, :t+1], bit-for-bit the context a sequential
        :meth:`decode_paged` chain would build.

        Args:
          tokens: (num_slots, T) int32 proposals; dead columns carry dummy
            ids.
          positions: (num_slots,) committed length of each participating
            slot; -1 = lane not in this verify (free / mid-prefill).
          n_live: (num_slots,) live token columns per row (0 for -1
            lanes). Columns >= n_live[b] scatter their KV to the trash
            page and their logits are garbage the caller must ignore.

        Returns (logits (num_slots, T, V), updated kv). Live columns'
        fresh KV is written through the page table at positions[b]+t —
        pages covering positions[b]+n_live[b] tokens must be allocated.
        The caller commits the accepted prefix by advancing ``slot.pos``
        and rolls back the rejected tail by NOT advancing over it: rows
        >= pos are never attended and are overwritten before ``pos``
        crosses them again (docs/speculative.md).

        Attention families only: Mamba2/hybrid recurrent state is a
        single evolving tensor that cannot be rewound page-style.
        """
        cfg = self.cfg
        if cfg.family not in ATTN_FAMILIES:
            raise NotImplementedError(
                "verify_paged needs rewindable (paged) KV state; the "
                f"{cfg.family!r} family's recurrent state cannot roll "
                "back rejected draft tokens")
        if cfg.head_layout == "hd":
            raise NotImplementedError("paged serving requires head_layout="
                                      "'heads'")
        if cfg.family in ("audio", "vlm"):
            raise NotImplementedError(
                "paged serving covers token-prompt families only")
        b, t_v = tokens.shape
        x = params["embed"][tokens]
        if act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        pos_c = jnp.maximum(positions, 0)
        trash = kv["k"].shape[1] - 1
        ps = kv["k"].shape[2]
        max_seq = page_table.shape[1] * ps
        phys = jnp.where(page_table >= 0, page_table, trash)      # (B, NP)
        view = self._paged_view(kv, phys)
        x, _, _, slabs = self._run_blocks(
            params, x, qc, q_offset=positions, prefix_len=0,
            cache=view, return_slabs=True, multi_slab=True)
        # scatter the T fresh rows per slot; dead columns -> trash page
        tok_pos = pos_c[:, None] + jnp.arange(t_v)[None, :]       # (B, T)
        live = (positions >= 0)[:, None] \
            & (jnp.arange(t_v)[None, :] < n_live[:, None])
        tok_pos = jnp.minimum(tok_pos, max_seq - 1)   # dead cols: clamp
        page, off = tok_pos // ps, tok_pos % ps
        tgt = jnp.where(live, jnp.take_along_axis(phys, page, axis=1),
                        trash)                                    # (B, T)
        new_kv = dict(kv)
        for key in ("k", "v"):
            slab = self._encode_rows(kv, key, slabs[key])
            new_kv[key] = kv[key].at[:, tgt, off].set(slab)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)                            # (B, T, V)
        return logits, new_kv


def _merge_live_states(old, new, live: jax.Array):
    """Per-lane select on slot-indexed state pytrees.

    old/new: trees of (L, num_slots, ...) arrays; live: (num_slots,) bool.
    Lanes with live=False keep their old state — decode steps must not
    clobber the recurrent state of slots that are mid-prefill."""
    return jax.tree_util.tree_map(
        lambda o, n: jnp.where(
            live.reshape((1, -1) + (1,) * (o.ndim - 2)), n.astype(o.dtype), o),
        old, new)


@functools.lru_cache(maxsize=None)
def _registry():
    from repro import configs
    return configs.REGISTRY


def build_model(cfg_or_name) -> Model:
    if isinstance(cfg_or_name, str):
        cfg_or_name = _registry()[cfg_or_name]()
    return Model(cfg_or_name)
