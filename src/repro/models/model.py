"""Unified model: one class covering all six assigned architecture families.

Layer stacks are ``jax.lax.scan`` over stacked block parameters (leading
layer axis) — this keeps HLO size and CPU compile time tractable at
62-layer × 512-device dry-run scale. Per-layer heterogeneity (gemma3's 5:1
local:global pattern, zamba2's shared attention block) is expressed as
per-layer scalars fed through the scan.

API (all functional, params are plain pytrees):

  init(key, qc)                     -> params
  forward(params, batch, qc)        -> (logits, aux)
  loss(params, batch, qc)           -> (scalar, metrics)
  init_cache(batch, max_seq)        -> cache
  prefill(params, batch, cache, qc) -> (next_logits, cache)
  decode(params, tokens, cache, qc) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lut import DENSE, QuantConfig
from .config import ModelConfig
from .layers import (attention, init_attention, init_mlp, mlp, rms_norm)
from .mamba2 import init_mamba2, mamba2_block, mamba2_decode
from .moe import init_moe, moe_ffn

Params = Dict[str, Any]

ATTN_FAMILIES = ("dense", "moe", "audio", "vlm")


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)

    def _init_block(self, key, qc: QuantConfig):
        cfg, dtype = self.cfg, self.dtype
        if cfg.family in ("ssm", "hybrid"):
            return init_mamba2(key, cfg, qc, dtype)
        ka, kf = jax.random.split(key)
        block = {"attn": init_attention(ka, cfg, qc, dtype)}
        if cfg.family == "moe":
            block["moe"] = init_moe(kf, cfg, qc, dtype)
        else:
            block["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff, cfg, qc, dtype)
        return block

    def init(self, key: jax.Array, qc: QuantConfig = DENSE) -> Params:
        cfg, dtype = self.cfg, self.dtype
        ke, kb, kh, ks = jax.random.split(key, 4)
        params: Params = {"final_norm": jnp.zeros((cfg.d_model,), dtype)}
        if cfg.family != "audio":
            params["embed"] = (0.02 * jax.random.normal(
                ke, (cfg.vocab_size, cfg.d_model))).astype(dtype)
        layer_keys = jax.random.split(kb, cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: self._init_block(k, qc))(layer_keys)
        if cfg.family == "hybrid":
            ka, km = jax.random.split(ks)
            params["shared_attn"] = {
                "attn": init_attention(ka, cfg, qc, dtype),
                "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg, qc, dtype),
            }
        if cfg.family == "audio":
            params["heads"] = (0.02 * jax.random.normal(
                kh, (cfg.num_codebooks, cfg.d_model, cfg.vocab_size))
            ).astype(dtype)
            # audio inputs are stub frame embeddings; a learned input proj
            # stands in for the EnCodec codebook-sum embedding.
            params["in_proj"] = (0.02 * jax.random.normal(
                ke, (cfg.d_model, cfg.d_model))).astype(dtype)
        elif not cfg.tie_embeddings:
            params["head"] = (0.02 * jax.random.normal(
                kh, (cfg.d_model, cfg.vocab_size))).astype(dtype)
        return params

    # ------------------------------------------------------------------
    # embedding / head per family
    # ------------------------------------------------------------------
    def _embed(self, params: Params, batch: Dict) -> Tuple[jax.Array, int]:
        """Returns (x (B, S, D), prefix_len)."""
        cfg = self.cfg
        if cfg.family == "audio":
            x = batch["embeds"].astype(self.dtype) @ params["in_proj"]
            return x, 0
        if cfg.family == "vlm":
            tok = params["embed"][batch["tokens"]]
            patches = batch["patch_embeds"].astype(self.dtype)
            return jnp.concatenate([patches, tok], axis=1), cfg.num_patches
        return params["embed"][batch["tokens"]], 0

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            return jnp.einsum("bsd,qdv->bsqv", x, params["heads"])
        if cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["head"]

    # ------------------------------------------------------------------
    # per-layer static metadata
    # ------------------------------------------------------------------
    def _windows(self) -> jax.Array:
        cfg = self.cfg
        return jnp.array(
            [0 if cfg.layer_is_global(i) else cfg.sliding_window
             for i in range(cfg.num_layers)], jnp.int32)

    def _attn_slot_list(self):
        """Hybrid: shared-attention invocation slot per layer (-1 = none)."""
        cfg = self.cfg
        slots, s = [], 0
        for i in range(cfg.num_layers):
            if cfg.shared_attn_every and (i % cfg.shared_attn_every
                                          == cfg.shared_attn_every - 1):
                slots.append(s)
                s += 1
            else:
                slots.append(-1)
        return slots

    def _attn_slots(self) -> jax.Array:
        return jnp.array(self._attn_slot_list(), jnp.int32)

    @property
    def num_attn_slots(self) -> int:
        return sum(1 for s in self._attn_slot_list() if s >= 0)

    # ------------------------------------------------------------------
    # block runners
    # ------------------------------------------------------------------
    def _run_blocks(self, params: Params, x: jax.Array, qc: QuantConfig,
                    q_offset, prefix_len,
                    cache: Optional[Params] = None):
        """Scan over the layer stack. Returns (x, recon, moe_aux, new_cache)."""
        cfg = self.cfg
        windows = self._windows()
        decode = cache is not None and x.shape[1] == 1

        if cfg.family in ATTN_FAMILIES:
            # Cache handling [§Perf I3/I5]:
            #  * decode: the cache is a scan INVARIANT (read-only per-layer
            #    slices are free); each layer emits only its new-token KV
            #    slab via ys, and the cache is updated ONCE after the scan.
            #  * prefill: the cache travels in the carry and each layer
            #    updates its slice in place — streaming it through xs/ys
            #    would rebuild the full stacked buffer every layer.
            # Layer grouping [§Perf I8]: local:global patterns (gemma3) scan
            # over groups of `global_every` with the window STATIC per
            # sub-layer, enabling the block-local attention fast path.
            slab_mode = decode and cfg.head_layout != "hd"

            def layer_fn(h, recon, aux, c_full, p_l, win, li):
                src = cache if slab_mode else c_full
                c_l = None
                if src is not None:
                    c_l = jax.tree_util.tree_map(
                        lambda t: jax.lax.dynamic_index_in_dim(
                            t, li, 0, keepdims=False), src)
                a, r1, new_c = attention(p_l["attn"], h, cfg, qc,
                                         q_offset=q_offset, window=win,
                                         prefix_len=prefix_len, cache=c_l,
                                         decode_slab=slab_mode)
                h = h + a
                if cfg.family == "moe":
                    f, r2, a2 = moe_ffn(p_l["moe"], h, cfg, qc)
                    aux = aux + a2
                else:
                    f, r2 = mlp(p_l["mlp"], h, cfg, qc)
                h = h + f
                slab = None
                if slab_mode:
                    slab = new_c
                elif c_full is not None:
                    c_full = jax.tree_util.tree_map(
                        lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                            full, upd.astype(full.dtype), li, 0),
                        c_full, new_c)
                return h, recon + r1 + r2, aux, c_full, slab

            ge = cfg.global_every
            # grouping pays off where the static window enables the
            # block-local path (train/prefill); decode keeps the flat scan
            # (slab path) — grouping there only perturbs fusion patterns.
            grouped = (ge > 1 and cfg.sliding_window > 0
                       and cfg.num_layers >= ge and not decode)
            carry_cache = cache is not None and not slab_mode
            z0 = jnp.zeros((), jnp.float32)

            if grouped:
                n_groups, tail = divmod(cfg.num_layers, ge)
                gp = jax.tree_util.tree_map(
                    lambda t: t[:n_groups * ge].reshape(
                        n_groups, ge, *t.shape[1:]), params["blocks"])
                tail_p = jax.tree_util.tree_map(
                    lambda t: t[n_groups * ge:], params["blocks"])

                def gbody(carry, xs):
                    if carry_cache:
                        h, recon, aux, c_full = carry
                    else:
                        h, recon, aux = carry
                        c_full = None
                    g_params, gid = xs
                    slabs = []
                    for j in range(ge):
                        p_l = jax.tree_util.tree_map(
                            lambda t: t[j], g_params)
                        win = 0 if j == ge - 1 else cfg.sliding_window
                        li = gid * ge + j
                        h, recon, aux, c_full, slab = layer_fn(
                            h, recon, aux, c_full, p_l, win, li)
                        slabs.append(slab)
                    ys = (jax.tree_util.tree_map(
                        lambda *t: jnp.stack(t), *slabs)
                        if slab_mode else None)
                    if carry_cache:
                        return (h, recon, aux, c_full), ys
                    return (h, recon, aux), ys

                if cfg.remat:
                    gbody = jax.checkpoint(gbody)
                gids = jnp.arange(n_groups, dtype=jnp.int32)
                carry0 = (x, z0, z0, cache) if carry_cache else (x, z0, z0)
                carry, ys = jax.lax.scan(gbody, carry0, (gp, gids))
                if carry_cache:
                    x, recon, aux, new_cache = carry
                else:
                    x, recon, aux = carry
                    new_cache = cache if slab_mode else None
                slab_list = []
                if slab_mode and ys is not None:
                    flat = jax.tree_util.tree_map(
                        lambda t: t.reshape(-1, *t.shape[2:]), ys)
                    slab_list.append(flat)
                # tail layers (num_layers % global_every), unscanned
                c_full = new_cache if carry_cache else None
                for j in range(tail):
                    li = n_groups * ge + j
                    p_l = jax.tree_util.tree_map(lambda t: t[j], tail_p)
                    win = 0 if cfg.layer_is_global(li) else \
                        cfg.sliding_window
                    x, recon, aux, c_full, slab = layer_fn(
                        x, recon, aux, c_full, p_l, win, jnp.int32(li))
                    if slab_mode:
                        slab_list.append(jax.tree_util.tree_map(
                            lambda t: t[None], slab))
                if carry_cache:
                    new_cache = c_full
                if slab_mode:
                    slabs = jax.tree_util.tree_map(
                        lambda *t: jnp.concatenate(t, 0), *slab_list)
                    new_cache = {
                        key: jax.lax.dynamic_update_slice_in_dim(
                            cache[key], slabs[key].astype(cache[key].dtype),
                            q_offset, axis=2)
                        for key in ("k", "v")}
                return x, recon, aux, new_cache

            def body(carry, xs):
                if carry_cache:
                    h, recon, aux, c_full = carry
                else:
                    h, recon, aux = carry
                    c_full = None
                p_l, win, li = xs
                h, recon, aux, c_full, slab = layer_fn(
                    h, recon, aux, c_full, p_l, win, li)
                if carry_cache:
                    return (h, recon, aux, c_full), slab
                return (h, recon, aux), slab

            if cfg.remat:
                body = jax.checkpoint(body)
            layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
            xs = (params["blocks"], windows, layer_ids)
            carry0 = (x, z0, z0, cache) if carry_cache else (x, z0, z0)
            carry, slabs = jax.lax.scan(body, carry0, xs)
            if carry_cache:
                x, recon, aux, new_cache = carry
                return x, recon, aux, new_cache
            x, recon, aux = carry
            if slab_mode:
                new_cache = {
                    key: jax.lax.dynamic_update_slice_in_dim(
                        cache[key], slabs[key], q_offset, axis=2)
                    for key in ("k", "v")}
                return x, recon, aux, new_cache
            return x, recon, aux, None

        # ssm / hybrid. Mamba states are FULLY replaced every step, so the
        # optimal cache movement is xs/ys streaming (one read + one write of
        # each layer's state); carry-DUS would rebuild the stacked buffer
        # per layer. (The attention KV cache is the opposite case — see the
        # slab path above.) [§Perf I7]
        slots = self._attn_slots() if cfg.family == "hybrid" else None
        shared = params.get("shared_attn")

        def body(carry, xs):
            if cfg.family == "hybrid":
                h, recon, aux, attn_cache = carry
                if cache is None:
                    p_l, slot, li = xs
                    c_l = None
                else:
                    p_l, slot, li, c_l = xs
            else:
                h, recon, aux = carry
                attn_cache = None
                if cache is None:
                    p_l, li = xs
                    c_l = None
                else:
                    p_l, li, c_l = xs
            if decode:
                o, r, new_c = mamba2_decode(p_l, h, cfg, qc, c_l)
            else:
                o, r, new_c = mamba2_block(p_l, h, cfg, qc, c_l)
            h = h + o
            recon = recon + r

            if cfg.family == "hybrid":
                # decode: attn cache is read-only; each invocation emits a
                # new-token slab through ys (zeros on non-attn layers), and
                # the slot rows are written back once after the scan. [I5b]
                slab_mode = decode and attn_cache is not None \
                    and cfg.head_layout != "hd"

                def with_attn(operand):
                    h, attn_cache, recon = operand
                    if attn_cache is None:
                        c_a = None
                    else:
                        c_a = jax.tree_util.tree_map(
                            lambda t: jax.lax.dynamic_index_in_dim(
                                t, jnp.maximum(slot, 0), 0, keepdims=False),
                            attn_cache)
                    a, r1, new_a = attention(shared["attn"], h, cfg, qc,
                                             q_offset=q_offset, window=0,
                                             prefix_len=prefix_len, cache=c_a,
                                             decode_slab=slab_mode)
                    h = h + a
                    f, r2 = mlp(shared["mlp"], h, cfg, qc)
                    h = h + f
                    if attn_cache is not None and not slab_mode:
                        attn_cache = jax.tree_util.tree_map(
                            lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                                full, upd.astype(full.dtype),
                                jnp.maximum(slot, 0), 0),
                            attn_cache, new_a)
                    if slab_mode:
                        return h, attn_cache, recon + r1 + r2, new_a
                    return h, attn_cache, recon + r1 + r2, None

                def no_attn(operand):
                    h, attn_cache, recon = operand
                    if slab_mode:
                        b = h.shape[0]
                        kvh, hd = cfg.num_kv_heads, cfg.head_dim
                        dt = attn_cache["k"].dtype
                        zero_slab = {
                            "k": jnp.zeros((b, 1, kvh, hd), dt),
                            "v": jnp.zeros((b, 1, kvh, hd), dt)}
                        return h, attn_cache, recon, zero_slab
                    return h, attn_cache, recon, None

                h, attn_cache, recon, slab = jax.lax.cond(
                    slot >= 0, with_attn, no_attn, (h, attn_cache, recon))
                return (h, recon, aux, attn_cache), (new_c, slab)
            return (h, recon, aux), new_c

        if cfg.remat:
            body = jax.checkpoint(body)

        z0 = jnp.zeros((), jnp.float32)
        layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        if cfg.family == "hybrid":
            attn_cache0 = cache["attn"] if cache is not None else None
            xs = (params["blocks"], slots, layer_ids)
            if cache is not None:
                xs = xs + (cache["mamba"],)
            (x, recon, aux, attn_cache), (new_mamba, slabs) = jax.lax.scan(
                body, (x, z0, z0, attn_cache0), xs)
            if decode and attn_cache0 is not None \
                    and cfg.head_layout != "hd":
                # gather the slab rows at the attn layers (static indices)
                # and write all slots' new-token KV in one update
                slot_layers = jnp.array(
                    [i for i, s in enumerate(self._attn_slot_list())
                     if s >= 0], jnp.int32)
                attn_cache = {
                    key: jax.lax.dynamic_update_slice_in_dim(
                        attn_cache0[key],
                        slabs[key][slot_layers].astype(
                            attn_cache0[key].dtype),
                        q_offset, axis=2)
                    for key in ("k", "v")}
            new_cache = (None if cache is None
                         else {"mamba": new_mamba, "attn": attn_cache})
            return x, recon, aux, new_cache

        xs = (params["blocks"], layer_ids)
        if cache is not None:
            xs = xs + (cache,)
        (x, recon, aux), new_cache = jax.lax.scan(body, (x, z0, z0), xs)
        return x, recon, aux, new_cache

    # ------------------------------------------------------------------
    # train forward + loss
    # ------------------------------------------------------------------
    def forward(self, params: Params, batch: Dict, qc: QuantConfig = DENSE):
        x, prefix_len = self._embed(params, batch)
        x, recon, moe_aux, _ = self._run_blocks(
            params, x, qc, q_offset=0, prefix_len=prefix_len)
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = self._head(params, x)
        return logits, {"recon": recon, "moe_aux": moe_aux}

    def loss(self, params: Params, batch: Dict, qc: QuantConfig = DENSE):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, qc)
        if cfg.family == "audio":
            labels = batch["labels"]                    # (B, S, Q)
            lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(
                lp, labels[:, 1:, :, None], axis=-1)[..., 0]
            ce = jnp.mean(nll)
        elif cfg.family == "vlm":
            # loss only over the text region (after the image prefix)
            p = cfg.num_patches
            text_logits = logits[:, p - 1:-1]           # predicts tokens
            labels = batch["tokens"]
            lp = jax.nn.log_softmax(text_logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
            ce = jnp.mean(nll)
        else:
            labels = batch["tokens"][:, 1:]
            lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
            ce = jnp.mean(nll)
        total = (ce + qc.recon_weight * aux["recon"]
                 + 0.01 * aux["moe_aux"])
        metrics = {"ce": ce, "recon": aux["recon"], "moe_aux": aux["moe_aux"],
                   "loss": total}
        return total, metrics

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_seq: int,
                   dtype=None) -> Params:
        cfg = self.cfg
        dtype = dtype or self.dtype
        l, b, t = cfg.num_layers, batch_size, max_seq
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        pos = jnp.zeros((), jnp.int32)
        if cfg.family in ATTN_FAMILIES:
            return {"layers": {
                "k": jnp.zeros((l, b, t, kvh, hd), dtype),
                "v": jnp.zeros((l, b, t, kvh, hd), dtype)},
                "pos": pos}
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        mamba = {
            "conv": jnp.zeros((l, b, cfg.ssm_conv - 1, conv_dim), dtype),
            "h": jnp.zeros((l, b, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32)}
        if cfg.family == "ssm":
            return {"layers": mamba, "pos": pos}
        n_inv = self.num_attn_slots
        return {"layers": {
            "mamba": mamba,
            "attn": {"k": jnp.zeros((n_inv, b, t, kvh, hd), dtype),
                     "v": jnp.zeros((n_inv, b, t, kvh, hd), dtype)}},
            "pos": pos}

    def prefill(self, params: Params, batch: Dict, cache: Params,
                qc: QuantConfig = DENSE):
        """Process the prompt; returns (next-token logits (B, V...), cache)."""
        x, prefix_len = self._embed(params, batch)
        s = x.shape[1]
        x, _, _, new_layers = self._run_blocks(
            params, x, qc, q_offset=0, prefix_len=prefix_len,
            cache=cache["layers"])
        x = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = self._head(params, x)[:, 0]
        return logits, {"layers": new_layers,
                        "pos": jnp.asarray(s, jnp.int32)}

    def decode(self, params: Params, tokens: jax.Array, cache: Params,
               qc: QuantConfig = DENSE):
        """One decode step. tokens (B, 1) int32 (audio: embeds (B, 1, D);
        vlm: text token ids). Returns (logits (B, V...), cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        if cfg.family == "audio":
            x = tokens.astype(self.dtype) @ params["in_proj"]
        else:
            x = params["embed"][tokens]
        x, _, _, new_layers = self._run_blocks(
            params, x, qc, q_offset=pos, prefix_len=0, cache=cache["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)[:, 0]
        return logits, {"layers": new_layers, "pos": pos + 1}


@functools.lru_cache(maxsize=None)
def _registry():
    from repro import configs
    return configs.REGISTRY


def build_model(cfg_or_name) -> Model:
    if isinstance(cfg_or_name, str):
        cfg_or_name = _registry()[cfg_or_name]()
    return Model(cfg_or_name)
