"""LUTBoost — the paper's lightweight multistage model converter (§V).

Stage ① swap linears for LUT operators and initialise centroids by per-
          subspace k-means over calibration activations;
Stage ② train *centroids only* (weights frozen) — fast convergence to a
          faithful representation of each layer's input distribution;
Stage ③ joint fine-tune of centroids + weights.

This module provides the conversion utilities and the stage bookkeeping; the
actual optimisation loop lives in ``repro.train.trainer`` (big models) and in
``benchmarks/table2_lutboost.py`` (paper-style small-model studies).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .codebook import kmeans_codebook
from .lut import QuantConfig, precompute_layer

# ---------------------------------------------------------------------------
# Stage ①: calibration capture + k-means init
# ---------------------------------------------------------------------------

_CAPTURE: Optional[Dict[int, np.ndarray]] = None


@contextlib.contextmanager
def capture_activations():
    """Context manager that records the input of every LutLinear, keyed by
    ``id(params['z'])``. Must run *eagerly* (outside jit) so array object
    identity is stable — conversion is a one-off offline step, so this costs
    nothing at training time."""
    global _CAPTURE
    prev = _CAPTURE
    _CAPTURE = {}
    try:
        yield _CAPTURE
    finally:
        _CAPTURE = prev


def record_activation(p: Dict[str, Any], x: jax.Array) -> None:
    """Called by LutLinear on every apply; no-op unless capturing."""
    if _CAPTURE is not None and "z" in p and not isinstance(
            x, jax.core.Tracer):
        key = id(p["z"])
        flat = np.asarray(x).reshape(-1, x.shape[-1])
        prev = _CAPTURE.get(key)
        _CAPTURE[key] = flat if prev is None else np.concatenate(
            [prev, flat], axis=0)


def _walk_lut_layers(tree, fn):
    """Apply fn to every sub-dict that looks like a LutLinear (has w & z)."""
    if isinstance(tree, dict):
        if "z" in tree and "w" in tree:
            return fn(tree)
        return {k: _walk_lut_layers(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_walk_lut_layers(v, fn) for v in tree)
    return tree


def kmeans_init_from_capture(params, captured: Dict[int, np.ndarray],
                             qc: QuantConfig, iters: int = 10,
                             seed: int = 0) -> Any:
    """Replace every captured layer's centroids with k-means of its inputs.

    Args:
      params: model params pytree containing LutLinear sub-dicts (w & z).
      captured: ``id(layer["z"]) -> (rows, K)`` activation matrix from
        :func:`capture_activations`.
      qc: quant config; ``qc.spec`` fixes (v, c, metric) for k-means.
      iters: Lloyd iterations per layer; seed: base PRNG seed (offset by
        a per-layer counter so layers get distinct inits).

    Returns: params with each captured layer's ``z`` replaced by its
    per-subspace k-means codebook (dtype preserved); uncaptured layers
    are returned untouched.
    """
    counter = [0]

    def init(layer):
        key = id(layer["z"])
        if key not in captured:
            return layer
        counter[0] += 1
        acts = jnp.asarray(captured[key])
        k = layer["w"].shape[0]
        z = kmeans_codebook(acts, k, qc.spec, iters=iters,
                            key=jax.random.PRNGKey(seed + counter[0]))
        out = dict(layer)
        out["z"] = z.astype(layer["z"].dtype)
        return out

    return _walk_lut_layers(params, init)


def convert(apply_fn: Callable, params, calib_batch, qc: QuantConfig,
            iters: int = 10, seed: int = 0):
    """LUTBoost stage ①: run one calibration forward, k-means-init centroids.

    Args:
      apply_fn: ``apply_fn(params, batch)`` running the model; it must
        execute every LutLinear *eagerly* (outside jit) so the capture
        hook sees concrete arrays.
      params: params whose LutLinear layers already carry ``z`` leaves
        (init the model with a ``lut_train`` QuantConfig).
      calib_batch: one representative batch — its activations define the
        centroid init.
      qc / iters / seed: forwarded to :func:`kmeans_init_from_capture`.

    Returns: params with calibrated centroids (stage ② trains them).
    """
    with capture_activations() as captured:
        apply_fn(params, calib_batch)
    return kmeans_init_from_capture(params, captured, qc, iters, seed)


# ---------------------------------------------------------------------------
# Stages ②/③: trainable-parameter masking + schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LutBoostSchedule:
    """Paper §VII-A hyper-parameters (ResNet defaults)."""
    stage2_steps: int = 1000
    stage3_steps: int = 5000
    lr_stage2: float = 1e-3
    lr_stage3: float = 5e-4
    recon_weight_stage2: float = 0.05
    recon_weight_stage3: float = 0.05

    def stage(self, step: int) -> int:
        return 2 if step < self.stage2_steps else 3

    def lr(self, step: int) -> float:
        return self.lr_stage2 if step < self.stage2_steps else self.lr_stage3

    def recon_weight(self, step: int) -> float:
        return (self.recon_weight_stage2 if step < self.stage2_steps
                else self.recon_weight_stage3)

    @property
    def total_steps(self) -> int:
        return self.stage2_steps + self.stage3_steps


def centroid_only_mask(params) -> Any:
    """Pytree of bools matching ``params``: True only on centroid (``z``)
    leaves — the stage-② trainable set (weights frozen)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def is_centroid(path) -> bool:
        last = path[-1]
        return getattr(last, "key", None) == "z"

    paths = {jax.tree_util.keystr(p) for p, _ in flat if is_centroid(p)}
    return jax.tree_util.tree_map_with_path(
        lambda p, _: jax.tree_util.keystr(p) in paths, params)


def stage_mask(params, stage: int):
    """Trainable mask for a LUTBoost stage: centroids-only for stage ②,
    everything for stage ③."""
    if stage == 2:
        return centroid_only_mask(params)
    return jax.tree_util.tree_map(lambda _: True, params)


def apply_mask(grads, mask):
    """Zero out gradient leaves wherever ``mask`` is False (frozen)."""
    return jax.tree_util.tree_map(
        lambda g, m: g if m else jnp.zeros_like(g), grads, mask)


# ---------------------------------------------------------------------------
# Deployment: precompute every LUT
# ---------------------------------------------------------------------------

def precompute_model(params, qc: QuantConfig):
    """Build inference LUTs for every LutLinear in the tree (paper step-2).

    Adds ``lut (nc, c, N)`` — int8 plus ``lut_scale (N,)`` when
    ``qc.lut_dtype == "int8"`` — to each LutLinear so it can serve in
    ``mode="lut_infer"`` (no dense GEMMs at runtime; the serving engines
    consume these params directly)."""
    return _walk_lut_layers(params, lambda p: precompute_layer(p, qc))
