"""repro.core — the paper's contribution: VQ-AMM / LUT-based GEMM + LUTBoost."""
from .codebook import CodebookSpec, init_centroids, kmeans, kmeans_codebook
from .kv_codebook import (CODEBOOK_KEY, KVCodebook, codebook_from_tree,
                          kv_decode, kv_decode_stacked, kv_encode,
                          kv_encode_stacked)
from .lut import (DENSE, QuantConfig, build_lut, lut_linear_apply,
                  lut_linear_init, precompute_layer, quantize_lut_int8,
                  strip_for_inference)
from .lutboost import (LutBoostSchedule, apply_mask, capture_activations,
                       centroid_only_mask, convert, kmeans_init_from_capture,
                       precompute_model, stage_mask)
from .similarity import (ALPHA_SIM, Metric, assign, assign_subspaces,
                         pairwise_distance, pairwise_distance_subspaces,
                         soft_assignment, ste_quantize,
                         ste_quantize_subspaces)
