"""Codebooks for VQ-AMM (paper §II-B step-1).

A :class:`CodebookSpec` describes the vector-quantization operating point of
one LUT-ified GEMM:

  * ``v``       sub-vector length (K is split into ``nc = K // v`` subspaces)
  * ``c``       number of centroids per subspace
  * ``metric``  similarity metric used by assignment

Centroid tensors are shaped ``(nc, c, v)`` and live alongside the weights in
the model pytree (they are trainable parameters in LUTBoost stages 2/3).

K-means initialisation from calibration activations is LUTBoost step-1.
Implemented as a fully-jittable ``jax.lax`` loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .similarity import Metric, pairwise_distance


@dataclasses.dataclass(frozen=True)
class CodebookSpec:
    v: int = 8
    c: int = 16
    metric: Metric = "l2"

    def num_subspaces(self, k: int) -> int:
        if k % self.v != 0:
            raise ValueError(f"K={k} not divisible by v={self.v}")
        return k // self.v

    @property
    def equivalent_bits(self) -> float:
        """Paper Table V: equivalent bit-width = ceil(log2 c) / v."""
        import math
        return math.ceil(math.log2(self.c)) / self.v

    def lut_entries(self, k: int, n: int) -> int:
        """Number of LUT entries for a (K, N) weight matrix."""
        return self.num_subspaces(k) * self.c * n


def init_centroids(key: jax.Array, k: int, spec: CodebookSpec,
                   scale: float = 0.02, dtype=jnp.float32) -> jax.Array:
    """Random-normal centroid init, shape (nc, c, v)."""
    nc = spec.num_subspaces(k)
    return scale * jax.random.normal(key, (nc, spec.c, spec.v), dtype=dtype)


def kmeans(x: jax.Array, c: int, metric: Metric = "l2", iters: int = 10,
           key: Optional[jax.Array] = None) -> jax.Array:
    """K-means over x (n, v) -> centroids (c, v).

    Uses k-means++-lite seeding (random distinct samples) and Lloyd updates.
    For L1 the true minimiser is the median; we use the mean for all metrics
    (the paper trains centroids afterwards, so seeding quality only needs to
    be "good", not optimal). Empty clusters are re-seeded from the data point
    farthest from its centroid.
    """
    n, v = x.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    perm = jax.random.permutation(key, n)[:c]
    init = x[perm]

    def step(cents, _):
        d = pairwise_distance(x, cents, metric)               # (n, c)
        idx = jnp.argmin(d, axis=-1)                          # (n,)
        onehot = jax.nn.one_hot(idx, c, dtype=x.dtype)        # (n, c)
        counts = onehot.sum(axis=0)                           # (c,)
        sums = jnp.einsum("nc,nv->cv", onehot, x)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # Re-seed empty clusters with the worst-represented point.
        worst = x[jnp.argmax(jnp.min(d, axis=-1))]
        new = jnp.where((counts > 0)[:, None], new, worst[None, :])
        return new, None

    cents, _ = jax.lax.scan(step, init, None, length=iters)
    return cents


def kmeans_codebook(acts: jax.Array, k: int, spec: CodebookSpec,
                    iters: int = 10, key: Optional[jax.Array] = None,
                    max_samples: int = 4096) -> jax.Array:
    """LUTBoost step-1: k-means per subspace over calibration activations.

    acts : (..., K) calibration activations for this layer.
    returns centroids (nc, c, v).
    """
    nc = spec.num_subspaces(k)
    flat = acts.reshape(-1, nc, spec.v)                       # (n, nc, v)
    n = flat.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    # Split ONCE up front: the subsample permutation and the per-subspace
    # k-means inits must consume distinct keys (re-splitting the key that
    # already produced the permutation would correlate the two streams).
    key_sel, key_init = jax.random.split(key)
    if n > max_samples:
        sel = jax.random.permutation(key_sel, n)[:max_samples]
        flat = flat[sel]
    keys = jax.random.split(key_init, nc)
    return jax.vmap(lambda xs, kk: kmeans(xs, spec.c, spec.metric, iters, kk),
                    in_axes=(1, 0))(flat, keys)               # (nc, c, v)
