"""LUT construction and the ``LutLinear`` layer — the paper's technique as a
first-class, drop-in replacement for every projection in the framework.

Three operating modes (``QuantConfig.mode``):

  * ``dense``      — plain ``x @ w + b`` (the paper's comparison baseline).
  * ``lut_train``  — LUTBoost training path: STE quantisation of activations,
                     forward value ``Â·W`` with backward ``A·W`` (paper §V-2),
                     plus the two-sided stop-gradient reconstruction loss.
  * ``lut_infer``  — deployment path: precomputed LUT (optionally int8),
                     assignment + gather-accumulate kernels. No dense weight
                     needed at runtime.

Parameters of one LutLinear (a plain pytree dict):
  w  (K, N)            dense weight  (absent after `strip_for_inference`)
  b  (N,)              optional bias
  z  (nc, c, v)        centroids (trainable in LUTBoost stages 2/3)
  lut (nc, c, N)       precomputed table      (inference only)
  lut_scale (N,)       dequant scale          (int8 inference only)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from .codebook import CodebookSpec, init_centroids
from .similarity import (Metric, assign_subspaces, ste_quantize_subspaces)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Global VQ-AMM operating point (threaded through every model)."""
    mode: str = "dense"            # dense | lut_train | lut_infer
    v: int = 8                     # sub-vector length
    c: int = 16                    # centroids per subspace
    metric: Metric = "l2"          # l2 | l1 | chebyshev
    lut_dtype: str = "float32"     # float32 | bfloat16 | int8
    recon_weight: float = 0.05     # paper's penalty ratio
    task_grad_to_centroids: bool = False   # LUT-NN-style alternative path
    impl: str = "auto"             # kernel impl: auto | fused | pallas | ref
    fuse: bool = True              # lut_infer: one fused assign+LUT kernel
    #                                (indices stay in VMEM) vs two-pass
    flash: str = "auto"            # paged decode attention: auto | pallas |
    #                                ref | gather (auto = pallas on TPU,
    #                                gather elsewhere; see kernels/
    #                                flash_decode.py)
    kv_quant: str = "none"         # paged KV pool: none (fp rows) | vq
    #                                (pages store uint8 codebook indices;
    #                                see core/kv_codebook.py + docs/
    #                                serving.md §KV-cache quantization)
    kv_v: int = 4                  # KV sub-vector length over head_dim
    kv_c: int = 16                 # KV centroids per subspace (<= 256)

    def __post_init__(self):
        if self.kv_quant not in ("none", "vq"):
            raise ValueError(
                f"kv_quant must be 'none' or 'vq', got {self.kv_quant!r}")
        if self.kv_quant == "vq" and self.kv_c > 256:
            raise ValueError(
                f"kv_c={self.kv_c} does not fit uint8 page codes")

    @property
    def spec(self) -> CodebookSpec:
        return CodebookSpec(v=self.v, c=self.c, metric=self.metric)

    @property
    def is_lut(self) -> bool:
        return self.mode in ("lut_train", "lut_infer")

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


DENSE = QuantConfig(mode="dense")


def lut_linear_init(key: jax.Array, k: int, n: int, qc: QuantConfig,
                    bias: bool = False, dtype=jnp.float32,
                    w_scale: Optional[float] = None) -> Params:
    """Initialise a (K, N) projection, with centroids when LUT mode is on."""
    kw, kz = jax.random.split(key)
    scale = w_scale if w_scale is not None else (1.0 / (k ** 0.5))
    p: Params = {"w": (scale * jax.random.normal(kw, (k, n))).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    if qc.is_lut:
        p["z"] = init_centroids(kz, k, qc.spec, dtype=dtype)
    return p


def build_lut(w: jax.Array, z: jax.Array) -> jax.Array:
    """Precompute LUT[k, j, n] = z[k, j, :] . w[k*v:(k+1)*v, n] (paper step-2).

    w (K, N), z (nc, c, v) -> (nc, c, N)
    """
    nc, c, v = z.shape
    k, n = w.shape
    assert nc * v == k, (w.shape, z.shape)
    wr = w.reshape(nc, v, n)
    return jnp.einsum("kcv,kvn->kcn", z.astype(jnp.float32),
                      wr.astype(jnp.float32))


def quantize_lut_int8(lut: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-output-column int8 quantisation of the LUT.

    The scale is shared across subspaces so the int accumulation
    ``sum_k lut8[k, idx, n]`` dequantises with one multiply per column.
    """
    amax = jnp.max(jnp.abs(lut), axis=(0, 1))                  # (N,)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    lut8 = jnp.clip(jnp.round(lut / scale[None, None, :]), -127, 127)
    return lut8.astype(jnp.int8), scale.astype(jnp.float32)


def precompute_layer(p: Params, qc: QuantConfig) -> Params:
    """Turn a trained LutLinear into its inference form (adds lut/scale).

    Handles leading batch dims on (w, z) — stacked scan layers (L, ...) and
    per-expert weights (L, E, ...) — by vmapping the table construction.
    """
    if "z" not in p:
        return p
    build = build_lut
    quant = quantize_lut_int8
    for _ in range(p["z"].ndim - 3):
        build = jax.vmap(build)
        quant = jax.vmap(quant)
    lut = build(p["w"], p["z"])
    out = dict(p)
    if qc.lut_dtype == "int8":
        out["lut"], out["lut_scale"] = quant(lut)
    elif qc.lut_dtype == "bfloat16":
        out["lut"] = lut.astype(jnp.bfloat16)
    else:
        out["lut"] = lut
    return out


def strip_for_inference(p: Params) -> Params:
    """Drop the dense weight once the LUT exists (deployment footprint)."""
    return {k: v for k, v in p.items() if k != "w" or "lut" not in p}


def lut_linear_apply(p: Params, x: jax.Array, qc: QuantConfig,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Apply the projection. Returns (out, recon_loss_scalar).

    x: (..., K) -> (..., N). recon_loss is 0.0 except in lut_train mode.
    """
    from . import lutboost  # circular-safe: only used for capture hook
    lutboost.record_activation(p, x)

    zero = jnp.zeros((), jnp.float32)
    if qc.mode == "dense" or "z" not in p:
        out = x @ p["w"]
        if "b" in p:
            out = out + p["b"]
        return out, zero

    k = p["z"].shape[0] * p["z"].shape[2]
    lead = x.shape[:-1]
    xs = x.reshape(*lead, k // qc.v, qc.v)

    if qc.mode == "lut_train":
        x_hat = ste_quantize_subspaces(xs, p["z"], qc.metric)
        a_hat = x_hat.reshape(*lead, k).astype(x.dtype)
        out_q = a_hat @ p["w"]                          # Â·W
        if qc.task_grad_to_centroids:
            # LUT-NN-style: task gradient reaches centroids through the STE.
            out = out_q
            out_d = jax.lax.stop_gradient(x) @ p["w"]
        else:
            # Paper-faithful: forward value Â·W, backward path A·W; centroids
            # learn only from the reconstruction loss.
            out_d = x @ p["w"]                          # A·W
            out = out_d + jax.lax.stop_gradient(out_q - out_d)
        sg = jax.lax.stop_gradient
        recon = (jnp.mean((sg(out_q) - out_d) ** 2)
                 + jnp.mean((out_q - sg(out_d)) ** 2))
        if "b" in p:
            out = out + p["b"]
        return out, recon.astype(jnp.float32)

    if qc.mode == "lut_infer":
        x2d = xs.reshape(-1, k // qc.v, qc.v)
        lut = p.get("lut")
        if lut is None:                    # on-the-fly (testing convenience)
            lut = build_lut(p["w"], p["z"])
        if qc.fuse:
            # CCM pipelined into IMM: no (M, nc) index tensor in HBM.
            out = kops.vq_amm(x2d, p["z"], lut, p.get("lut_scale"),
                              qc.metric, impl=qc.impl)
        else:
            idx = kops.vq_assign(x2d, p["z"], qc.metric, impl=qc.impl)
            out = kops.lut_matmul(idx, lut, p.get("lut_scale"), impl=qc.impl)
        out = out.reshape(*lead, -1).astype(x.dtype)
        if "b" in p:
            out = out + p["b"]
        return out, zero

    raise ValueError(f"unknown quant mode: {qc.mode}")


def assignment_only(p: Params, x: jax.Array, qc: QuantConfig) -> jax.Array:
    """Expose raw indices (used by tests/benchmarks). x (..., K)."""
    k = p["z"].shape[0] * p["z"].shape[2]
    xs = x.reshape(-1, k // qc.v, qc.v)
    return assign_subspaces(xs, p["z"], qc.metric)
