"""Vector-quantized KV-cache codebooks (ROADMAP item 2).

The paper's VQ + LUT thesis applied to serving *state*: paged KV pages
store per-subspace centroid indices (uint8, grouped over ``head_dim``)
instead of fp rows, so HBM per live token drops ``4·head_dim / nc``×
(fp32 pool → uint8 codes). A :class:`KVCodebook` holds one codebook per
layer for K and one for V, plus per-layer/per-head RMS scales that
normalise head magnitudes before assignment — one small ``(nc, c, v)``
table then covers every head of the layer.

Layout algebra (``nc = head_dim // v``, ``c <= 256`` so indices fit
uint8):

    fp row    (..., KVH, HD)   --encode-->   codes (..., KVH, nc) uint8
    codes     (..., KVH, nc)   --decode-->   fp row (..., KVH, HD)

    decode(codes)[..., h, s*v:(s+1)*v] = scale[h] * z[s, codes[..., h, s]]

Encode is plain-L2 nearest-centroid assignment (the fused-kernel metric
zoo is a weight-path concern; KV rows are smooth activations where L2 is
the right default). Both directions are pure ``jnp`` and jit-safe — they
run inside the engine's prefill/decode/verify steps, on the write path
(encode) and inside the attention kernels (decode / LUT-accumulate, see
``kernels/flash_decode.py``).

Fitting reuses the LUTBoost k-means (:func:`repro.core.codebook.kmeans`
via :func:`kmeans_codebook`), vmapped over layers, on calibration K/V
rows harvested from a short prefill. :meth:`KVCodebook.from_rows` builds
an *exact-cover* codebook (centroids = the row set, unit scales) — the
lossless fixture the parity/identity tests key on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .codebook import CodebookSpec, kmeans_codebook

#: pytree key carrying the codebook inside a quantized paged-cache dict —
#: ``Model`` methods detect a quantized pool by its presence.
CODEBOOK_KEY = "codebook"


# ---------------------------------------------------------------------------
# per-layer encode / decode (z (nc, c, v), scale (KVH,))
# ---------------------------------------------------------------------------

def kv_encode(rows: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    """Encode fp K/V rows to per-subspace centroid indices.

    rows (..., KVH, HD) -> codes (..., KVH, nc) uint8. L2 assignment in
    the scale-normalised space (the same space the codebook was fit in).
    """
    nc, c, v = z.shape
    x = rows.astype(jnp.float32) / scale[:, None]
    x = x.reshape(*rows.shape[:-1], nc, v)                 # (..., KVH, nc, v)
    zf = z.astype(jnp.float32)
    # batched MXU form of ||x - z||^2: ||x||^2 - 2<x,z> + ||z||^2
    x2 = jnp.sum(x * x, axis=-1)[..., None]                # (..., nc, 1)
    z2 = jnp.sum(zf * zf, axis=-1)                         # (nc, c)
    xz = jnp.einsum("...sv,scv->...sc", x, zf,
                    preferred_element_type=jnp.float32)
    d = x2 - 2.0 * xz + z2
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def kv_decode(codes: jax.Array, z: jax.Array, scale: jax.Array,
              dtype=jnp.float32) -> jax.Array:
    """Decode centroid indices back to fp rows.

    codes (..., KVH, nc) uint8 -> rows (..., KVH, HD). One gather from
    the tiny ``(nc, c, v)`` table — the pool itself stays uint8.
    """
    nc, c, v = z.shape
    idx = codes.astype(jnp.int32)
    sub = z[jnp.arange(nc), idx]                           # (..., KVH, nc, v)
    rows = sub.reshape(*codes.shape[:-1], nc * v)
    return (rows * scale[:, None]).astype(dtype)


def kv_encode_stacked(rows: jax.Array, z: jax.Array,
                      scale: jax.Array) -> jax.Array:
    """:func:`kv_encode` over a leading layer axis: rows (L, ..., KVH, HD),
    z (L, nc, c, v), scale (L, KVH) -> (L, ..., KVH, nc) uint8."""
    return jax.vmap(kv_encode)(rows, z, scale)


def kv_decode_stacked(codes: jax.Array, z: jax.Array, scale: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """:func:`kv_decode` over a leading layer axis."""
    return jax.vmap(lambda cd, zz, ss: kv_decode(cd, zz, ss, dtype))(
        codes, z, scale)


# ---------------------------------------------------------------------------
# the codebook object (host-side; arrays ride the cache pytree)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVCodebook:
    """Per-layer K/V codebooks + per-layer/per-head scales.

    zk/zv : (L, nc, c, v) float32 centroids (K / V streams fit separately
            — their distributions differ materially after RoPE).
    sk/sv : (L, KVH) float32 RMS scales dividing rows before assignment.
    """
    zk: jax.Array
    zv: jax.Array
    sk: jax.Array
    sv: jax.Array

    def __post_init__(self):
        l, nc, c, v = self.zk.shape
        if self.zv.shape != (l, nc, c, v):
            raise ValueError(f"zk {self.zk.shape} vs zv {self.zv.shape}")
        if self.sk.shape[0] != l or self.sk.shape != self.sv.shape:
            raise ValueError(f"scale shapes {self.sk.shape}/{self.sv.shape} "
                             f"do not match zk {self.zk.shape}")
        if c > 256:
            raise ValueError(f"c={c} does not fit uint8 codes")

    # -- shape algebra ------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return self.zk.shape[0]

    @property
    def nc(self) -> int:
        return self.zk.shape[1]

    @property
    def c(self) -> int:
        return self.zk.shape[2]

    @property
    def v(self) -> int:
        return self.zk.shape[3]

    @property
    def head_dim(self) -> int:
        return self.nc * self.v

    @property
    def bytes_per_token_per_kv_head(self) -> int:
        """uint8 codes per token per kv head for ONE of K/V."""
        return self.nc

    @property
    def equivalent_bits(self) -> float:
        """Paper Table V metric for the KV operating point."""
        return CodebookSpec(v=self.v, c=self.c).equivalent_bits

    def tree(self) -> Dict[str, jax.Array]:
        """The device pytree embedded in the paged cache under
        :data:`CODEBOOK_KEY` (leading L axis on every leaf so the model's
        per-layer cache slicing applies uniformly)."""
        return {"zk": self.zk, "zv": self.zv, "sk": self.sk, "sv": self.sv}

    def fingerprint(self) -> int:
        """64-bit content hash of the codebook — seeds the prefix-cache
        hash chain so pages encoded under different codebooks can never
        alias (the cache identifies *codes*, and codes are only
        comparable under the same codebook)."""
        import numpy as np
        h = 0
        for leaf in (self.zk, self.zv, self.sk, self.sv):
            h = hash((h, np.asarray(leaf).tobytes()))
        return h

    # -- host-side convenience wrappers (tests / harnesses) ----------------
    def encode(self, rows: jax.Array, which: str = "k") -> jax.Array:
        z, s = (self.zk, self.sk) if which == "k" else (self.zv, self.sv)
        return kv_encode_stacked(rows, z, s)

    def decode(self, codes: jax.Array, which: str = "k",
               dtype=jnp.float32) -> jax.Array:
        z, s = (self.zk, self.sk) if which == "k" else (self.zv, self.sv)
        return kv_decode_stacked(codes, z, s, dtype)

    # -- constructors -------------------------------------------------------
    @classmethod
    def fit(cls, k_rows: jax.Array, v_rows: jax.Array, *, v: int = 4,
            c: int = 16, iters: int = 8,
            key: Optional[jax.Array] = None) -> "KVCodebook":
        """K-means fit on calibration rows (L, T, KVH, HD).

        Rows are RMS-normalised per (layer, kv-head) first, so one
        ``(nc, c, v)`` table per layer covers heads with very different
        magnitudes (post-RoPE K norms vary ~10x across heads)."""
        if key is None:
            key = jax.random.PRNGKey(0)
        l, _, kvh, hd = k_rows.shape
        spec = CodebookSpec(v=v, c=c, metric="l2")
        spec.num_subspaces(hd)        # validates v | head_dim
        kk, kv_ = jax.random.split(key)

        def one_stream(rows, key_s):
            # rows (L, T, KVH, HD) -> scales (L, KVH), z (L, nc, c, v)
            scale = jnp.sqrt(
                jnp.mean(rows.astype(jnp.float32) ** 2, axis=(1, 3))) + 1e-6
            xs = rows.astype(jnp.float32) / scale[:, None, :, None]
            keys = jax.random.split(key_s, l)
            z = jax.vmap(lambda x, kx: kmeans_codebook(
                x, hd, spec, iters=iters, key=kx))(xs, keys)
            return z, scale

        zk, sk = one_stream(k_rows, kk)
        zv, sv = one_stream(v_rows, kv_)
        return cls(zk=zk, zv=zv, sk=sk, sv=sv)

    @classmethod
    def from_rows(cls, k_rows: jax.Array, v_rows: jax.Array) -> "KVCodebook":
        """Exact-cover codebook: one subspace (v = head_dim), centroids =
        the row set verbatim, unit scales.

        Every row in ``k_rows``/``v_rows`` then round-trips BIT-IDENTICAL
        through encode/decode (x/1.0 is x, and argmin lands on an exact
        copy of x), which is what makes greedy token-identity testable on
        a lossy path. Requires T*KVH <= 256 rows per layer."""
        l, t, kvh, hd = k_rows.shape
        n = t * kvh
        if n > 256:
            raise ValueError(f"exact-cover needs T*KVH <= 256, got {n}")

        def pack(rows):
            flat = rows.astype(jnp.float32).reshape(l, n, hd)
            return flat[:, None, :, :]                     # (L, 1, c=n, v=hd)
        # sk/sv must be DISTINCT buffers: the cache pytree they ride in is
        # donated by the serving jits, and donating one buffer twice is an
        # XLA error.
        return cls(zk=pack(k_rows), zv=pack(v_rows),
                   sk=jnp.ones((l, kvh), jnp.float32),
                   sv=jnp.ones((l, kvh), jnp.float32))


def codebook_from_tree(tree: Dict[str, jax.Array]) -> KVCodebook:
    """Rebuild a :class:`KVCodebook` from its cache-pytree form."""
    return KVCodebook(zk=tree["zk"], zv=tree["zv"],
                      sk=tree["sk"], sv=tree["sv"])
