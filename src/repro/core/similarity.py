"""Similarity metrics between input sub-vectors and centroids (paper §V-2).

The paper supports three metrics, trading model accuracy for hardware cost:

  * L2 (Euclidean)   — sum (v - z)^2          (1 mul + 1 add per element)
  * L1 (Manhattan)   — sum |v - z|            (adders + abs only)
  * Chebyshev        — max |v - z|            (abs + max tree only)

All functions take
  x : (..., v)        input sub-vectors
  z : (c, v)          centroids for one subspace
and return distances (..., c) — smaller = more similar.

Assignment (argmin) is non-differentiable; training uses a straight-through
estimator implemented in :func:`ste_quantize` — forward returns the selected
centroid, backward passes gradients to both the input (identity, STE) and the
centroids (via the soft selection path).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["l2", "l1", "chebyshev"]

#: Hardware cost of one element-wise similarity op (paper Eq. 1's alpha_sim):
#: L2 = mul+add, L1 = abs+add, Chebyshev = abs+max.
ALPHA_SIM = {"l2": 2.0, "l1": 1.0, "chebyshev": 1.0}


def pairwise_distance(x: jax.Array, z: jax.Array, metric: Metric) -> jax.Array:
    """Distances between x (..., v) and centroids z (c, v) -> (..., c)."""
    if metric == "l2":
        # ||x||^2 - 2 x.z + ||z||^2 : the MXU-friendly expansion (no (.,c,v)
        # intermediate). Matches the Pallas kernel's formulation.
        x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (..., 1)
        z2 = jnp.sum(z * z, axis=-1)                          # (c,)
        xz = jnp.einsum("...v,cv->...c", x, z)                # (..., c)
        return x2 - 2.0 * xz + z2
    diff = jnp.abs(x[..., None, :] - z)                       # (..., c, v)
    if metric == "l1":
        return jnp.sum(diff, axis=-1)
    if metric == "chebyshev":
        return jnp.max(diff, axis=-1)
    raise ValueError(f"unknown metric: {metric}")


def assign(x: jax.Array, z: jax.Array, metric: Metric) -> jax.Array:
    """Index of the nearest centroid. x (..., v), z (c, v) -> (...,) int32."""
    d = pairwise_distance(x, z, metric)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ste_quantize(x: jax.Array, z: jax.Array, metric: Metric) -> jax.Array:
    """Quantize sub-vectors to their nearest centroid with an STE backward.

    Forward:  x_hat = z[argmin_j d(x, z_j)]
    Backward: dL/dx  = dL/dx_hat                (straight-through, paper §V-2)
              dL/dz  = scatter of dL/dx_hat onto selected centroids (the
                       k-means-style gradient: each centroid receives the
                       cotangents of the sub-vectors assigned to it).

    x : (..., v), z : (c, v) -> (..., v)
    """
    idx = assign(x, z, metric)
    return jnp.take(z, idx, axis=0)


def _ste_fwd(x, z, metric):
    idx = assign(x, z, metric)
    return jnp.take(z, idx, axis=0), (idx, z.shape[0])


def _ste_bwd(metric, res, g):
    idx, c = res
    # dL/dx: straight-through.
    dx = g
    # dL/dz: sum cotangents per selected centroid (one-hot scatter-add).
    onehot = jax.nn.one_hot(idx, c, dtype=g.dtype)            # (..., c)
    dz = jnp.einsum("...c,...v->cv", onehot, g)
    return dx, dz


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


def pairwise_distance_subspaces(x: jax.Array, z: jax.Array,
                                metric: Metric) -> jax.Array:
    """x (..., nc, v), z (nc, c, v) -> distances (..., nc, c)."""
    if metric == "l2":
        x2 = jnp.sum(x * x, axis=-1)[..., None]
        z2 = jnp.sum(z * z, axis=-1)
        xz = jnp.einsum("...kv,kcv->...kc", x, z)
        return x2 - 2.0 * xz + z2
    diff = jnp.abs(x[..., None, :] - z)                       # (..., nc, c, v)
    return jnp.sum(diff, -1) if metric == "l1" else jnp.max(diff, -1)


def assign_subspaces(x: jax.Array, z: jax.Array, metric: Metric) -> jax.Array:
    """x (..., nc, v), z (nc, c, v) -> (..., nc) int32."""
    return jnp.argmin(pairwise_distance_subspaces(x, z, metric),
                      axis=-1).astype(jnp.int32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ste_quantize_subspaces(x: jax.Array, z: jax.Array,
                           metric: Metric) -> jax.Array:
    """Per-subspace STE quantisation: x (..., nc, v), z (nc, c, v)."""
    idx = assign_subspaces(x, z, metric)
    return _gather_centroids(z, idx)


def _gather_centroids(z: jax.Array, idx: jax.Array) -> jax.Array:
    # z (nc, c, v), idx (..., nc) -> (..., nc, v)
    return jnp.einsum("...kc,kcv->...kv",
                      jax.nn.one_hot(idx, z.shape[1], dtype=z.dtype), z)


def _stes_fwd(x, z, metric):
    idx = assign_subspaces(x, z, metric)
    return _gather_centroids(z, idx), (idx, z.shape[1])


def _stes_bwd(metric, res, g):
    idx, c = res
    dx = g                                                    # straight-through
    onehot = jax.nn.one_hot(idx, c, dtype=g.dtype)            # (..., nc, c)
    dz = jnp.einsum("...kc,...kv->kcv", onehot, g)
    return dx, dz


ste_quantize_subspaces.defvjp(_stes_fwd, _stes_bwd)


def soft_assignment(x: jax.Array, z: jax.Array, metric: Metric,
                    temperature: float = 1.0) -> jax.Array:
    """Differentiable soft assignment (softmax over -distance/T), (..., c).

    z may be a single codebook (c, v) or per-subspace codebooks (nc, c, v)
    with x (..., nc, v). Used by LUTBoost stage-2 warmup when a smooth
    relaxation helps centroid training stability (LUT-NN-style); the hard
    STE path is the default.
    """
    if z.ndim == 3:
        d = pairwise_distance_subspaces(x, z, metric)
    else:
        d = pairwise_distance(x, z, metric)
    return jax.nn.softmax(-d / jnp.maximum(temperature, 1e-6), axis=-1)
