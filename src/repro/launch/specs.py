"""Input ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation: params/optimizer/batch/cache are all
``jax.ShapeDtypeStruct`` trees derived with ``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lut import QuantConfig
from repro.core.lutboost import precompute_model
from repro.data.synthetic import make_batch_specs
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.train.trainer import TrainConfig, init_opt_state


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train", 4096, 256),
    "prefill_32k": ShapeCase("prefill", 32768, 32),
    "decode_32k": ShapeCase("decode", 32768, 128),
    "long_500k": ShapeCase("decode", 524288, 1),
}


def cell_is_runnable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """long_500k only runs for archs with sub-quadratic structure
    (SSM/hybrid or sliding-window); see DESIGN.md §Arch-applicability."""
    if shape_name == "long_500k" and cfg.pure_full_attention:
        return False, "skipped (pure full-attention arch at 500k context)"
    return True, ""


def batch_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_specs(model: Model, qc: QuantConfig):
    """ShapeDtypeStruct tree of model params (inference LUTs included when
    qc.mode == lut_infer)."""
    def build(key):
        p = model.init(key, qc)
        if qc.mode == "lut_infer":
            p = precompute_model(p, qc)
        return p
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def train_input_specs(model: Model, qc: QuantConfig, case: ShapeCase,
                      tc: Optional[TrainConfig] = None):
    """(params, opt_state, batch, step) ShapeDtypeStructs for train_step."""
    tc = tc or TrainConfig()
    p_specs = param_specs(model, qc)
    opt_specs = jax.eval_shape(lambda p: init_opt_state(p, tc), p_specs)
    batch = make_batch_specs(model.cfg, case.batch, case.seq,
                             dtype=batch_dtype(model.cfg))
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return p_specs, opt_specs, batch, step


def cache_specs(model: Model, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_seq))


def serve_input_specs(model: Model, qc: QuantConfig, case: ShapeCase):
    """Returns (params, inputs..., cache) ShapeDtypeStructs for
    prefill (kind=prefill) or a single decode step (kind=decode)."""
    cfg = model.cfg
    p_specs = param_specs(model, qc)
    cache = cache_specs(model, case.batch, case.seq)
    if case.kind == "prefill":
        batch = make_batch_specs(cfg, case.batch, case.seq,
                                 dtype=batch_dtype(cfg))
        batch.pop("labels", None)
        return p_specs, batch, cache
    # decode: one new token against a seq-long cache
    if cfg.family == "audio":
        tok = jax.ShapeDtypeStruct((case.batch, 1, cfg.d_model),
                                   batch_dtype(cfg))
    else:
        tok = jax.ShapeDtypeStruct((case.batch, 1), jnp.int32)
    return p_specs, tok, cache
