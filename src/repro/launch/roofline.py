"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

  t_compute    = HLO_FLOPs        / (chips × 197e12 FLOP/s bf16)
  t_memory     = HLO_bytes        / (chips × 819e9  B/s HBM)
  t_collective = collective_bytes / (chips × 50e9   B/s/link × links)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed out of the optimized HLO text: we sum the
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (operand sizes are resolved via
a first pass over instruction definitions).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# ---- TPU v5e hardware constants -------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # links per chip engaged on a 2D torus (approx)

@dataclasses.dataclass
class RooflineReport:
    """All cost figures are PER DEVICE (the SPMD-partitioned module is the
    per-device program); ``model_flops``/``model_bytes`` are the GLOBAL
    useful work (bytes = the irreducible HBM traffic: params + caches +
    optimizer state, read/written once)."""
    flops: float
    bytes_accessed: float
    coll_bytes: Dict[str, int]
    chips: int
    model_flops: float = 0.0
    model_bytes: float = 0.0

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(v for k, v in self.coll_bytes.items()
                         if k != "count"))

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.total_coll_bytes / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops (remat/redundancy waste metric)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def t_ideal(self) -> float:
        """Roofline-ideal step time: the workload's own compute/bandwidth
        floor (whichever is larger) on perfect hardware utilisation."""
        return max(self.model_flops / (self.chips * PEAK_FLOPS),
                   self.model_bytes / (self.chips * HBM_BW))

    @property
    def roofline_fraction(self) -> float:
        """ideal step time / achievable step time (bound by max term)."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_ideal / t_bound if t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.total_coll_bytes,
            "collectives": {k: v for k, v in self.coll_bytes.items()},
            "chips": self.chips, "model_flops": self.model_flops,
            "model_bytes": self.model_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "t_ideal_s": self.t_ideal,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            model_bytes: float = 0.0,
            hlo_text: Optional[str] = None) -> RooflineReport:
    """Roofline terms from the compiled module.

    Uses the while-aware HLO cost model (``repro.launch.hlo_cost``): the
    backend's ``cost_analysis()`` counts loop bodies once, which undercounts
    scan-over-layers models by ~num_layers (verified; see EXPERIMENTS.md
    §Dry-run methodology). All terms are PER-DEVICE (the module is the
    SPMD-partitioned program), so `chips` only enters the denominators as
    already-partitioned work.
    """
    from . import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    mc = hlo_cost.module_cost(text)
    coll = {k: int(v) for k, v in mc.coll.items()}
    coll["count"] = int(mc.coll_count)
    return RooflineReport(flops=mc.flops, bytes_accessed=mc.bytes,
                          coll_bytes=coll, chips=chips,
                          model_flops=model_flops, model_bytes=model_bytes)


def model_flops_for(cfg, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (forward) per token, using
    active params (MoE counts routed top-k + shared only)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = batch * seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * batch


def model_bytes_for(cfg, kind: str, batch: int, seq: int,
                    param_bytes: float, cache_bytes: float = 0.0) -> float:
    """Irreducible global HBM traffic per step.

    decode : stream all (LUT/dense) params once + read the KV/state cache
    prefill: params + write the cache once
    train  : params fwd+bwd reads + grad write + fp32 Adam m/v read+write
    """
    if kind == "decode":
        return param_bytes + cache_bytes
    if kind == "prefill":
        return param_bytes + cache_bytes
    n_params = param_bytes / 2.0          # bf16 params
    return 3.0 * param_bytes + 16.0 * n_params
