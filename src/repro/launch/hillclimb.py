import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb runner: re-lower the three chosen cells after each code
change and log (hypothesis, before, after) to results/perf/.

Cells (see EXPERIMENTS.md §Perf for selection rationale):
  * zamba2-1.2b  × long_500k   — worst baseline roofline fraction
  * gemma3-4b    × prefill_32k — most collective-bound
  * gemma3-27b   × decode_32k  — most representative of the paper's
                                 technique (large-model LUT-int8 decode)
"""
import argparse    # noqa: E402
import json        # noqa: E402
from typing import Optional   # noqa: E402

from repro.launch.dryrun import run_cell            # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

CELLS = [
    ("zamba2-1.2b", "long_500k"),
    ("gemma3-4b", "prefill_32k"),
    ("gemma3-27b", "decode_32k"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", required=True,
                    help="iteration tag, e.g. I1_bf16_kv")
    ap.add_argument("--cell", default="all",
                    help="'all' or arch:shape")
    ap.add_argument("--out", default="results/perf")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v

    cells = CELLS if args.cell == "all" else \
        [tuple(args.cell.split(":"))]
    mesh = make_production_mesh()
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        res = run_cell(arch, shape, mesh, "lut", cfg_overrides=overrides)
        path = os.path.join(args.out, f"{args.tag}__{arch}__{shape}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        rl = res.get("roofline", {})
        print(f"[hillclimb:{args.tag}] {arch}×{shape}: "
              f"frac={rl.get('roofline_fraction', 0):.4f} "
              f"t_mem={rl.get('t_memory_s', 0):.3e} "
              f"t_comp={rl.get('t_compute_s', 0):.3e} "
              f"t_coll={rl.get('t_collective_s', 0):.3e}")


if __name__ == "__main__":
    main()
