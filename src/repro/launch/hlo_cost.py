"""While-aware HLO cost model.

``compiled.cost_analysis()`` counts loop bodies ONCE (verified on this
backend), which undercounts scan-over-layers models by ~num_layers. This
module parses the optimized HLO text and computes:

  * flops            — dot FLOPs, with while bodies × trip count, fusion
                       subcomputations traversed, conditionals = max(branch)
  * bytes            — HBM-traffic proxy: per-instruction result+operand
                       bytes at fusion granularity (inside-fusion values stay
                       in registers/VMEM), with loop multiplication
  * collectives      — operand bytes per collective kind, × trip counts

Trip counts are extracted from each while's condition region (the loop bound
appears as an integer constant compared against the induction variable).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\]"
    r"(?:\{[\d,]*\})?))\s+([a-z0-9\-]+)(?:\(|\.)")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

#: ops excluded from the bytes (HBM traffic) proxy
_BYTES_SKIP = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "while", "conditional",
               "call", "copy-start", "copy-done"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str

    @property
    def is_root(self) -> bool:
        return self.line.lstrip().startswith("ROOT")

    @property
    def operands(self) -> List[str]:
        after = self.line.split("(", 1)
        if len(after) < 2:
            return []
        return _OPERAND_RE.findall(after[1].split(")")[0])


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    sizes: Dict[str, str]      # instr name -> type str


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None:
            if ("->" in line and line.rstrip().endswith("{")
                    and not stripped.startswith("//")):
                m = _COMP_START_RE.match(stripped)
                if m:
                    current = Computation(m.group(1), [], {})
            continue
        if stripped.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, op = m.groups()
            current.instrs.append(Instr(name, type_str, op, stripped))
            current.sizes[name] = type_str
    if current is not None:
        comps[current.name] = current
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Loop bound = the largest integer constant in the condition region
    (covering `i < N` and fused comparison patterns)."""
    best = 1
    seen = set()

    def visit(name):
        if name in seen or name not in comps:
            return
        seen.add(name)
        for ins in comps[name].instrs:
            for c in _CONST_RE.findall(ins.line):
                best_local = int(c)
                nonlocal best
                if best_local > best:
                    best = best_local
            cm = _CALLS_RE.search(ins.line)
            if cm:
                visit(cm.group(1))

    visit(cond_name)
    return max(best, 1)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 × prod(result dims) × prod(contracting dim sizes of lhs)."""
    result = _shape_dims(ins.type_str)
    if not result:
        return 0.0
    out_elems = 1
    for d in result[0][1]:
        out_elems *= d
    cm = _CONTRACT_RE.search(ins.line)
    operands = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
    lhs = next((o for o in operands if o in comp.sizes), None)
    if cm is None or lhs is None:
        return 2.0 * out_elems            # fallback: treat as elementwise-ish
    lhs_dims = _shape_dims(comp.sizes[lhs])
    if not lhs_dims:
        return 2.0 * out_elems
    contract = 1
    for ci in cm.group(1).split(","):
        if ci:
            idx = int(ci)
            if idx < len(lhs_dims[0][1]):
                contract *= lhs_dims[0][1][idx]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    coll_count: float = 0.0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k,
                       {kk: v * k for kk, v in self.coll.items()},
                       self.coll_count * k)

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] += v
        self.coll_count += other.coll_count

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll.values())


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    after = ins.line.split("(", 1)
    if len(after) < 2:
        return 0
    total = 0
    for ref in _OPERAND_RE.findall(after[1].split(")")[0]):
        if ref in comp.sizes:
            total += _type_bytes(comp.sizes[ref])
    return total


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_bytes(ins: Instr, comp: Computation,
                  comps: Dict[str, Computation], called: str) -> int:
    """HBM bytes for a fusion call, HloCostAnalysis-style:

    * a fused dynamic-slice reads only the slice, not the whole operand
      (scan-over-layers parameter slicing);
    * a fusion rooted in dynamic-update-slice writes only the update
      (in-place KV-cache writes), and its sliced target is not re-read.
    """
    region = comps.get(called)
    if region is None:
        return _type_bytes(ins.type_str) + _operand_bytes(ins, comp)

    # map parameter index -> param name; find slice-consumed params
    param_names: Dict[int, str] = {}
    ds_result: Dict[str, int] = {}     # param name -> slice bytes
    dus_target: set = set()            # params that are DUS in-place targets
    for r in region.instrs:
        if r.op == "parameter":
            m = _PARAM_IDX_RE.search(r.line)
            if m:
                param_names[int(m.group(1))] = r.name
    for r in region.instrs:
        ops_ = r.operands
        if r.op == "dynamic-slice" and ops_:
            ds_result[ops_[0]] = _type_bytes(r.type_str)
        if r.op == "dynamic-update-slice" and ops_:
            dus_target.add(ops_[0])

    # result bytes: DUS-rooted fusions write only the update slice
    root = next((r for r in region.instrs if r.is_root), None)
    seen = 0
    while root is not None and root.op in ("bitcast", "copy") \
            and root.operands and seen < 4:
        nxt = next((r for r in region.instrs
                    if r.name == root.operands[0]), None)
        root, seen = nxt, seen + 1
    if root is not None and root.op == "dynamic-update-slice" \
            and len(root.operands) >= 2:
        upd = root.operands[1]
        result_bytes = _type_bytes(region.sizes.get(upd, ""))
    else:
        result_bytes = _type_bytes(ins.type_str)

    total = result_bytes
    for i, ref in enumerate(ins.operands):
        if ref not in comp.sizes:
            continue
        pname = param_names.get(i)
        if pname in dus_target:
            continue                        # in-place target: not re-read
        if pname in ds_result:
            total += ds_result[pname]       # only the slice is read
        else:
            total += _type_bytes(comp.sizes[ref])
    return total


def _region_cost(comps: Dict[str, Computation], name: str,
                 cache: Dict[str, HloCost], flops_only: bool = False
                 ) -> HloCost:
    key = name + ("#f" if flops_only else "")
    if key in cache:
        return cache[key]
    cost = HloCost()
    cache[key] = cost                      # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return cost
    for ins in comp.instrs:
        if ins.op == "while":
            body = _BODY_RE.search(ins.line)
            cond = _COND_RE.search(ins.line)
            if body:
                trips = _trip_count(comps, cond.group(1)) if cond else 1
                cost.add(_region_cost(comps, body.group(1), cache,
                                      flops_only).scaled(trips))
            continue
        if ins.op == "conditional":
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                branches = [_region_cost(comps, b.strip().lstrip("%"),
                                         cache, flops_only)
                            for b in bm.group(1).split(",")]
                if branches:
                    best = max(branches, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
            if not flops_only:
                cost.bytes += _type_bytes(ins.type_str)
            continue
        if ins.op in ("fusion", "call"):
            cm = _CALLS_RE.search(ins.line)
            if cm:
                # flops live inside; bytes counted at the fusion boundary
                inner = _region_cost(comps, cm.group(1), cache,
                                     flops_only=True)
                cost.flops += inner.flops
            if not flops_only:
                if cm:
                    cost.bytes += _fusion_bytes(ins, comp, comps,
                                                cm.group(1))
                else:
                    cost.bytes += _type_bytes(ins.type_str) \
                        + _operand_bytes(ins, comp)
            continue
        kind = next((k for k in COLLECTIVE_OPS if ins.op.startswith(k)), None)
        if kind is not None:
            ob = _operand_bytes(ins, comp) or _type_bytes(ins.type_str)
            cost.coll[kind] += ob
            cost.coll_count += 1
            if not flops_only:
                cost.bytes += _type_bytes(ins.type_str) + \
                    _operand_bytes(ins, comp)
            continue
        if ins.op == "dot":
            cost.flops += _dot_flops(ins, comp)
        if not flops_only and ins.op not in _BYTES_SKIP:
            if ins.op == "dynamic-slice":
                cost.bytes += 2 * _type_bytes(ins.type_str)
            elif ins.op == "dynamic-update-slice" and len(ins.operands) >= 2:
                upd = ins.operands[1]
                cost.bytes += 2 * _type_bytes(comp.sizes.get(upd, ""))
            else:
                cost.bytes += _type_bytes(ins.type_str) + \
                    _operand_bytes(ins, comp)
    cache[key] = cost
    return cost


def module_cost(hlo_text: str, entry: Optional[str] = None) -> HloCost:
    comps = parse_module(hlo_text)
    if not comps:
        return HloCost()
    if entry is None:
        # the ENTRY computation is the one named like main / the last one
        entry = next((n for n in comps if n.startswith("main")), None) \
            or list(comps)[-1]
    return _region_cost(comps, entry, {})


def top_cost_lines(hlo_text: str, k: int = 20, by: str = "bytes"):
    """Profiling aid: the k most expensive instructions, with loop
    multipliers applied. Returns [(cost, trips, op, line-prefix)]."""
    comps = parse_module(hlo_text)
    if not comps:
        return []
    entry = next((n for n in comps if n.startswith("main")), None) \
        or list(comps)[-1]
    rows = []

    def visit(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                if body:
                    trips = _trip_count(comps, cond.group(1)) if cond else 1
                    visit(body.group(1), mult * trips)
                continue
            if ins.op == "conditional":
                bm = _BRANCHES_RE.search(ins.line)
                if bm:
                    for b in bm.group(1).split(","):
                        visit(b.strip().lstrip("%"), mult)
                continue
            if ins.op in ("fusion", "call"):
                cm = _CALLS_RE.search(ins.line)
                if by == "flops" and cm:
                    inner = _region_cost(comps, cm.group(1), {},
                                         flops_only=True)
                    if inner.flops:
                        rows.append((inner.flops * mult, mult, ins.op,
                                     ins.line[:140]))
                elif by == "bytes" and cm:
                    b = _fusion_bytes(ins, comp, comps, cm.group(1))
                    if b:
                        rows.append((b * mult, mult, ins.op, ins.line[:140]))
                continue
            if by == "flops":
                if ins.op == "dot":
                    rows.append((_dot_flops(ins, comp) * mult, mult, ins.op,
                                 ins.line[:140]))
            elif ins.op not in _BYTES_SKIP:
                if ins.op == "dynamic-slice":
                    b = 2 * _type_bytes(ins.type_str)
                elif (ins.op == "dynamic-update-slice"
                      and len(ins.operands) >= 2):
                    b = 2 * _type_bytes(comp.sizes.get(ins.operands[1], ""))
                else:
                    b = _type_bytes(ins.type_str) + _operand_bytes(ins, comp)
                if b:
                    rows.append((b * mult, mult, ins.op, ins.line[:140]))

    visit(entry, 1.0)
    rows.sort(key=lambda r: -r[0])
    return rows[:k]
