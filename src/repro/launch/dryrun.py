import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below runs with 512 placeholder CPU devices ---------------
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax                                   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_NAMES, get_config          # noqa: E402
from repro.core.lut import QuantConfig                     # noqa: E402
from repro.launch import roofline as rl                    # noqa: E402
from repro.launch.mesh import (data_axes,                  # noqa: E402
                               make_production_mesh, mesh_context)
from repro.launch.specs import (SHAPES, cell_is_runnable,   # noqa: E402
                                serve_input_specs, train_input_specs)
from repro.models.model import Model                        # noqa: E402
from repro.parallel.sharding import (batch_pspecs, cache_pspecs,  # noqa: E402
                                     param_pspecs)
from repro.train.trainer import TrainConfig, make_train_step  # noqa: E402


def _shard(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def _opt_pspecs(p_pspecs, tc: TrainConfig):
    out = {"adam": {"m": p_pspecs, "v": p_pspecs, "count": P()}}
    if tc.compress_grads:
        out["ef"] = p_pspecs
    return out


def quant_config(mode: str, kind: str) -> QuantConfig:
    """The paper's technique operating point per step kind."""
    if mode == "dense":
        return QuantConfig(mode="dense")
    lut_mode = "lut_train" if kind == "train" else "lut_infer"
    return QuantConfig(mode=lut_mode, v=8, c=16, metric="l2",
                       lut_dtype="int8" if kind != "train" else "float32",
                       impl="ref")


def run_cell(arch: str, shape_name: str, mesh, quant: str = "lut",
             tc: Optional[TrainConfig] = None, verbose: bool = True,
             cfg_overrides: Optional[dict] = None):
    """Lower + compile one (arch × shape) cell on `mesh`. Returns a dict."""
    case = SHAPES[shape_name]
    cfg = get_config(arch)
    runnable, why = cell_is_runnable(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "quant": quant,
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "chips": mesh.devices.size}
    if not runnable:
        result.update({"status": "skipped", "reason": why})
        return result

    overrides = dict(cfg_overrides or {})
    if case.kind == "train":
        overrides.setdefault("remat", True)
    cfg = cfg.replace(**overrides)
    model = Model(cfg)
    qc = quant_config(quant, case.kind)
    da = data_axes(mesh)
    dp = 1
    for a in da:
        dp *= mesh.shape[a]

    t0 = time.perf_counter()
    if case.kind == "train":
        tc = tc or TrainConfig()
        p_s, o_s, b_s, step_s = train_input_specs(model, qc, case, tc)
        p_spec = param_pspecs(p_s, cfg, model_axis_size=mesh.shape["model"])
        in_specs = (_shard(mesh, p_spec),
                    _shard(mesh, _opt_pspecs(p_spec, tc)),
                    _shard(mesh, batch_pspecs(cfg, da)),
                    NamedSharding(mesh, P()))
        metrics_spec = {"loss": P(), "ce": P(), "recon": P(),
                        "moe_aux": P(), "grad_norm": P(), "lr": P()}
        out_specs = (_shard(mesh, p_spec),
                     _shard(mesh, _opt_pspecs(p_spec, tc)),
                     _shard(mesh, metrics_spec))
        step_fn = make_train_step(model, qc, tc, stage=3)
        jitted = jax.jit(step_fn, in_shardings=in_specs,
                         out_shardings=out_specs)
        with mesh_context(mesh):
            lowered = jitted.lower(p_s, o_s, b_s, step_s)
    else:
        specs = serve_input_specs(model, qc, case)
        p_s, in2_s, cache_s = specs
        p_spec = param_pspecs(p_s, cfg, model_axis_size=mesh.shape["model"])
        cache_spec = cache_pspecs(cfg, case.batch, mesh, da)
        batch_first = case.batch % dp == 0 and case.batch >= dp
        dlead = (da if len(da) > 1 else da[0]) if batch_first else None
        if case.kind == "prefill":
            in2_spec = batch_pspecs(cfg, da)
            in2_spec.pop("labels", None)
            fn = lambda p, b, c: model.prefill(p, b, c, qc)  # noqa: E731
        else:
            if cfg.family == "audio":
                in2_spec = P(dlead, None, None)
            else:
                in2_spec = P(dlead, None)
            fn = lambda p, t, c: model.decode(p, t, c, qc)   # noqa: E731
        vshard = "model" if cfg.vocab_size % mesh.shape["model"] == 0 \
            else None
        logits_spec = (P(dlead, None, vshard) if cfg.family == "audio"
                       else P(dlead, vshard))
        in_specs = (_shard(mesh, p_spec), _shard(mesh, in2_spec),
                    _shard(mesh, cache_spec))
        out_specs = (_shard(mesh, logits_spec), _shard(mesh, cache_spec))
        jitted = jax.jit(fn, in_shardings=in_specs, out_shardings=out_specs,
                         donate_argnums=(2,))
        with mesh_context(mesh):
            lowered = jitted.lower(p_s, in2_s, cache_s)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
        if not mem and ma is not None:
            mem["repr"] = str(ma)
    except Exception as e:                      # pragma: no cover
        mem["error"] = repr(e)

    def _tree_bytes(tree):
        return float(sum(x.size * x.dtype.itemsize
                         for x in jax.tree_util.tree_leaves(tree)))

    param_bytes = _tree_bytes(p_s)
    cache_bytes = _tree_bytes(cache_s) if case.kind != "train" else 0.0
    mf = rl.model_flops_for(cfg, case.kind, case.batch, case.seq)
    mb = rl.model_bytes_for(cfg, case.kind, case.batch, case.seq,
                            param_bytes, cache_bytes)
    report = rl.analyze(compiled, chips=mesh.devices.size, model_flops=mf,
                        model_bytes=mb)
    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": report.to_dict(),
    })
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']} ({quant}) "
              f"OK — lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"bottleneck={report.bottleneck} "
              f"frac={report.roofline_fraction:.3f}")
        print(f"  memory: {mem}")
        print(f"  flops={report.flops:.3e} bytes={report.bytes_accessed:.3e} "
              f"coll={report.total_coll_bytes:.3e}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="LUT-DLA multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="lut", choices=["lut", "dense"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have results")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_tag = "multi" if multi else "single"
        for arch in archs:
            for shape in shapes:
                tag = f"{mesh_tag}__{args.quant}__{arch}__{shape}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] {tag}: cached, skipping")
                    continue
                try:
                    res = run_cell(arch, shape, mesh, args.quant)
                except Exception as e:
                    res = {"arch": arch, "shape": shape, "quant": args.quant,
                           "mesh": mesh_tag, "status": "error",
                           "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures.append(tag)
                    print(f"[dryrun] {tag}: FAILED — {e!r}")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
