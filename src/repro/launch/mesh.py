"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The single-pod mesh is 16×16 = 256 chips (v5e pod),
axes (data, model); the multi-pod mesh adds a leading pod axis:
2×16×16 = 512 chips, axes (pod, data, model). ``pod`` is an outer
data-parallel axis — gradients reduce hierarchically (fast ICI inside a pod
first, the slower inter-pod hop once).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax < 0.6 has no jax.sharding.AxisType (meshes are implicitly Auto);
    # newer versions want it spelled out.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions.

    jax >= 0.6 spells it ``jax.set_mesh``; on 0.4.x the ``Mesh`` object is
    itself a context manager with the same effect for explicitly-sharded
    ``jit.lower`` calls.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires XLA host-device override)."""
    return _make_mesh(shape, axes)


def replica_submeshes(mesh):
    """Carve a ``(..., model)`` mesh into one TP submesh per data index.

    Every non-``model`` axis is flattened into replica groups: a
    ``(2, 16, 16)`` (pod, data, model) mesh yields 32 submeshes of shape
    ``(1, 16)`` with axes ``("data", "model")``. This is the data-parallel
    serving decomposition — each replica group runs its own
    tensor-parallel engine (``repro.serve.router.ReplicaRouter``), so no
    collective ever crosses replica boundaries.
    """
    import numpy as np
    if "model" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
    if mesh.axis_names[-1] != "model":
        raise ValueError("the 'model' axis must be trailing (fastest-"
                         f"varying), got {mesh.axis_names}")
    msize = mesh.shape["model"]
    groups = np.asarray(mesh.devices).reshape(-1, msize)
    return [jax.sharding.Mesh(row.reshape(1, msize), ("data", "model"))
            for row in groups]
