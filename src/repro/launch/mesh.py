"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The single-pod mesh is 16×16 = 256 chips (v5e pod),
axes (data, model); the multi-pod mesh adds a leading pod axis:
2×16×16 = 512 chips, axes (pod, data, model). ``pod`` is an outer
data-parallel axis — gradients reduce hierarchically (fast ICI inside a pod
first, the slower inter-pod hop once).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires XLA host-device override)."""
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)
