"""One schema for every ``BENCH_*.json`` perf snapshot.

``serve_bench --snapshot``, ``kernels_micro --snapshot`` and
``kv_accuracy.py``'s merge path all used to hand-roll their JSON
writers; :mod:`repro.obs.perfgate` needs a single schema to trust, so
the row format and the write/merge/load logic live here.

Row schema (all fields present after :func:`normalize_row`):

===========  ============================================================
field        meaning
===========  ============================================================
``name``     dotted metric name (``serve.chaos.goodput_pct``)
``value``    float
``unit``     ``"us"`` (CPU timer), ``"%"``, ``"B"``, ``"x"``, ``""`` ...
``direction``  ``"down"`` = smaller is better, ``"up"`` = bigger is
``derived``  free-text provenance shown in reports
``tol``      optional per-row relative tolerance override for the gate
===========  ============================================================

Legacy snapshots (PR 6–9) carried only ``name``/``value``/``derived``;
:func:`normalize_row` back-fills ``unit``/``direction`` from name
heuristics so the gate can still read history.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

SCHEMA_VERSION = 2

#: substrings marking a bigger-is-better metric in legacy (schema-1) rows
_UP_HINTS = ("goodput", "reduction", "agreement", "identity", "resident",
             "rate", "tok_s", "speedup")


def make_row(name: str, value: float, derived: str = "", unit: str = "us",
             direction: str = "down", tol: Optional[float] = None) -> dict:
    if direction not in ("up", "down"):
        raise ValueError(f"direction must be up/down, got {direction!r}")
    row = {"name": name, "value": float(value), "unit": unit,
           "direction": direction, "derived": derived}
    if tol is not None:
        row["tol"] = float(tol)
    return row


def infer_direction(name: str) -> str:
    low = name.lower()
    return "up" if any(h in low for h in _UP_HINTS) else "down"


def infer_unit(name: str) -> str:
    low = name.lower()
    # every legacy kernels_micro row ("micro/...") is a wall-time in us
    if "us_per" in low or low.endswith("_us") or low.startswith("micro/"):
        return "us"
    if low.endswith("_pct") or "pct" in low:
        return "%"
    if "bytes" in low:
        return "B"
    return ""


def normalize_row(row: dict) -> dict:
    """Fill schema-2 fields on a possibly-legacy row (non-destructive)."""
    out = dict(row)
    out.setdefault("derived", "")
    out.setdefault("unit", infer_unit(row["name"]))
    out.setdefault("direction", infer_direction(row["name"]))
    out["value"] = float(out["value"])
    return out


def _host_fingerprint() -> str:
    """Coarse host identity: timer rows are only *gated* between
    snapshots from the same fingerprint (absolute CPU microseconds are
    not comparable across machines — see docs/observability.md)."""
    import platform
    return f"{platform.machine()}-{os.cpu_count()}c"


def _meta(**meta) -> dict:
    import jax
    base = {"date": time.strftime("%Y-%m-%d"),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "host": _host_fingerprint(),
            "schema": SCHEMA_VERSION}
    base.update(meta)
    return base


def write_snapshot(path: str, rows: List[dict], **meta) -> dict:
    """Write a fresh snapshot document (clobbers ``path``)."""
    doc = _meta(**meta)
    doc["rows"] = [normalize_row(r) for r in rows]
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[snapshot] wrote {len(rows)} row(s) -> {path}")
    return doc


def merge_snapshot(path: str, rows: List[dict], prefix: str,
                   **meta) -> dict:
    """Fold ``rows`` into an existing snapshot (or start one), replacing
    stale rows under ``prefix`` and preserving everything else."""
    doc = {}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    fresh = [normalize_row(r) for r in rows]
    kept = [normalize_row(r) for r in doc.get("rows", [])
            if not r["name"].startswith(prefix)]
    for k, v in _meta(**meta).items():
        doc.setdefault(k, v)
    doc.update(meta)
    doc["rows"] = kept + fresh
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[snapshot] merged {len(fresh)} row(s) under {prefix!r} -> "
          f"{path} ({len(doc['rows'])} total)")
    return doc


def load_snapshot(path: str) -> dict:
    """Load a snapshot with every row normalized to schema 2."""
    with open(path) as f:
        doc = json.load(f)
    return loads_snapshot(doc)


def loads_snapshot(doc: dict) -> dict:
    doc = dict(doc)
    doc["rows"] = [normalize_row(r) for r in doc.get("rows", [])]
    return doc
