"""Process-local metrics: counters, gauges, streaming histograms.

The serving stack records everything it knows about itself here —
request latency families (TTFT / TPOT / end-to-end), per-step phase
timings, admission/shed/deadline tallies, degradation-mode residency,
speculative acceptance, device-read counts — through one
:class:`Registry` per engine (replicas each get their own so per-engine
counts stay attributable; see :class:`repro.obs.Obs`).

Design constraints (ISSUE 10):

* **No unbounded sample lists.** :class:`Histogram` is a fixed array of
  geometrically-spaced buckets; an observation is two array writes and
  four scalar updates. Quantiles are estimated from bucket midpoints
  with a relative error bounded by ``growth - 1`` (12.5% at the default
  ``growth=1.25``) — exact ``count``/``total``/``min``/``max`` ride
  along so means and extremes are not estimates.
* **Hot-path safe.** Recording is plain host arithmetic — no device
  values, no syncs, no allocation beyond the first get-or-create. The
  registry is always live (engine counters double as test-visible
  state); only *timing* is compiled out when obs is disabled.
* **Snapshot round-trip.** :meth:`Registry.snapshot` emits a JSON-able
  dict; :meth:`Registry.from_snapshot` reconstructs an equivalent
  registry (bucket-exact for histograms). :meth:`Registry.prometheus`
  renders the conventional text exposition format.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional


def safe_ratio(num: float, den: float, default: float = 0.0) -> float:
    """``num / den`` with a well-defined answer on an empty denominator.

    Every rate in the stack (prefix hit rate before any admission,
    acceptance rate before any verify round) funnels through this so
    "no data yet" is ``default``, never ``ZeroDivisionError``.
    """
    return num / den if den else default


class Counter:
    """Monotonic counter. ``inc`` is as cheap as the ``+=`` it replaced."""

    __slots__ = ("name", "unit", "desc", "value")

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        self.name, self.unit, self.desc = name, unit, desc
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (pool bytes, pressure, mode)."""

    __slots__ = ("name", "unit", "desc", "value")

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        self.name, self.unit, self.desc = name, unit, desc
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Streaming histogram over fixed geometric buckets.

    Buckets cover ``[lo, hi)`` with ratio ``growth`` between edges;
    observations below ``lo`` (incl. zero/negative) land in a dedicated
    underflow bucket, above ``hi`` in an overflow bucket. Quantiles
    interpolate to the geometric midpoint of the hit bucket, so the
    relative estimation error is at most ``sqrt(growth) - 1`` for any
    in-range value (``tests/test_obs.py`` asserts the looser
    ``growth - 1`` bound end to end).
    """

    __slots__ = ("name", "unit", "desc", "lo", "growth", "_log_g",
                 "buckets", "count", "total", "min", "max")

    def __init__(self, name: str, unit: str = "s", desc: str = "",
                 lo: float = 1e-7, hi: float = 1e4, growth: float = 1.25):
        if not (lo > 0.0 and hi > lo and growth > 1.0):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.name, self.unit, self.desc = name, unit, desc
        self.lo, self.growth = lo, growth
        self._log_g = math.log(growth)
        n = int(math.ceil(math.log(hi / lo) / self._log_g))
        # [0] underflow, [1..n] geometric, [n+1] overflow — fixed forever
        self.buckets: List[int] = [0] * (n + 2)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    def observe(self, v: float) -> None:
        if v < self.lo:
            idx = 0
        else:
            idx = 1 + int(math.log(v / self.lo) / self._log_g)
            if idx > len(self.buckets) - 2:
                idx = len(self.buckets) - 1
        self.buckets[idx] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return safe_ratio(self.total, self.count)

    def _edge(self, i: int) -> float:
        """Lower edge of geometric bucket ``i`` (1-based)."""
        return self.lo * self.growth ** (i - 1)

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-quantile (``q`` in [0, 1])."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = 0
        for i, n in enumerate(self.buckets):
            if n == 0:
                continue
            seen += n
            if seen > rank:
                if i == 0:                       # underflow: exact floor
                    return self.min
                if i == len(self.buckets) - 1:   # overflow: exact ceiling
                    return self.max
                mid = self._edge(i) * math.sqrt(self.growth)
                # clamp to the observed extremes so single-bucket
                # histograms report sane values
                return min(max(mid, self.min), self.max)
        return self.max


class Registry:
    """Get-or-create home for every metric family, keyed by dotted name."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- get-or-create ------------------------------------------------------
    def counter(self, name: str, unit: str = "", desc: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, unit, desc)
        return c

    def gauge(self, name: str, unit: str = "", desc: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, unit, desc)
        return g

    def histogram(self, name: str, unit: str = "s", desc: str = "",
                  lo: float = 1e-7, hi: float = 1e4,
                  growth: float = 1.25) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name, unit, desc, lo, hi,
                                              growth)
        return h

    def ratio(self, num_name: str, den_name: str,
              default: float = 0.0) -> float:
        """Guarded ratio of two counters by name (0 if either absent)."""
        num = self._counters.get(num_name)
        den = self._counters.get(den_name)
        return safe_ratio(num.value if num else 0,
                          den.value if den else 0, default)

    # -- snapshot round-trip ------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able dump of every metric, bucket-exact for histograms."""
        return {
            "counters": {n: {"value": c.value, "unit": c.unit}
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: {"value": g.value, "unit": g.unit}
                       for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: {"unit": h.unit, "lo": h.lo, "growth": h.growth,
                    "count": h.count, "total": h.total,
                    "min": None if h.count == 0 else h.min,
                    "max": None if h.count == 0 else h.max,
                    "buckets": list(h.buckets)}
                for n, h in sorted(self._hists.items())},
        }

    @classmethod
    def from_snapshot(cls, doc: dict) -> "Registry":
        r = cls()
        for n, c in doc.get("counters", {}).items():
            r.counter(n, unit=c.get("unit", "")).value = c["value"]
        for n, g in doc.get("gauges", {}).items():
            r.gauge(n, unit=g.get("unit", "")).set(g["value"])
        for n, hd in doc.get("histograms", {}).items():
            nb = len(hd["buckets"])
            # reconstruct hi from (lo, growth, bucket count)
            hi = hd["lo"] * hd["growth"] ** (nb - 2)
            h = r.histogram(n, unit=hd.get("unit", "s"), lo=hd["lo"],
                            hi=hi * 0.999999, growth=hd["growth"])
            if len(h.buckets) != nb:          # defensive: force exact shape
                h.buckets = [0] * nb
            h.buckets[:] = hd["buckets"]
            h.count = hd["count"]
            h.total = hd["total"]
            h.min = math.inf if hd["min"] is None else hd["min"]
            h.max = -math.inf if hd["max"] is None else hd["max"]
        return r

    # -- prometheus text exposition -----------------------------------------
    @staticmethod
    def _prom_name(name: str) -> str:
        return "".join(ch if (ch.isalnum() or ch == "_") else "_"
                       for ch in name)

    def prometheus(self) -> str:
        """Conventional ``# TYPE``-annotated text dump (counters, gauges,
        and summary-style quantile lines for histograms)."""
        out: List[str] = []
        for n, c in sorted(self._counters.items()):
            pn = self._prom_name(n)
            if c.desc:
                out.append(f"# HELP {pn} {c.desc}")
            out.append(f"# TYPE {pn} counter")
            out.append(f"{pn} {c.value}")
        for n, g in sorted(self._gauges.items()):
            pn = self._prom_name(n)
            if g.desc:
                out.append(f"# HELP {pn} {g.desc}")
            out.append(f"# TYPE {pn} gauge")
            out.append(f"{pn} {g.value}")
        for n, h in sorted(self._hists.items()):
            pn = self._prom_name(n)
            if h.desc:
                out.append(f"# HELP {pn} {h.desc}")
            out.append(f"# TYPE {pn} summary")
            for q in (0.5, 0.9, 0.99):
                out.append(f'{pn}{{quantile="{q}"}} {h.percentile(q)}')
            out.append(f"{pn}_sum {h.total}")
            out.append(f"{pn}_count {h.count}")
        return "\n".join(out) + "\n"

    # -- convenience views --------------------------------------------------
    def counters(self) -> Dict[str, int]:
        return {n: c.value for n, c in self._counters.items()}

    def get_histogram(self, name: str) -> Optional[Histogram]:
        return self._hists.get(name)
