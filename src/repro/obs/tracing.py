"""Request-lifecycle and step-phase tracing with Perfetto export.

A :class:`Tracer` records compact event tuples into a bounded ring
buffer (a ``deque(maxlen=...)``: old events fall off, recording never
blocks or grows) and exports Chrome/Perfetto ``trace_event`` JSON that
``ui.perfetto.dev`` or ``chrome://tracing`` loads directly:

* ``X`` complete events — engine step phases (admit / prefill_chunk /
  decode / draft / verify / sample / device_read), one track per
  replica (``pid`` = replica index).
* ``b``/``e``/``n`` async events — request lifecycles, all on the
  dedicated :data:`REQUEST_PID` track, matched by the request's
  scheduler sequence number so a request that migrates replicas after a
  crash still renders as one span.
* ``i`` instant events — annotations: degradation-ladder transitions,
  preemptions, CoW forks, replica health flips, injected faults.
* ``C`` counter events — pool pressure / occupancy time-series.

Timestamps are host ``perf_counter`` microseconds relative to the
tracer's construction — taken only at points the engine already runs
host code, never forcing a device sync. A disabled tracer's recording
methods return immediately; :meth:`Tracer.span` hands back a shared
no-op context manager so the hot path allocates nothing.

Optional deep-profiler hooks: :func:`jax_annotation` wraps a block in
``jax.profiler.TraceAnnotation`` when available so phase names show up
inside an XLA profile too (no-op if the profiler is absent).
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, List, Optional

#: pid of the synthetic "requests" process in exported traces — request
#: lifecycle spans live here (not on a replica track) so cross-replica
#: migration after a crash cannot orphan a ``b`` without its ``e``.
REQUEST_PID = 999


class _NullCtx:
    """Shared do-nothing context manager (returned when tracing is off)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_CTX = _NullCtx()


class _Span:
    """Times one block and appends a single ``X`` event on exit."""

    __slots__ = ("tracer", "name", "cat", "pid", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, pid: int,
                 tid: int, args: Optional[dict]):
        self.tracer, self.name, self.cat = tracer, name, cat
        self.pid, self.tid, self.args = pid, tid, args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self.tracer
        tr._events.append(
            ("X", self.name, self.cat, (self._t0 - tr._t0) * 1e6,
             (t1 - self._t0) * 1e6, self.pid, self.tid, self.args))
        return False


class Tracer:
    """Bounded ring-buffer recorder + ``trace_event`` JSON exporter.

    One tracer may be shared by many engines (each replica stamps its
    own ``pid``); recording is append-only and single-threaded like the
    engines themselves.
    """

    def __init__(self, enabled: bool = False, capacity: int = 65536):
        self.enabled = enabled
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self._t0 = time.perf_counter()
        self._names: Dict[int, str] = {}   # pid -> process label

    # -- recording ----------------------------------------------------------
    def _ts(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def span(self, name: str, cat: str = "phase", pid: int = 0,
             tid: int = 0, args: Optional[dict] = None):
        """Context manager producing one complete (``X``) event."""
        if not self.enabled:
            return NULL_CTX
        return _Span(self, name, cat, pid, tid, args)

    def instant(self, name: str, cat: str = "annot", pid: int = 0,
                tid: int = 0, args: Optional[dict] = None) -> None:
        if self.enabled:
            self._events.append(("i", name, cat, self._ts(), pid, tid, args))

    def counter(self, name: str, value: float, pid: int = 0) -> None:
        if self.enabled:
            self._events.append(("C", name, self._ts(), pid, value))

    def request_begin(self, rid: int, name: str,
                      args: Optional[dict] = None) -> None:
        if self.enabled:
            self._events.append(("b", rid, name, self._ts(), args))

    def request_instant(self, rid: int, name: str, note: str,
                        args: Optional[dict] = None) -> None:
        if self.enabled:
            self._events.append(("n", rid, name, self._ts(),
                                 dict(args or {}, note=note)))

    def request_end(self, rid: int, name: str,
                    args: Optional[dict] = None) -> None:
        if self.enabled:
            self._events.append(("e", rid, name, self._ts(), args))

    def name_process(self, pid: int, label: str) -> None:
        """Label a pid's track in the exported trace (e.g. ``replica 1``)."""
        self._names[pid] = label

    def clear(self) -> None:
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)

    # -- export -------------------------------------------------------------
    def chrome_events(self) -> List[dict]:
        """Render the ring buffer as ``trace_event`` dicts."""
        out: List[dict] = []
        pids = {REQUEST_PID}
        for ev in self._events:
            ph = ev[0]
            if ph == "X":
                _, name, cat, ts, dur, pid, tid, args = ev
                d = {"ph": "X", "name": name, "cat": cat, "ts": ts,
                     "dur": dur, "pid": pid, "tid": tid}
                pids.add(pid)
            elif ph == "i":
                _, name, cat, ts, pid, tid, args = ev
                d = {"ph": "i", "name": name, "cat": cat, "ts": ts,
                     "pid": pid, "tid": tid, "s": "p"}
                pids.add(pid)
            elif ph == "C":
                _, name, ts, pid, value = ev
                d = {"ph": "C", "name": name, "ts": ts, "pid": pid,
                     "tid": 0, "args": {"value": value}}
                pids.add(pid)
                args = None
            else:                          # b / n / e async request events
                ph_, rid, name, ts, args = ev
                d = {"ph": ph_, "cat": "request", "id": rid, "name": name,
                     "ts": ts, "pid": REQUEST_PID, "tid": 0}
            if args:
                d["args"] = dict(args)
            out.append(d)
        meta = [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                 "args": {"name": self._names.get(
                     pid, "requests" if pid == REQUEST_PID
                     else f"replica {pid}")}}
                for pid in sorted(pids)]
        return meta + out

    def export(self, path: str) -> dict:
        """Write ``{"traceEvents": [...]}`` JSON; returns the document."""
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return doc


def jax_annotation(name: str, enabled: bool = True):
    """``jax.profiler.TraceAnnotation(name)`` when available, else no-op.

    Lets step-phase names appear inside an XLA device profile captured
    with ``jax.profiler.trace`` — purely additive, never required.
    """
    if not enabled:
        return NULL_CTX
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return NULL_CTX


def validate_trace(doc: Any) -> List[str]:
    """Structural checks on an exported trace document; returns problems
    (empty = valid). Used by tests and ``serve_bench --trace``."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents"]
    events = doc["traceEvents"]
    open_async: Dict[tuple, int] = {}
    # X-event nesting per (pid, tid): sorted by ts, a span must close
    # before any span that started earlier on the same track closes
    tracks: Dict[tuple, List[tuple]] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph == "X":
            for k in ("ts", "dur", "pid", "tid", "name"):
                if k not in ev:
                    problems.append(f"X event missing {k}: {ev}")
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(
                (ev["ts"], ev["ts"] + ev["dur"], ev["name"]))
        elif ph == "b":
            open_async[(ev.get("cat"), ev.get("id"))] = \
                open_async.get((ev.get("cat"), ev.get("id")), 0) + 1
        elif ph == "e":
            key = (ev.get("cat"), ev.get("id"))
            if open_async.get(key, 0) <= 0:
                problems.append(f"async end without begin: {ev}")
            else:
                open_async[key] -= 1
    for (pid, tid), spans in tracks.items():
        stack: List[float] = []
        eps = 1e-3                        # µs slack for fp round-trip
        for ts, te, name in sorted(spans):
            while stack and stack[-1] <= ts + eps:
                stack.pop()
            if stack and te > stack[-1] + eps:
                problems.append(
                    f"span '{name}' on ({pid},{tid}) overlaps its parent "
                    f"(ends {te:.1f} after {stack[-1]:.1f})")
            stack.append(te)
    for key, n in open_async.items():
        if n > 0:
            problems.append(f"async begin without end: {key}")
    return problems
