"""Perf-regression gate over committed ``BENCH_*.json`` snapshots.

PRs 1–9 each landed an asserted win (fused AMM, 2.2x continuous
batching, ~54% prefix reuse, 16x KV bytes/token...) and PR 6+ started
*recording* them — but nothing *compared* runs, so a regression would
sit in the JSON until a human diffed it. :func:`compare` makes the
trajectory a gate:

* rows are matched by ``name`` between a baseline document (committed)
  and a fresh document (just measured);
* each row moves in its declared ``direction`` (``down`` = smaller is
  better) and regresses when it worsens by more than its relative
  tolerance;
* tolerance is the row's explicit ``tol`` if present, else
  :data:`TIMER_TOL` (±25%) for CPU-timer rows (``unit == "us"``), else
  **exact** (``EXACT_EPS`` relative, to absorb float formatting) for
  ratio/accuracy asserts;
* CPU-timer rows are only gated when both snapshots carry the same
  ``host`` fingerprint — absolute microseconds measured on different
  machines are noise, so cross-host timer drift is *reported*, never
  failed (ratio/accuracy rows gate unconditionally);
* rows present on one side only are reported as notes, not failures —
  partial benchmark runs (``--smoke --chaos`` vs a full sweep) are
  legitimate.

``scripts/perf_gate.py`` is the CLI: by default it compares the
workspace ``BENCH_serve.json``/``BENCH_kernels.json`` against the
committed copies (``git show HEAD:...``) and exits 1 on any regression.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

TIMER_TOL = 0.25      # relative tolerance for same-host CPU-timer rows
EXACT_EPS = 1e-6      # relative slack on "exact" ratio/accuracy rows


@dataclasses.dataclass
class Delta:
    """One compared row (or a one-sided note)."""

    name: str
    base: Optional[float]
    fresh: Optional[float]
    direction: str = "down"
    tol: float = 0.0
    gated: bool = True
    regressed: bool = False
    note: str = ""

    def render(self) -> str:
        if self.base is None:
            return f"  new row (no baseline): {self.name} = {self.fresh}"
        if self.fresh is None:
            return f"  baseline row not in fresh run: {self.name}"
        pct = 100.0 * (self.fresh - self.base) / abs(self.base) \
            if self.base else 0.0
        arrow = "▲" if self.fresh > self.base else \
            ("▼" if self.fresh < self.base else "=")
        status = "REGRESSED" if self.regressed else (
            "ok" if self.gated else "ungated")
        tolpct = f"±{self.tol * 100:.0f}%" if self.tol else "exact"
        line = (f"  {status:9s} {self.name}: {self.base:g} -> "
                f"{self.fresh:g} ({arrow} {pct:+.1f}%, want "
                f"{self.direction}, tol {tolpct})")
        if self.note:
            line += f" [{self.note}]"
        return line


def tolerance_for(row: dict) -> float:
    if row.get("tol") is not None:
        return float(row["tol"])
    if row.get("unit") == "us":
        return TIMER_TOL
    return 0.0


def compare(base_doc: dict, fresh_doc: dict,
            gate_timers: str = "auto") -> Tuple[List[Delta], List[Delta]]:
    """Compare two (normalized) snapshot docs row by row.

    ``gate_timers``: ``"auto"`` gates ``us`` rows only when host
    fingerprints match, ``"always"``/``"never"`` force it.

    Returns ``(regressions, all_deltas)``.
    """
    base_rows = {r["name"]: r for r in base_doc.get("rows", [])}
    fresh_rows = {r["name"]: r for r in fresh_doc.get("rows", [])}
    same_host = (base_doc.get("host") is not None
                 and base_doc.get("host") == fresh_doc.get("host"))
    deltas: List[Delta] = []
    for name, b in base_rows.items():
        f = fresh_rows.get(name)
        if f is None:
            deltas.append(Delta(name, b["value"], None, gated=False))
            continue
        direction = b.get("direction", "down")
        tol = tolerance_for(b)
        gated = True
        note = ""
        if b.get("unit") == "us":
            if gate_timers == "never" or (gate_timers == "auto"
                                          and not same_host):
                gated = False
                note = "cross-host timer: reported, not gated"
        bad = _worsened(b["value"], f["value"], direction, tol)
        deltas.append(Delta(name, b["value"], f["value"], direction, tol,
                            gated=gated, regressed=bad and gated,
                            note=note))
    for name, f in fresh_rows.items():
        if name not in base_rows:
            deltas.append(Delta(name, None, f["value"], gated=False))
    regressions = [d for d in deltas if d.regressed]
    return regressions, deltas


def _worsened(base: float, fresh: float, direction: str,
              tol: float) -> bool:
    slack = abs(base) * max(tol, EXACT_EPS) + 1e-12
    if direction == "down":
        return fresh > base + slack
    return fresh < base - slack


def gate(pairs: List[Tuple[dict, dict, str]],
         gate_timers: str = "auto") -> Tuple[int, List[str]]:
    """Run :func:`compare` over ``(base_doc, fresh_doc, label)`` pairs.

    Returns ``(exit_code, report_lines)`` — 0 iff no gated row
    regressed anywhere.
    """
    lines: List[str] = []
    n_reg = 0
    for base_doc, fresh_doc, label in pairs:
        regs, deltas = compare(base_doc, fresh_doc, gate_timers)
        n_reg += len(regs)
        n_gated = sum(1 for d in deltas if d.gated)
        lines.append(f"{label}: {len(deltas)} row(s), {n_gated} gated, "
                     f"{len(regs)} regression(s)")
        lines.extend(d.render() for d in deltas)
    lines.append("perf gate: " + ("FAIL" if n_reg else "OK") +
                 f" ({n_reg} regression(s))")
    return (1 if n_reg else 0), lines
