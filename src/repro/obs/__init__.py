"""Serving observability: metrics registry, tracing, snapshots, perf gate.

:class:`Obs` is the per-engine bundle the serving stack records into —
one :class:`~repro.obs.metrics.Registry` per engine (so per-replica
counters stay attributable) plus a :class:`~repro.obs.tracing.Tracer`
that *may be shared* across replicas to export one merged Perfetto
timeline. ``Obs.pid`` is the replica index (stamped by
:class:`~repro.serve.router.ReplicaRouter`) and keys the trace track.

Hot-path contract (enforced by ``analysis/astlint.py``'s
``SYNC_FREE_PATHS`` knob and ``tests/test_obs.py``): recording never
touches device values — counters are host ints, timestamps are
``perf_counter`` at points the engine already runs host code, and with
``Obs.disabled()`` the timing layer collapses to a shared no-op context
manager (counters stay live: they double as engine state that tests and
schedulers read).
"""
from __future__ import annotations

import time
from typing import Optional

from .metrics import Counter, Gauge, Histogram, Registry, safe_ratio
from .tracing import (NULL_CTX, REQUEST_PID, Tracer, jax_annotation,
                      validate_trace)
from .snapshot import (infer_direction, load_snapshot, make_row,
                       merge_snapshot, normalize_row, write_snapshot)
from .perfgate import compare, gate

__all__ = [
    "Obs", "Registry", "Counter", "Gauge", "Histogram", "Tracer",
    "safe_ratio", "jax_annotation", "validate_trace", "REQUEST_PID",
    "NULL_CTX", "make_row", "normalize_row", "write_snapshot",
    "merge_snapshot", "load_snapshot", "infer_direction", "compare",
    "gate",
]


class _Phase:
    """Times one engine step phase: feeds a histogram and, when the
    tracer is live, appends one ``X`` trace event."""

    __slots__ = ("obs", "name", "_t0")

    def __init__(self, obs: "Obs", name: str):
        self.obs, self.name = obs, name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        obs = self.obs
        if obs.timing:
            obs._phase_hist(self.name).observe(t1 - self._t0)
        tr = obs.tracer
        if tr.enabled:
            tr._events.append(
                ("X", self.name, "phase", (self._t0 - tr._t0) * 1e6,
                 (t1 - self._t0) * 1e6, obs.pid, 0, None))
        return False


class Obs:
    """Per-engine observability bundle: registry + tracer + switches.

    * ``metrics`` — always-live :class:`Registry` (engine counters are
      backed by it even when "disabled").
    * ``tracer`` — ring-buffer :class:`Tracer`; pass a shared instance
      to merge replicas into one exported timeline.
    * ``timing`` — when False, :meth:`phase` returns a shared no-op
      context manager and no histograms are touched (the < 5% overhead
      micro-benchmark in ``serve_bench`` measures this path).
    * ``jax_annotations`` — additionally wrap phases in
      ``jax.profiler.TraceAnnotation`` for XLA profiles (off by
      default; purely additive).
    """

    def __init__(self, metrics: Optional[Registry] = None,
                 tracer: Optional[Tracer] = None, timing: bool = True,
                 jax_annotations: bool = False):
        self.metrics = metrics if metrics is not None else Registry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.timing = timing
        self.jax_annotations = jax_annotations
        self.pid = 0
        self._phase_hists = {}

    @classmethod
    def disabled(cls) -> "Obs":
        """Recording compiled out: no timing, no tracing (counters stay
        live — they are engine state, and an ``inc`` costs what the old
        ad-hoc ``+=`` did)."""
        return cls(timing=False)

    @property
    def active(self) -> bool:
        return self.timing or self.tracer.enabled

    def _phase_hist(self, name: str) -> Histogram:
        h = self._phase_hists.get(name)
        if h is None:
            h = self.metrics.histogram(f"engine.phase.{name}_s", unit="s",
                                       desc=f"host time in step phase "
                                            f"'{name}'")
            self._phase_hists[name] = h
        return h

    def phase(self, name: str):
        """Context manager timing one step phase (no-op when inactive)."""
        if not (self.timing or self.tracer.enabled):
            return NULL_CTX
        if self.jax_annotations:
            return _AnnotatedPhase(self, name)
        return _Phase(self, name)

    def annotate(self, name: str, **args) -> None:
        """Instant annotation event on this replica's trace track
        (degradation flip, preemption, CoW fork, fault, health change)."""
        tr = self.tracer
        if tr.enabled:
            tr.instant(name, cat="annot", pid=self.pid,
                       args=args or None)

    def track(self, name: str, value: float) -> None:
        """Counter time-series sample on this replica's track."""
        tr = self.tracer
        if tr.enabled:
            tr.counter(name, value, pid=self.pid)


class _AnnotatedPhase(_Phase):
    """_Phase that also enters a ``jax.profiler.TraceAnnotation``."""

    __slots__ = ("_ann",)

    def __enter__(self):
        self._ann = jax_annotation(self.name)
        self._ann.__enter__()
        return super().__enter__()

    def __exit__(self, *exc):
        super().__exit__(*exc)
        self._ann.__exit__(*exc)
        return False
