"""mamba2-2.7b [ssm]: SSD, attention-free (arXiv:2405.21060).
64L d_model=2560, ssm_state=128, d_ff=0, vocab=50280."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280, head_dim=1,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        dtype="bfloat16")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256, head_dim=1,
        ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_ngroups=1,
        dtype="float32")
