"""Architecture registry: the 10 assigned architectures + proxy models.

Each module exposes ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family configuration for CPU smoke tests).
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.models.config import ModelConfig

from . import (dbrx_132b, deepseek_moe_16b, gemma3_4b, gemma3_27b,
               mamba2_2p7b, musicgen_large, paligemma_3b, qwen1p5_4b,
               yi_9b, zamba2_1p2b)

_MODULES = {
    "zamba2-1.2b": zamba2_1p2b,
    "mamba2-2.7b": mamba2_2p7b,
    "gemma3-27b": gemma3_27b,
    "qwen1.5-4b": qwen1p5_4b,
    "gemma3-4b": gemma3_4b,
    "yi-9b": yi_9b,
    "dbrx-132b": dbrx_132b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "musicgen-large": musicgen_large,
    "paligemma-3b": paligemma_3b,
}

REGISTRY: Dict[str, Callable[[], ModelConfig]] = {
    name: mod.config for name, mod in _MODULES.items()}
SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {
    name: mod.smoke_config for name, mod in _MODULES.items()}

ARCH_NAMES = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    return REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    return SMOKE_REGISTRY[name]()
