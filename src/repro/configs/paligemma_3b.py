"""paligemma-3b [vlm]: SigLIP + gemma (arXiv:2407.07726). LM backbone:
18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216. The SigLIP
frontend is a STUB: input_specs() provides precomputed patch embeddings
(B, 256, d_model); the image prefix attends bidirectionally (prefix-LM)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        d_ff=16384, vocab_size=257216, head_dim=256, num_patches=256,
        dtype="bfloat16", attn_impl="chunked")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke", family="vlm",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16, num_patches=16,
        dtype="float32")
