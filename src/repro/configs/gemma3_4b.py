"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5:1 local:global (hf:google/gemma-3-4b)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256,
        global_every=6, sliding_window=1024,
        rope_theta=1_000_000.0, dtype="bfloat16", attn_impl="chunked")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        global_every=6, sliding_window=8, dtype="float32")
