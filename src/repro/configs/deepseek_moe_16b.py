"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408
(fine-grained experts), 2 shared + 64 routed top-6 (arXiv:2401.06066).
Simplification: every layer is MoE (the real model's layer-0 dense FFN is
dropped; see DESIGN.md)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        num_experts=64, num_shared_experts=2, top_k=6, capacity_factor=1.25,
        dtype="bfloat16", attn_impl="chunked", tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256,
        # cf=4 makes routing drop-free at smoke sizes (cap==T): the
        # decode-vs-forward parity tests require no capacity overflow.
        num_experts=8, num_shared_experts=2, top_k=2, capacity_factor=4.0,
        dtype="float32", tie_embeddings=False)
