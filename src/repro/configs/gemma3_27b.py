"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local(1024-window):global layers, 128k context
(hf:google/gemma-3-*). head_dim=128 (the published value; d_model/heads
would be 168 — gemma3 decouples q-dim from d_model)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
        d_ff=21504, vocab_size=262144, head_dim=128,
        global_every=6, sliding_window=1024,
        rope_theta=1_000_000.0, dtype="bfloat16", attn_impl="chunked")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        global_every=3, sliding_window=8, dtype="float32")
