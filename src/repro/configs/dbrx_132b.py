"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, 16 experts top-4 fine-grained (hf:databricks/dbrx-base)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        num_experts=16, top_k=4, capacity_factor=1.25,
        dtype="bfloat16", attn_impl="chunked", tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        num_experts=4, top_k=2, capacity_factor=2.0,
        dtype="float32", tie_embeddings=False)
