"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block
(arXiv:2411.15242). 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64. Our simplification: ONE shared attn+MLP block applied every
6th layer (the real model alternates two shared blocks; see DESIGN.md)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        shared_attn_every=6, dtype="bfloat16", attn_impl="chunked")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_ngroups=1,
        shared_attn_every=2, dtype="float32")
