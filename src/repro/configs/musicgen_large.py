"""musicgen-large [audio]: decoder-only over EnCodec tokens
(arXiv:2306.05284). 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048,
4 codebooks. The EnCodec frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, num_codebooks=4,
        dtype="bfloat16", attn_impl="chunked")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=64, num_codebooks=4, dtype="float32")
