"""yi-9b [dense]: llama-arch GQA (arXiv:2403.04652). 48L d_model=4096
32H (kv=4) d_ff=11008 vocab=64000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", family="dense",
        num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000,
        dtype="bfloat16", attn_impl="chunked", tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32", tie_embeddings=False)
