"""Fused VQ-assign + LUT-GEMM kernel: parity vs the two-pass oracle.

Everything runs the Pallas interpreter on CPU; the contract under test is
out == lut_gemm_ref(assign_ref(x, z), lut) with indices never materialised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lut import QuantConfig, build_lut, lut_linear_apply, \
    lut_linear_init, precompute_layer
from repro.kernels import ref
from repro.kernels.fused_amm import vq_amm_pallas
from repro.kernels.ops import vq_amm
from repro.kernels.tuning import regime, select_blocks

METRICS = ["l2", "l1", "chebyshev"]


def _mk(key, m, nc, v, c, n, dtype=jnp.float32):
    x = jax.random.normal(key, (m, nc, v)).astype(dtype)
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v)).astype(dtype)
    lut = jax.random.normal(jax.random.fold_in(key, 2), (nc, c, n))
    return x, z, lut


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("m,nc,v,c,n", [
    (32, 8, 4, 16, 64), (64, 12, 8, 8, 96), (16, 4, 16, 32, 128),
])
def test_fused_matches_two_pass_oracle(metric, m, nc, v, c, n):
    x, z, lut = _mk(jax.random.PRNGKey(m * n + c), m, nc, v, c, n)
    o_ref = ref.lut_gemm_ref(ref.assign_ref(x, z, metric), lut)
    o_pl = vq_amm_pallas(x, z, lut, metric=metric, block_m=16, block_n=32,
                         block_k=4, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", METRICS)
def test_fused_index_parity_exact(metric):
    """Decode the selected index from the fused output: with a LUT whose
    (k, j, n) entry is j·[n == k], column n of the output IS idx[:, n]."""
    m, nc, v, c = 40, 6, 4, 16
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (m, nc, v))
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v))
    dec = (jnp.arange(c, dtype=jnp.float32)[None, :, None]
           * jnp.eye(nc)[:, None, :])                       # (nc, c, nc)
    out = vq_amm_pallas(x, z, dec, metric=metric, block_m=8, block_k=2,
                        interpret=True)
    idx_fused = np.asarray(jnp.round(out)).astype(np.int32)
    idx_ref = np.asarray(ref.assign_ref(x, z, metric))
    np.testing.assert_array_equal(idx_fused, idx_ref)


@pytest.mark.parametrize("m,nc,c,n", [
    (17, 5, 7, 33), (1, 3, 9, 50), (23, 11, 6, 130),
])
def test_fused_nonmultiple_shapes_padding_path(m, nc, c, n):
    v = 3
    x, z, lut = _mk(jax.random.PRNGKey(m + n), m, nc, v, c, n)
    o_ref = ref.vq_amm_ref(x, z, lut)
    o_pl = vq_amm_pallas(x, z, lut, block_m=8, block_n=32, block_k=4,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


def test_fused_int8_lut_with_scale():
    m, nc, v, c, n = 48, 6, 4, 16, 80
    key = jax.random.PRNGKey(2)
    x, z, lut = _mk(key, m, nc, v, c, n)
    scale = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (n,))) + .05
    lut8 = jnp.clip(jnp.round(lut / scale * 16), -127, 127).astype(jnp.int8)
    o_ref = ref.vq_amm_ref(x, z, lut8, scale / 16)
    o_pl = vq_amm_pallas(x, z, lut8, scale / 16, block_m=16, block_n=16,
                         block_k=3, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


def test_fused_bf16_inputs():
    m, nc, v, c, n = 32, 8, 8, 16, 64
    x, z, lut = _mk(jax.random.PRNGKey(5), m, nc, v, c, n, dtype=jnp.bfloat16)
    o_pl = vq_amm_pallas(x, z, lut, block_m=16, block_k=4, interpret=True)
    # distances are computed in fp32 inside the kernel; the oracle on the
    # same bf16 inputs upcast identically must agree exactly on indices
    o_ref = ref.vq_amm_ref(x.astype(jnp.float32), z.astype(jnp.float32), lut)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


def test_ops_vq_amm_dispatch_paths_agree():
    x, z, lut = _mk(jax.random.PRNGKey(11), 24, 6, 4, 8, 40)
    o_auto = vq_amm(x, z, lut)                       # auto -> ref on CPU
    o_fused = vq_amm(x, z, lut, impl="fused")        # interpreted kernel
    o_two = vq_amm(x, z, lut, impl="pallas")         # two-pass baseline
    o_ref = ref.vq_amm_ref(x, z, lut)
    for o in (o_auto, o_fused, o_two):
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", METRICS)
def test_lut_linear_fuse_knob_matches_two_pass(metric, rng):
    qc = QuantConfig(mode="lut_infer", v=4, c=16, metric=metric,
                     impl="fused", fuse=True)
    p = lut_linear_init(rng, 16, 24, qc, bias=True)
    p = precompute_layer(p, qc)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 16))
    out_f, _ = lut_linear_apply(p, x, qc)
    out_u, _ = lut_linear_apply(p, x, qc.replace(fuse=False, impl="ref"))
    assert out_f.shape == (2, 5, 24)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_u),
                               rtol=1e-4, atol=1e-4)


def test_lut_linear_fuse_int8(rng):
    qc = QuantConfig(mode="lut_infer", v=4, c=8, lut_dtype="int8",
                     impl="fused")
    p = lut_linear_init(rng, 16, 12, qc)
    p = precompute_layer(p, qc)
    assert p["lut"].dtype == jnp.int8
    x = jax.random.normal(jax.random.PRNGKey(4), (6, 16))
    out_f, _ = lut_linear_apply(p, x, qc)
    out_r, _ = lut_linear_apply(p, x, qc.replace(impl="ref"))
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)


def test_block_heuristic_regimes():
    assert regime(1) == "decode" and regime(8) == "decode"
    assert regime(64) == "mid"
    assert regime(256) == "prefill" and regime(4096) == "prefill"
    dec = select_blocks("fused", 4, 96, 16, 768)
    pre = select_blocks("fused", 1024, 96, 16, 768)
    assert dec.block_m <= 8 and pre.block_m >= 256
    assert dec.block_n >= pre.block_n // 2    # decode keeps the N-tile wide
    # large-c codebooks shrink block_n to fit the VMEM budget
    big = select_blocks("lut_gemm", 512, 96, 4096, 4096)
    assert big.block_k * 4096 * big.block_n * 4 <= 4 * 1024 * 1024


def test_fused_moe_expert_path(rng):
    """Per-expert codebooks through the shared dispatch (vmapped vq_amm)."""
    from repro.models.moe import expert_proj, init_expert_proj
    qc = QuantConfig(mode="lut_infer", v=4, c=8, impl="fused")
    p = init_expert_proj(rng, 3, 16, 20, qc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 10, 16))
    out_f, _ = expert_proj(p, x, qc)
    out_r, _ = expert_proj(p, x, qc.replace(impl="ref", fuse=False))
    assert out_f.shape == (3, 10, 20)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
