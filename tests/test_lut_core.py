"""LUT construction, LutLinear modes, LUTBoost conversion pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantConfig, build_lut, convert, kmeans_codebook,
                        lut_linear_apply, lut_linear_init, precompute_layer,
                        precompute_model, quantize_lut_int8,
                        stage_mask, apply_mask, strip_for_inference)
from repro.core.codebook import CodebookSpec


def test_build_lut_matches_explicit(rng):
    k, n, v, c = 16, 12, 4, 8
    nc = k // v
    w = jax.random.normal(rng, (k, n))
    z = jax.random.normal(jax.random.PRNGKey(1), (nc, c, v))
    lut = build_lut(w, z)
    for kk in range(nc):
        for j in range(c):
            expect = z[kk, j] @ w[kk * v:(kk + 1) * v]
            np.testing.assert_allclose(np.asarray(lut[kk, j]),
                                       np.asarray(expect), rtol=1e-5,
                                       atol=1e-5)


def test_quantize_lut_int8_error_bound(rng):
    lut = jax.random.normal(rng, (6, 8, 20)) * 3.0
    lut8, scale = quantize_lut_int8(lut)
    recon = lut8.astype(jnp.float32) * scale[None, None, :]
    err = jnp.abs(recon - lut)
    # error per entry bounded by scale/2 (symmetric rounding)
    assert float(jnp.max(err - scale[None, None, :] / 2)) < 1e-6


def test_equivalent_bits():
    assert CodebookSpec(v=8, c=16).equivalent_bits == 0.5
    assert CodebookSpec(v=3, c=8).equivalent_bits == 1.0
    assert CodebookSpec(v=9, c=8).equivalent_bits == pytest.approx(1 / 3)


def test_kmeans_reduces_distortion(rng):
    spec = CodebookSpec(v=4, c=8)
    acts = jax.random.normal(rng, (256, 16))
    z0 = kmeans_codebook(acts, 16, spec, iters=1, key=rng)
    z10 = kmeans_codebook(acts, 16, spec, iters=12, key=rng)

    def distortion(z):
        from repro.core.similarity import pairwise_distance_subspaces
        d = pairwise_distance_subspaces(acts.reshape(-1, 4, 4), z, "l2")
        return float(jnp.mean(jnp.min(d, -1)))

    assert distortion(z10) <= distortion(z0) + 1e-6


def test_kmeans_codebook_subsample_key_hygiene():
    """The calibration-subsample permutation and the per-subspace k-means
    inits must consume DISTINCT subkeys (regression: the permutation key
    was re-split for the inits — classic JAX key reuse). Observable
    contract: deterministic per key, different across keys, and the
    subsample path (n > max_samples) produces finite centroids."""
    spec = CodebookSpec(v=4, c=8)
    acts = jax.random.normal(jax.random.PRNGKey(3), (600, 16))
    a = kmeans_codebook(acts, 16, spec, iters=2, key=jax.random.PRNGKey(0),
                        max_samples=128)
    a2 = kmeans_codebook(acts, 16, spec, iters=2, key=jax.random.PRNGKey(0),
                         max_samples=128)
    b = kmeans_codebook(acts, 16, spec, iters=2, key=jax.random.PRNGKey(1),
                        max_samples=128)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    assert np.isfinite(np.asarray(a)).all() and a.shape == (4, 8, 4)


@pytest.mark.parametrize("metric", ["l2", "l1", "chebyshev"])
def test_lut_linear_modes_consistent(metric, rng):
    qc_t = QuantConfig(mode="lut_train", v=4, c=16, metric=metric)
    p = lut_linear_init(rng, 16, 24, qc_t, bias=True)
    x = jax.random.normal(jax.random.PRNGKey(2), (10, 16))
    out_t, recon = lut_linear_apply(p, x, qc_t)
    assert out_t.shape == (10, 24) and float(recon) > 0
    qc_i = QuantConfig(mode="lut_infer", v=4, c=16, metric=metric, impl="ref")
    pi = precompute_layer(p, qc_i)
    out_i, zero = lut_linear_apply(pi, x, qc_i)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_i),
                               rtol=2e-4, atol=2e-4)
    assert float(zero) == 0.0


def test_paper_mode_gradients_use_dense_path(rng):
    """Paper §V-2: backward uses A·W — dW must equal Aᵀg (not Âᵀg)."""
    qc = QuantConfig(mode="lut_train", v=4, c=4, metric="l2",
                     task_grad_to_centroids=False, recon_weight=0.0)
    p = lut_linear_init(rng, 8, 6, qc)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 8))

    def loss(w):
        out, _ = lut_linear_apply({**p, "w": w}, x, qc)
        return jnp.sum(out)

    gw = jax.grad(loss)(p["w"])
    expect = x.T @ jnp.ones((5, 6))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_centroids_get_gradient_only_via_recon(rng):
    qc = QuantConfig(mode="lut_train", v=4, c=4, metric="l2",
                     task_grad_to_centroids=False)
    p = lut_linear_init(rng, 8, 6, qc)
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 8))

    def task_only(z):
        out, _ = lut_linear_apply({**p, "z": z}, x, qc)
        return jnp.sum(out)

    def with_recon(z):
        out, recon = lut_linear_apply({**p, "z": z}, x, qc)
        return jnp.sum(out) + recon

    gz_task = jax.grad(task_only)(p["z"])
    gz_recon = jax.grad(with_recon)(p["z"])
    np.testing.assert_allclose(np.asarray(gz_task), 0.0, atol=1e-7)
    assert float(jnp.max(jnp.abs(gz_recon))) > 0


def test_stage_mask_and_apply(rng):
    qc = QuantConfig(mode="lut_train", v=4, c=4)
    params = {"a": lut_linear_init(rng, 8, 8, qc),
              "norm": jnp.zeros((8,))}
    m2 = stage_mask(params, 2)
    assert m2["a"]["z"] is True
    assert m2["a"]["w"] is False and m2["norm"] is False
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    masked = apply_mask(grads, m2)
    assert float(jnp.sum(masked["a"]["w"])) == 0.0
    assert float(jnp.sum(masked["a"]["z"])) > 0
    m3 = stage_mask(params, 3)
    assert all(jax.tree_util.tree_leaves(m3))


def test_convert_runs_kmeans_on_captured_activations(rng):
    qc = QuantConfig(mode="lut_train", v=4, c=8)
    params = {"fc": lut_linear_init(rng, 16, 8, qc)}

    def fwd(p, x):
        return lut_linear_apply(p["fc"], x, qc.replace(mode="lut_train"))[0]

    x = jax.random.normal(jax.random.PRNGKey(5), (64, 16)) * 5.0
    z_before = params["fc"]["z"]
    params2 = convert(fwd, params, x, qc)
    # centroids moved to the activation scale (std 5), not init scale 0.02
    assert float(jnp.std(params2["fc"]["z"])) > 1.0
    assert float(jnp.std(z_before)) < 0.1


def test_strip_for_inference():
    qc = QuantConfig(mode="lut_infer", v=4, c=4)
    p = lut_linear_init(jax.random.PRNGKey(0), 8, 8,
                        qc.replace(mode="lut_train"))
    pi = precompute_layer(p, qc)
    stripped = strip_for_inference(pi)
    assert "w" not in stripped and "lut" in stripped and "z" in stripped


def test_precompute_model_handles_stacked_and_expert_dims(rng):
    qc = QuantConfig(mode="lut_infer", v=4, c=4)
    stacked = {"w": jax.random.normal(rng, (3, 8, 6)),
               "z": jax.random.normal(rng, (3, 2, 4, 4))}
    experts = {"w": jax.random.normal(rng, (3, 5, 8, 6)),
               "z": jax.random.normal(rng, (3, 5, 2, 4, 4))}
    out = precompute_model({"a": stacked, "b": experts}, qc)
    assert out["a"]["lut"].shape == (3, 2, 4, 6)
    assert out["b"]["lut"].shape == (3, 5, 2, 4, 6)
