"""Vector-quantized KV-cache pages: codebook laws, kernel parity, serving.

Layers of evidence, innermost out:
  1. codebook algebra — encode/decode round-trip error IS the nearest-
     centroid distance (property tests), codes are in-range uint8 with
     ``nc * v == head_dim``, and a row that sits on a centroid round-
     trips bit-identical (``from_rows`` builds exactly that situation
     for a whole run's row set);
  2. the quantized kernels — the LUT-accumulate "ref" impl and the
     dequant-in-VMEM Pallas grid — match the dequantize-then-reference
     oracle ``kernels.ref.flash_decode_kvq_ref`` across GQA / window /
     kv_start / page-boundary / inactive-lane grids;
  3. model-level decode chains over a quantized pool are token-identical
     across the gather / ref / pallas read paths, for dense and
     ``lut_infer`` weights (both lossy paths stacked) and for gemma-style
     GQA + sliding window;
  4. the serving engine with ``kv_quant="vq"``: prefix-cache warm==cold
     parity (the cache identifies CODES, salted by the codebook
     fingerprint), CoW forks preserve codes, speculative rollback keeps
     refcount == mapped rows after every step, the chaos schedule loses
     zero requests, and admission accounting reports real bytes.
"""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.kv_codebook import (CODEBOOK_KEY, KVCodebook,
                                    codebook_from_tree, kv_decode, kv_encode)
from repro.core.lut import DENSE, QuantConfig
from repro.kernels.flash_decode import flash_decode_paged
from repro.kernels.ref import flash_decode_kvq_ref
from repro.models.model import Model
from repro.serve import (Engine, FaultInjector, FaultSchedule, FinishReason,
                         PageTable, ReplicaRouter, Request, SpecConfig)
from repro.serve.kv_cache import _chunk_keys

KEY = jax.random.PRNGKey(0)
KVQ = DENSE.replace(kv_quant="vq")


# ---------------------------------------------------------------------------
# codebook algebra (hypothesis property tests)
# ---------------------------------------------------------------------------

def _rand_layer_codebook(rng, nc, c, v, kvh):
    z = jnp.asarray(rng.randn(nc, c, v), jnp.float32)
    s = jnp.asarray(np.abs(rng.randn(kvh)) + 0.5, jnp.float32)
    return z, s


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 20), kvh=st.integers(1, 3),
       nc=st.integers(1, 4), c=st.integers(2, 12), v=st.integers(1, 4),
       t=st.integers(1, 6))
def test_roundtrip_error_is_nearest_centroid_distance(seed, kvh, nc, c, v, t):
    """decode(encode(x)) lands on the nearest centroid — per subspace the
    reconstruction error equals min-over-centroids distance (in the
    scale-normalised space the assignment runs in), so the round-trip
    error is bounded by the codebook covering radius by construction."""
    rng = np.random.RandomState(seed)
    z, s = _rand_layer_codebook(rng, nc, c, v, kvh)
    rows = jnp.asarray(rng.randn(t, kvh, nc * v) * 2, jnp.float32)
    codes = kv_encode(rows, z, s)
    rec = kv_decode(codes, z, s)
    x = np.asarray(rows / s[:, None]).reshape(t, kvh, nc, v)
    r = np.asarray(rec / s[:, None]).reshape(t, kvh, nc, v)
    # distance of every subvector to every centroid, then the min
    d = np.linalg.norm(x[..., None, :] - np.asarray(z)[None, None], axis=-1)
    got = np.linalg.norm(x - r, axis=-1)
    np.testing.assert_allclose(got, d.min(-1), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 20), kvh=st.integers(1, 3),
       nc=st.integers(1, 4), c=st.integers(2, 16), v=st.integers(1, 4))
def test_codes_uint8_in_range_and_shape_algebra(seed, kvh, nc, c, v):
    rng = np.random.RandomState(seed)
    z, s = _rand_layer_codebook(rng, nc, c, v, kvh)
    rows = jnp.asarray(rng.randn(5, kvh, nc * v), jnp.float32)
    codes = kv_encode(rows, z, s)
    assert codes.dtype == jnp.uint8
    assert codes.shape == (5, kvh, nc)
    assert int(codes.max()) < c
    rec = kv_decode(codes, z, s)
    assert rec.shape == rows.shape and rec.dtype == jnp.float32


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 20), nc=st.integers(1, 3),
       c=st.integers(2, 8), v=st.integers(1, 4))
def test_centroid_rows_roundtrip_bit_identical(seed, nc, c, v):
    """A row assembled FROM centroids (unit scale) must encode to those
    centroids' indices and decode back bit-identical — quantize-of-
    centroid is exact, the germ of the from_rows identity tests."""
    rng = np.random.RandomState(seed)
    z = jnp.asarray(rng.randn(nc, c, v), jnp.float32)
    s = jnp.ones((1,), jnp.float32)
    idx = rng.randint(0, c, size=(4, 1, nc))
    rows = np.asarray(z)[np.arange(nc), idx].reshape(4, 1, nc * v)
    codes = kv_encode(jnp.asarray(rows), z, s)
    np.testing.assert_array_equal(np.asarray(codes), idx.astype(np.uint8))
    rec = kv_decode(codes, z, s)
    np.testing.assert_array_equal(np.asarray(rec), rows)


def test_from_rows_exact_cover_roundtrip_and_bounds():
    rng = np.random.RandomState(3)
    l, t, kvh, hd = 2, 5, 3, 16
    rows_k = jnp.asarray(rng.randn(l, t, kvh, hd), jnp.float32)
    rows_v = jnp.asarray(rng.randn(l, t, kvh, hd), jnp.float32)
    cb = KVCodebook.from_rows(rows_k, rows_v)
    assert (cb.nc, cb.c, cb.v) == (1, t * kvh, hd)
    for which, rows in (("k", rows_k), ("v", rows_v)):
        rec = cb.decode(cb.encode(rows, which), which)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(rows))
    with pytest.raises(ValueError, match="exact-cover"):
        KVCodebook.from_rows(jnp.zeros((1, 130, 2, 8)),
                             jnp.zeros((1, 130, 2, 8)))


def test_codebook_validation_and_fingerprint():
    z = jnp.zeros((2, 4, 300, 4))
    with pytest.raises(ValueError, match="uint8"):
        KVCodebook(zk=z, zv=z, sk=jnp.ones((2, 2)), sv=jnp.ones((2, 2)))
    rng = np.random.RandomState(0)
    rows = jnp.asarray(rng.randn(2, 6, 2, 8), jnp.float32)
    cb = KVCodebook.fit(rows, rows + 0.5, v=4, c=4, iters=2, key=KEY)
    assert cb.head_dim == 8 and cb.equivalent_bits == pytest.approx(0.5)
    assert cb.fingerprint() == codebook_from_tree(cb.tree()).fingerprint()
    cb2 = KVCodebook.fit(rows + 1.0, rows, v=4, c=4, iters=2, key=KEY)
    assert cb.fingerprint() != cb2.fingerprint()


# ---------------------------------------------------------------------------
# kernel-level parity vs the dequantize-then-reference oracle
# ---------------------------------------------------------------------------

def _mk_kvq_case(seed, slots, np_, ps, kvh, g, d, positions, nc=4, c=16):
    """Synthetic quantized pool mirroring test_flash_decode._mk_case:
    permuted physical pages, in-range random codes, a random codebook
    with non-trivial per-head scales, fp q/k_new/v_new."""
    key = jax.random.PRNGKey(seed)
    rng = np.random.RandomState(seed)
    p1 = slots * np_ + 1
    v = d // nc
    ks = jax.random.split(key, 5)
    kc = jnp.asarray(rng.randint(0, c, (p1, ps, kvh, nc)), jnp.uint8)
    vc = jnp.asarray(rng.randint(0, c, (p1, ps, kvh, nc)), jnp.uint8)
    cb = {"zk": jax.random.normal(ks[0], (nc, c, v), jnp.float32),
          "zv": jax.random.normal(ks[1], (nc, c, v), jnp.float32),
          "sk": jnp.asarray(np.abs(rng.randn(kvh)) + 0.5, jnp.float32),
          "sv": jnp.asarray(np.abs(rng.randn(kvh)) + 0.5, jnp.float32)}
    perm = rng.permutation(p1 - 1)
    phys = np.full((slots, np_), p1 - 1, np.int64)
    for b, pos in enumerate(positions):
        n_alloc = min(-(-(int(pos) + 1) // ps), np_) if pos >= 0 else 0
        phys[b, :n_alloc] = perm[b * np_: b * np_ + n_alloc]
    q = jax.random.normal(ks[2], (slots, 1, kvh * g, d), jnp.float32)
    k_new = jax.random.normal(ks[3], (slots, 1, kvh, d), jnp.float32)
    v_new = jax.random.normal(ks[4], (slots, 1, kvh, d), jnp.float32)
    return (q, kc, vc, cb, k_new, v_new,
            jnp.asarray(phys, jnp.int32), jnp.asarray(positions, jnp.int32))


# page boundary (16), one past (17), mid-page (9), inactive lane (-1)
_POSITIONS = [16, 17, 9, -1]


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("kvh,g", [(2, 1), (2, 3)])          # MHA and GQA
@pytest.mark.parametrize("window,kv_start", [(0, 0), (11, 0), (0, 5),
                                             (11, 5)])
def test_kvq_flash_matches_dequant_oracle(impl, kvh, g, window, kv_start):
    q, kc, vc, cb, kn, vn, phys, pos = _mk_kvq_case(
        seed=3, slots=4, np_=4, ps=8, kvh=kvh, g=g, d=16,
        positions=_POSITIONS)
    out = flash_decode_paged(q, kc, vc, kn, vn, phys, pos, window=window,
                             kv_start=kv_start, impl=impl, codebook=cb,
                             interpret=True)
    oracle = flash_decode_kvq_ref(q, kc, vc, cb, kn, vn, phys, pos,
                                  window=window, kv_start=kv_start)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_kvq_flash_single_slot_batch(impl):
    q, kc, vc, cb, kn, vn, phys, pos = _mk_kvq_case(
        seed=11, slots=1, np_=4, ps=8, kvh=2, g=2, d=16, positions=[24])
    out = flash_decode_paged(q, kc, vc, kn, vn, phys, pos, impl=impl,
                             codebook=cb, interpret=True)
    oracle = flash_decode_kvq_ref(q, kc, vc, cb, kn, vn, phys, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)


def test_kvq_trash_page_codes_never_attended():
    """Rewriting the trash page's CODES must not change any live output."""
    q, kc, vc, cb, kn, vn, phys, pos = _mk_kvq_case(
        seed=9, slots=3, np_=4, ps=8, kvh=2, g=2, d=16,
        positions=[9, 16, -1])
    for impl in ("ref", "pallas"):
        a = flash_decode_paged(q, kc, vc, kn, vn, phys, pos, impl=impl,
                               codebook=cb, interpret=True)
        b = flash_decode_paged(q, kc.at[-1].set(15), vc.at[-1].set(0),
                               kn, vn, phys, pos, impl=impl, codebook=cb,
                               interpret=True)
        live = np.asarray(pos) >= 0
        np.testing.assert_array_equal(np.asarray(a)[live],
                                      np.asarray(b)[live])


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_kvq_flash_8k_context_parity(impl):
    """8k-token heavy: quantized long-context parity at a realistic page
    count — the regime the 4x-bytes claim is about."""
    ps, np_ = 16, 512                                  # 8192 tokens / slot
    q, kc, vc, cb, kn, vn, phys, pos = _mk_kvq_case(
        seed=17, slots=2, np_=np_, ps=ps, kvh=2, g=2, d=32,
        positions=[8191, 5000])
    out = flash_decode_paged(q, kc, vc, kn, vn, phys, pos, impl=impl,
                             codebook=cb, interpret=True)
    oracle = flash_decode_kvq_ref(q, kc, vc, cb, kn, vn, phys, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# model-level decode chains over a quantized pool
# ---------------------------------------------------------------------------

def _fit_for(cfg, v=4, c=16):
    rng = np.random.RandomState(1)
    rows = jnp.asarray(rng.randn(cfg.num_layers, 24, cfg.num_kv_heads,
                                 cfg.head_dim), jnp.float32)
    return KVCodebook.fit(rows, rows + 0.3, v=v, c=c, iters=2, key=KEY)


def _kvq_chain_parity(cfg, qc_base, params=None, steps=3, lens=(9, 16)):
    """Greedy chains over ONE quantized pool must be token-identical
    across the gather / ref / pallas read paths (they all read the same
    codes; only the float summation order differs)."""
    m = Model(cfg)
    if params is None:
        params = m.init(KEY, qc_base)
    cb = _fit_for(cfg)
    slots, max_seq, ps = len(lens), 32, 8
    pt = PageTable(num_slots=slots, max_seq=max_seq, page_size=ps)
    kv = m.init_paged_cache(slots, max_seq, ps, pt.allocator.num_pages,
                            codebook=cb)
    assert kv["k"].dtype == jnp.uint8 and CODEBOOK_KEY in kv
    for slot, n in enumerate(lens):
        pt.ensure(slot, n + steps + 1)
        toks = jnp.asarray(np.arange(2, 2 + n)[None] % cfg.vocab_size,
                           jnp.int32)
        toks = jnp.pad(toks, ((0, 0), (0, 16 - n)), constant_values=1)
        _, kv = m.prefill_paged(params, toks, kv, pt.device(), slot, 0, n,
                                qc_base)
    impls = ("gather", "ref", "pallas")
    kvs = {i: jax.tree_util.tree_map(lambda t: t, kv) for i in impls}
    qcs = {i: qc_base.replace(flash=i) for i in impls}
    tok = jnp.asarray([[5]] * slots, jnp.int32)
    pos = jnp.asarray(lens, jnp.int32)
    worst = 0.0
    for step in range(steps):
        logits = {}
        for i in impls:
            logits[i], kvs[i] = m.decode_paged(
                params, tok, kvs[i], pt.device(), pos + step, qcs[i])
        for i in impls[1:]:
            assert bool(jnp.all(logits["gather"].argmax(-1)
                                == logits[i].argmax(-1))), (i, step)
            worst = max(worst, float(jnp.max(jnp.abs(
                logits["gather"] - logits[i]))))
        tok = jnp.asarray(logits["gather"].argmax(-1)[:, None], jnp.int32)
    return worst


def test_kvq_chain_parity_dense():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    assert _kvq_chain_parity(cfg, KVQ) < 1e-4


def test_kvq_chain_parity_lut_infer():
    """Both lossy paths stacked: lut_infer weights + vq KV pool."""
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    qc_t = QuantConfig(mode="lut_train")
    m = Model(cfg)
    qc_i = qc_t.replace(mode="lut_infer", kv_quant="vq")
    params = precompute_model(m.init(KEY, qc_t), qc_i)
    assert _kvq_chain_parity(cfg, qc_i, params=params) < 1e-4


def test_kvq_chain_parity_gqa_sliding_window():
    cfg = get_smoke_config("gemma3-27b").replace(attn_impl="naive")
    assert cfg.num_heads > cfg.num_kv_heads and cfg.sliding_window > 0
    assert _kvq_chain_parity(cfg, KVQ) < 1e-4


def test_init_paged_cache_rejects_mismatched_codebook():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    bad = KVCodebook.fit(jnp.ones((cfg.num_layers, 8, 2, 8)),
                         jnp.ones((cfg.num_layers, 8, 2, 8)),
                         v=4, c=4, iters=1)
    with pytest.raises(ValueError):
        m.init_paged_cache(1, 32, 8, 4, codebook=bad)   # head_dim mismatch


# ---------------------------------------------------------------------------
# serving engine with kv_quant="vq"
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


@pytest.fixture(scope="module")
def qwen_cb(qwen):
    """One calibration-fit codebook shared by every engine test (the fit
    is deterministic, but sharing skips re-running it per test)."""
    m, params = qwen
    probe = Engine(m, params, KVQ, batch_size=1, max_seq=32, page_size=8,
                   prefill_chunk=4, prefix_cache=False)
    return probe.kv_codebook


def _mk_engine(m, params, qc=DENSE, slots=2, cb=None, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, qc, batch_size=slots, kv_codebook=cb, **kw)


def test_kvq_engine_read_paths_token_identical(qwen, qwen_cb):
    """One quantized engine per flash impl, identical greedy streams."""
    m, params = qwen
    outs = {}
    for flash in ("gather", "ref", "pallas"):
        reqs = [Request(tokens=[3, 4, 5], max_new_tokens=6),
                Request(tokens=[7, 8], max_new_tokens=4)]
        _mk_engine(m, params, qc=KVQ.replace(flash=flash),
                   cb=qwen_cb).run(reqs)
        assert all(r.done and len(r.out_tokens) == r.max_new_tokens
                   for r in reqs)
        outs[flash] = [r.out_tokens for r in reqs]
    assert outs["gather"] == outs["ref"] == outs["pallas"]


def test_kvq_engine_validation(qwen, qwen_cb):
    m, params = qwen
    with pytest.raises(ValueError, match="kv_quant"):
        _mk_engine(m, params, qc=DENSE, cb=qwen_cb)    # codebook w/o vq


def test_kvq_exact_cover_engine_token_identity(qwen):
    """End-to-end greedy identity under a from_rows exact-cover codebook:
    the fp engine's run is harvested row for row, those rows become the
    centroids, and the QUANTIZED engine must reproduce the fp tokens
    bit-identically (encode-on-write + decode-on-read both active)."""
    m, params = qwen
    prompt, n_new = [2, 3, 5, 7, 11], 8
    qc = DENSE.replace(flash="gather")

    def run(e_qc, cb=None):
        eng = _mk_engine(m, params, qc=e_qc, slots=1, cb=cb,
                         prefix_cache=False)
        req = Request(tokens=list(prompt), max_new_tokens=n_new)
        eng.run([req])
        assert req.done and len(req.out_tokens) == n_new
        return req.out_tokens

    fp_out = run(qc)
    # manual chain on a static table: same tokens, harvestable pool
    p = len(prompt)
    kv = m.init_paged_cache(1, 32, 8, 4)
    table = jnp.arange(4, dtype=jnp.int32).reshape(1, 4)
    logits, kv = m.prefill_paged(params, jnp.asarray([prompt], jnp.int32),
                                 kv, table, 0, 0, p, qc)
    toks = []
    for step in range(n_new):
        nxt = int(jnp.argmax(logits.reshape(-1)))
        toks.append(nxt)
        logits, kv = m.decode_paged(params, jnp.asarray([[nxt]], jnp.int32),
                                    kv, table,
                                    jnp.asarray([p + step], jnp.int32), qc)
    assert toks == fp_out, "manual chain diverged from the engine"
    t_rows = p + n_new - 1                 # every row the run READS
    cfg = m.cfg
    rows = {key: kv[key][:, np.arange(4)].reshape(
        cfg.num_layers, 32, cfg.num_kv_heads, cfg.head_dim)[:, :t_rows]
        for key in ("k", "v")}
    cb = KVCodebook.from_rows(rows["k"], rows["v"])
    assert run(KVQ.replace(flash="gather"), cb) == fp_out


def test_kvq_prefix_warm_cold_parity(qwen, qwen_cb):
    """Warm (prefix-cached) quantized engine == cold quantized engine,
    token for token, on page-aligned SUFFIX matches — the reused codes
    are bitwise the codes the cold run writes for itself, so parity is
    exact even though the pool is lossy. (Full-prompt CoW matches are
    the one warm case that re-runs a prompt token under different
    prefill chunking, where a lossy pool may legitimately drift within
    quantization error — covered by the codes-preservation test below
    and docs/serving.md.)"""
    m, params = qwen
    system = [(3 * j) % 40 + 2 for j in range(16)]      # 2 full pages
    streams, engines = {}, {}
    for tag, warm in (("cold", False), ("warm", True)):
        eng = _mk_engine(m, params, qc=KVQ, cb=qwen_cb, prefix_cache=warm)
        reqs = [Request(tokens=system + [50 + i], max_new_tokens=4)
                for i in range(3)]
        eng.run([reqs[0]])
        for r in reqs[1:]:
            eng.submit(r)
        eng.run_until_idle()
        assert all(r.done and len(r.out_tokens) == r.max_new_tokens
                   for r in reqs)
        streams[tag] = [r.out_tokens for r in reqs]
        engines[tag] = eng
    assert streams["warm"] == streams["cold"]
    assert engines["warm"].prefilled_tokens < engines["cold"].prefilled_tokens
    # the prefix index chains from the codebook fingerprint: the same
    # token chunks hash differently under a different (or no) codebook
    salt = engines["warm"].kv.table.content_salt
    assert salt == qwen_cb.fingerprint() != 0
    assert _chunk_keys(system, 8, salt) != _chunk_keys(system, 8, 0)


def test_kvq_cow_fork_preserves_codes(qwen, qwen_cb):
    """A full-prompt match CoW-forks a CODE page: the fork must leave the
    shared page's codes bitwise untouched, copy them into the private
    page, and later suffix-match requests must still reuse the original
    codes and stay token-identical to before the fork."""
    m, params = qwen
    system = [(3 * j) % 40 + 2 for j in range(16)]      # 2 full pages
    eng = _mk_engine(m, params, qc=KVQ, cb=qwen_cb)
    warm = Request(tokens=system + [50], max_new_tokens=4)
    eng.run([warm])
    salt = eng.kv.table.content_salt
    shared = [eng.kv.table.prefix.lookup(key)
              for key in _chunk_keys(system, 8, salt)]
    assert all(p is not None for p in shared)
    before = {key: np.asarray(eng.kv.data[key][:, shared])
              for key in ("k", "v")}

    fork = Request(tokens=list(system), max_new_tokens=4)
    eng.run([fork])
    assert eng.kv.cow_forks >= 1
    assert fork.done and len(fork.out_tokens) == 4
    for key in ("k", "v"):                 # shared codes bitwise intact
        np.testing.assert_array_equal(
            np.asarray(eng.kv.data[key][:, shared]), before[key])

    again = Request(tokens=system + [50], max_new_tokens=4)
    eng.run([again])                       # suffix reuse still exact
    assert again.out_tokens == warm.out_tokens


def test_kvq_spec_rollback_refcounts_match_mapped_rows(qwen, qwen_cb):
    """Speculative verify/rollback on a quantized pool: after EVERY step
    each physical page's refcount equals the slot rows mapping it, and
    the run completes token-identical to the non-speculative engine."""
    m, params = qwen

    def reqs():
        return [Request(tokens=[3, 4, 5, 6], max_new_tokens=10),
                Request(tokens=[9, 8, 7], max_new_tokens=8)]

    plain = reqs()
    _mk_engine(m, params, qc=KVQ, cb=qwen_cb, max_seq=64,
               prefill_chunk=8).run(plain)
    spec = reqs()
    eng = _mk_engine(m, params, qc=KVQ, cb=qwen_cb, max_seq=64,
                     prefill_chunk=8,
                     spec_decode=SpecConfig(k=3, drafter="ngram"))
    for r in spec:
        eng.submit(r)
    pt = eng.kv.table
    while eng.scheduler.has_work:
        eng.step()
        mapped = Counter(p for row in pt._slot_pages for p in row)
        for pg in range(pt.allocator.num_pages):
            assert pt.allocator.refcount(pg) == mapped.get(pg, 0), \
                f"page {pg}: refcount != mapped rows after rollback"
    assert [r.out_tokens for r in spec] == [r.out_tokens for r in plain]


def test_kvq_chaos_zero_lost(qwen, qwen_cb):
    """The canned chaos schedule over 2 quantized replicas: ZERO lost
    requests, every request COMPLETED with its full token budget.

    (No token-identity clause: crash recovery re-prefills prompt +
    already-emitted tokens on the surviving replica, and a re-prefill
    chunks attention differently than the original decode — exact on an
    fp pool, drift-within-quantization-error on a lossy one; see
    docs/serving.md. The robustness invariant — nothing lost, nothing
    truncated — is what kv_quant must preserve.)"""
    m, params = qwen
    prompts = [[i + 2, i + 3, i + 4] for i in range(6)]
    reqs = [Request(tokens=list(p), max_new_tokens=8) for p in prompts]
    router = ReplicaRouter([_mk_engine(m, params, qc=KVQ, cb=qwen_cb)
                            for _ in range(2)])
    inj = FaultInjector(FaultSchedule.canned(replicas=2)).attach(router)
    for r in reqs:
        router.submit(r)
    router.run_until_idle()
    assert all(r.done for r in reqs)                   # zero lost
    for r in reqs:
        assert r.finish_reason is FinishReason.COMPLETED
        assert len(r.out_tokens) == 8                  # full budget, no dupes
    fired = inj.report()["by_kind"]
    assert fired.get("crash", 0) >= 1 and fired.get("pool_exhaust", 0) >= 1


def test_kvq_admission_accounting_reports_bytes(qwen, qwen_cb):
    """occupancy()/byte properties reflect the uint8 pool: bytes/token
    shrinks >= 4x vs fp, live_bytes tracks live pages, and the MiB
    figures surface in the occupancy string."""
    m, params = qwen
    fp = _mk_engine(m, params, qc=DENSE)
    kvq = _mk_engine(m, params, qc=KVQ, cb=qwen_cb)
    assert fp.kv.bytes_per_token >= 4 * kvq.kv.bytes_per_token
    assert kvq.kv.page_bytes == kvq.kv.bytes_per_token * 8
    assert kvq.kv.pool_bytes == \
        kvq.kv.page_bytes * kvq.kv.table.allocator.num_pages
    assert kvq.kv.live_bytes == 0
    req = Request(tokens=[3, 4, 5], max_new_tokens=4)
    kvq.run([req])
    assert "MiB" in kvq.kv.occupancy()
    assert kvq.kv.table.page_bytes == kvq.kv.page_bytes
