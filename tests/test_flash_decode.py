"""Paged flash-decode kernel: split-KV properties, oracle parity, serving.

Layers of evidence, innermost out:
  1. the split-triple algebra (combine/reduce) is invariant to split
     count and order, matches full softmax, and treats all-masked splits
     as the identity (property tests via hypothesis when available);
  2. the kernel (both the Pallas grid and the XLA "ref" impl) matches
     the full-softmax oracle ``kernels.ref.flash_decode_ref`` AND the
     legacy gather path across GQA/window/kv_start/page-boundary grids;
  3. model-level decode chains (dense, lut_infer, gemma-style
     GQA+sliding-window) are token-identical across impls;
  4. the serving engine under pool exhaustion + preemption produces
     token-identical output on the flash path (pages are never read
     after reclaim).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.core.lut import DENSE, QuantConfig
from repro.kernels.flash_decode import (NEG_INF, combine_splits,
                                        flash_decode_paged,
                                        flash_decode_splits, reduce_splits,
                                        resolve_flash_impl)
from repro.kernels.ref import flash_decode_ref
from repro.models.layers import _sdpa_decode_combine
from repro.models.model import Model
from repro.serve import Engine, PageTable, Request
from repro.serve.faults import Fault, FaultInjector, FaultSchedule

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# split-triple algebra (hypothesis property tests)
# ---------------------------------------------------------------------------

def _chunk_triples(s, v, mask, bounds):
    """Per-split (m, l, acc) triples for a 1-D masked softmax problem."""
    triples = []
    for lo, hi in bounds:
        sc, mc, vc = s[lo:hi], mask[lo:hi], v[lo:hi]
        m = np.max(np.where(mc, sc, NEG_INF)) if hi > lo else NEG_INF
        p = np.where(mc, np.exp(sc - m), 0.0)
        triples.append((np.float32(m), np.float32(p.sum()),
                        (p[:, None] * vc).sum(0).astype(np.float32)))
    m, l, acc = (np.stack([t[i] for t in triples]) for i in range(3))
    return jnp.asarray(m), jnp.asarray(l), jnp.asarray(acc)


def _partition(n, pieces, rng):
    cuts = np.sort(rng.choice(np.arange(1, n), size=min(pieces - 1, n - 1),
                              replace=False)) if n > 1 and pieces > 1 else []
    bounds, lo = [], 0
    for c in list(cuts) + [n]:
        bounds.append((lo, int(c)))
        lo = int(c)
    return bounds


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 20), n=st.integers(1, 40),
       pa=st.integers(1, 7), pb=st.integers(1, 7))
def test_split_reduction_count_and_order_invariant(seed, n, pa, pb):
    """Reducing per-split triples gives the same answer for any split
    count and any split order, and matches the oracle softmax."""
    rng = np.random.RandomState(seed)
    s = (rng.randn(n) * 3).astype(np.float32)
    v = rng.randn(n, 4).astype(np.float32)
    mask = rng.rand(n) < 0.7                       # some all-masked splits
    outs = []
    for pieces in (pa, pb):
        m, l, acc = _chunk_triples(s, v, mask, _partition(n, pieces, rng))
        perm = rng.permutation(m.shape[0])         # order invariance
        outs.append(reduce_splits(m[perm], l[perm], acc[perm]))
    for a, b in zip(*outs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    m_t, l_t, acc_t = outs[0]
    assert np.isfinite(np.asarray(l_t)) and np.all(
        np.isfinite(np.asarray(acc_t)))            # never NaN
    if mask.any():
        sm = np.where(mask, s, -np.inf)
        p = np.exp(sm - sm.max())
        oracle = (p[:, None] * v).sum(0) / p.sum()
        np.testing.assert_allclose(np.asarray(acc_t) / np.asarray(l_t),
                                   oracle, rtol=1e-4, atol=1e-5)
    else:                                          # identity, not NaN
        assert float(l_t) == 0.0 and float(m_t) == NEG_INF


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 20), n=st.integers(1, 24),
       where=st.integers(0, 8))
def test_all_masked_split_is_identity(seed, n, where):
    """Splicing an all-masked (empty page) split anywhere is a no-op."""
    rng = np.random.RandomState(seed)
    s = (rng.randn(n) * 2).astype(np.float32)
    v = rng.randn(n, 3).astype(np.float32)
    mask = np.ones(n, bool)
    m, l, acc = _chunk_triples(s, v, mask, _partition(n, 3, rng))
    ident = (jnp.full((1,), NEG_INF), jnp.zeros((1,)), jnp.zeros((1, 3)))
    i = where % (m.shape[0] + 1)
    m2 = jnp.concatenate([m[:i], ident[0], m[i:]])
    l2 = jnp.concatenate([l[:i], ident[1], l[i:]])
    acc2 = jnp.concatenate([acc[:i], ident[2], acc[i:]])
    for a, b in zip(reduce_splits(m, l, acc), reduce_splits(m2, l2, acc2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
        assert np.all(np.isfinite(np.asarray(b)))


def test_combine_splits_identity_and_fold():
    """(NEG_INF, 0, 0) is a two-sided identity and pairwise folding
    equals the vectorised reduction."""
    rng = np.random.RandomState(7)
    s = (rng.randn(20) * 3).astype(np.float32)
    v = rng.randn(20, 5).astype(np.float32)
    mask = rng.rand(20) < 0.6
    m, l, acc = _chunk_triples(s, v, mask, _partition(20, 5, rng))
    ident = (jnp.asarray(NEG_INF, jnp.float32), jnp.asarray(0.0),
             jnp.zeros((5,)))
    folded = ident
    for i in range(m.shape[0]):
        folded = combine_splits(folded, (m[i], l[i], acc[i]))
    folded = combine_splits(folded, ident)         # right identity too
    for a, b in zip(folded, reduce_splits(m, l, acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel-level parity (oracle + gather path)
# ---------------------------------------------------------------------------

def _mk_case(seed, slots, np_, ps, kvh, g, d, positions):
    """Synthetic one-layer pool + page tables honouring the engine
    invariant (pages covering pos+1 tokens allocated, rest trash).
    Physical ids are a permutation — pages are deliberately NOT laid out
    in logical order — and the trash page holds violent garbage so any
    unmasked read is loud."""
    key = jax.random.PRNGKey(seed)
    p1 = slots * np_ + 1
    ks = jax.random.split(key, 5)
    k_pages = jax.random.normal(ks[0], (p1, ps, kvh, d), jnp.float32)
    v_pages = jax.random.normal(ks[1], (p1, ps, kvh, d), jnp.float32)
    k_pages = k_pages.at[-1].set(37.0)
    v_pages = v_pages.at[-1].set(-53.0)
    perm = np.random.RandomState(seed).permutation(p1 - 1)
    phys = np.full((slots, np_), p1 - 1, np.int64)
    for b, pos in enumerate(positions):
        n_alloc = min(-(-(int(pos) + 1) // ps), np_) if pos >= 0 else 0
        phys[b, :n_alloc] = perm[b * np_: b * np_ + n_alloc]
    q = jax.random.normal(ks[2], (slots, 1, kvh * g, d), jnp.float32)
    k_new = jax.random.normal(ks[3], (slots, 1, kvh, d), jnp.float32)
    v_new = jax.random.normal(ks[4], (slots, 1, kvh, d), jnp.float32)
    return (q, k_pages, v_pages, k_new, v_new,
            jnp.asarray(phys, jnp.int32), jnp.asarray(positions, jnp.int32))


def _gather_out(q, k_pages, v_pages, k_new, v_new, phys, pos, window,
                kv_start):
    slots, np_ = phys.shape
    ps, kvh, d = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    view_k = k_pages[phys].reshape(slots, np_ * ps, kvh, d)
    view_v = v_pages[phys].reshape(slots, np_ * ps, kvh, d)
    return _sdpa_decode_combine(q, view_k, view_v, k_new, v_new, pos,
                                window, kv_start=kv_start)


# positions: exactly on a page boundary (16), one past it (17), mid-page
# (9), and an inactive lane (-1).
_POSITIONS = [16, 17, 9, -1]


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("kvh,g", [(2, 1), (2, 3)])         # MHA and GQA
@pytest.mark.parametrize("window,kv_start", [(0, 0), (11, 0), (0, 5),
                                             (11, 5)])
def test_flash_matches_oracle_and_gather(impl, kvh, g, window, kv_start):
    q, kp, vp, kn, vn, phys, pos = _mk_case(
        seed=3, slots=4, np_=4, ps=8, kvh=kvh, g=g, d=16,
        positions=_POSITIONS)
    out = flash_decode_paged(q, kp, vp, kn, vn, phys, pos, window=window,
                             kv_start=kv_start, impl=impl, interpret=True)
    oracle = flash_decode_ref(q, kp, vp, kn, vn, phys, pos, window,
                              kv_start)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)
    gather = _gather_out(q, kp, vp, kn, vn, phys, pos, window, kv_start)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gather),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_flash_single_slot_batch(impl):
    """B=1 decode (the other batch-shape extreme of the grid)."""
    q, kp, vp, kn, vn, phys, pos = _mk_case(
        seed=11, slots=1, np_=4, ps=8, kvh=2, g=2, d=16, positions=[24])
    out = flash_decode_paged(q, kp, vp, kn, vn, phys, pos, impl=impl,
                             interpret=True)
    oracle = flash_decode_ref(q, kp, vp, kn, vn, phys, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("sp", [1, 2, 4])
def test_paged_split_count_invariance(sp):
    """flash_decode_splits reduces to the same triple for every
    page-aligned split size (trash-padding included)."""
    q, kp, vp, kn, vn, phys, pos = _mk_case(
        seed=5, slots=3, np_=4, ps=8, kvh=2, g=2, d=16,
        positions=[16, 31, -1])
    b, _, h, d = q.shape
    qg = q.reshape(b, 2, 2, d) * d ** -0.5
    win = jnp.asarray(0, jnp.int32)
    ks = jnp.zeros((b,), jnp.int32)
    pad = (-phys.shape[1]) % sp
    phys_p = jnp.pad(phys, ((0, 0), (0, pad)),
                     constant_values=kp.shape[0] - 1)
    got = reduce_splits(*flash_decode_splits(qg, kp, vp, phys_p, pos, win,
                                             ks, sp))
    want = reduce_splits(*flash_decode_splits(qg, kp, vp, phys, pos, win,
                                              ks, phys.shape[1]))
    for a, b_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_trash_page_contents_never_attended():
    """Changing what the trash page holds must not change any output —
    the redirection proof for unallocated pages."""
    q, kp, vp, kn, vn, phys, pos = _mk_case(
        seed=9, slots=3, np_=4, ps=8, kvh=2, g=2, d=16,
        positions=[9, 16, -1])
    for impl in ("ref", "pallas"):
        a = flash_decode_paged(q, kp, vp, kn, vn, phys, pos, impl=impl,
                               interpret=True)
        b = flash_decode_paged(q, kp.at[-1].set(-1e4), vp.at[-1].set(1e4),
                               kn, vn, phys, pos, impl=impl, interpret=True)
        live = np.asarray(pos) >= 0
        np.testing.assert_array_equal(np.asarray(a)[live],
                                      np.asarray(b)[live])


def test_resolve_flash_impl():
    assert resolve_flash_impl("auto", on_tpu=True) == "pallas"
    assert resolve_flash_impl("auto", on_tpu=False) == "gather"
    assert resolve_flash_impl("ref") == "ref"
    with pytest.raises(ValueError):
        resolve_flash_impl("nope")


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_flash_8k_context_parity(impl):
    """8k-token heavy: long-context parity at a realistic page count."""
    ps, np_ = 16, 512                                 # 8192 tokens / slot
    q, kp, vp, kn, vn, phys, pos = _mk_case(
        seed=17, slots=2, np_=np_, ps=ps, kvh=2, g=2, d=32,
        positions=[8191, 5000])
    out = flash_decode_paged(q, kp, vp, kn, vn, phys, pos, window=0,
                             impl=impl, interpret=True)
    oracle = flash_decode_ref(q, kp, vp, kn, vn, phys, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# model-level decode chains
# ---------------------------------------------------------------------------

def _chain_parity(cfg, qc_base, params=None, steps=3, lens=(9, 16)):
    """Greedy decode chains must be token-identical across flash impls
    (logits fp32-close); returns the max logits delta seen."""
    m = Model(cfg)
    if params is None:
        params = m.init(KEY, qc_base)
    slots, max_seq, ps = len(lens), 32, 8
    pt = PageTable(num_slots=slots, max_seq=max_seq, page_size=ps)
    kv = m.init_paged_cache(slots, max_seq, ps, pt.allocator.num_pages)
    for slot, n in enumerate(lens):
        pt.ensure(slot, n + steps + 1)
        toks = jnp.asarray(np.arange(2, 2 + n)[None] % cfg.vocab_size,
                           jnp.int32)
        toks = jnp.pad(toks, ((0, 0), (0, 16 - n)), constant_values=1)
        _, kv = m.prefill_paged(params, toks, kv, pt.device(), slot, 0, n,
                                qc_base)
    impls = ("gather", "ref", "pallas")
    kvs = {i: jax.tree_util.tree_map(lambda t: t, kv) for i in impls}
    qcs = {i: qc_base.replace(flash=i) for i in impls}
    tok = jnp.asarray([[5]] * slots, jnp.int32)
    pos = jnp.asarray(lens, jnp.int32)
    worst = 0.0
    for step in range(steps):
        logits = {}
        for i in impls:
            logits[i], kvs[i] = m.decode_paged(
                params, tok, kvs[i], pt.device(), pos + step, qcs[i])
        for i in impls[1:]:
            assert bool(jnp.all(logits["gather"].argmax(-1)
                                == logits[i].argmax(-1))), (i, step)
            worst = max(worst, float(jnp.max(jnp.abs(
                logits["gather"] - logits[i]))))
        tok = jnp.asarray(logits["gather"].argmax(-1)[:, None], jnp.int32)
    return worst


def test_chain_parity_dense():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    assert _chain_parity(cfg, DENSE) < 1e-4


def test_chain_parity_lut_infer():
    from repro.core import precompute_model
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    qc_t = QuantConfig(mode="lut_train")
    m = Model(cfg)
    params = precompute_model(m.init(KEY, qc_t), qc_t.replace(
        mode="lut_infer"))
    assert _chain_parity(cfg, qc_t.replace(mode="lut_infer"),
                         params=params) < 1e-4


def test_chain_parity_gqa_sliding_window():
    """gemma-style config: q-heads > kv-heads AND per-layer sliding
    windows — the GQA tile mapping and window masks together."""
    cfg = get_smoke_config("gemma3-27b").replace(attn_impl="naive")
    assert cfg.num_heads > cfg.num_kv_heads and cfg.sliding_window > 0
    assert _chain_parity(cfg, DENSE) < 1e-4


# ---------------------------------------------------------------------------
# serving engine: exhaustion + preemption (satellite: recovery parity)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


def _mk_engine(m, params, qc=DENSE, slots=2, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, qc, batch_size=slots, **kw)


def test_flash_engine_matches_gather_engine(qwen):
    """Plain mixed-length run: flash-ref engine output == gather engine
    output, request for request."""
    m, params = qwen
    outs = {}
    for flash in ("gather", "ref"):
        reqs = [Request(tokens=[3, 4, 5], max_new_tokens=8),
                Request(tokens=list(range(2, 13)), max_new_tokens=6),
                Request(tokens=[7, 8], max_new_tokens=10)]
        _mk_engine(m, params, qc=DENSE.replace(flash=flash)).run(reqs)
        assert all(r.done for r in reqs)
        outs[flash] = [r.out_tokens for r in reqs]
    assert outs["gather"] == outs["ref"]


def test_flash_engine_pallas_smoke(qwen):
    """The Pallas kernel (interpret mode on CPU) inside the live engine."""
    m, params = qwen
    outs = {}
    for flash in ("gather", "pallas"):
        reqs = [Request(tokens=[3, 4, 5], max_new_tokens=3),
                Request(tokens=[6, 7], max_new_tokens=3)]
        _mk_engine(m, params, qc=DENSE.replace(flash=flash)).run(reqs)
        outs[flash] = [r.out_tokens for r in reqs]
    assert outs["gather"] == outs["pallas"]


def test_flash_engine_exhaustion_preemption_recovery(qwen):
    """PagePoolExhausted + preemption mid-decode on the flash path: an
    undersized pool (preemption pressure) plus an injected pool squeeze
    must still produce token-identical output to the gather path — the
    kernel never reads a reclaimed page."""
    m, params = qwen
    outs = {}
    for flash in ("gather", "ref"):
        reqs = [Request(tokens=[3, 4, 5], max_new_tokens=20),
                Request(tokens=[6, 7, 8], max_new_tokens=20)]
        eng = _mk_engine(m, params, qc=DENSE.replace(flash=flash),
                         num_pages=5)
        inj = FaultInjector(FaultSchedule(
            [Fault(step=4, kind="pool_exhaust", replica=0,
                   duration=3)])).attach(eng)
        eng.run(reqs)
        assert all(r.done and len(r.out_tokens) == 20 for r in reqs)
        assert inj.report()["by_kind"].get("pool_exhaust", 0) >= 1
        outs[flash] = [r.out_tokens for r in reqs]
    assert outs["gather"] == outs["ref"]
