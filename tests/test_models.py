"""Per-architecture smoke tests (reduced configs): forward/train/decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SMOKE_REGISTRY, get_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.models.model import Model

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16):
    if cfg.family == "audio":
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model)),
                "labels": jax.random.randint(
                    KEY, (B, S, cfg.num_codebooks), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        return {"patch_embeds": jax.random.normal(
                    KEY, (B, cfg.num_patches, cfg.d_model)),
                "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


# the heaviest smoke configs (deep grouped scans) run in the slow CI job;
# the default run keeps one representative per family fast
_SLOW_SMOKE = {"gemma3-4b", "gemma3-27b", "zamba2-1.2b", "dbrx-132b"}


@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=pytest.mark.slow) if n in _SLOW_SMOKE
             else n for n in ARCH_NAMES])
def test_smoke_forward_and_train_step(name):
    cfg = SMOKE_REGISTRY[name]()
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    batch = make_batch(cfg)
    logits, aux = m.forward(params, batch, DENSE)
    assert not bool(jnp.any(jnp.isnan(logits)))
    if cfg.family == "audio":
        assert logits.shape[2:] == (cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape[-1] == cfg.vocab_size
    loss, metrics = m.loss(params, batch, DENSE)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: m.loss(p, batch, DENSE)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The full configs carry the exact published numbers."""
    cfg = get_config(name)
    expected = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)
    # family-specific assignment details
    if name == "dbrx-132b":
        assert (cfg.num_experts, cfg.top_k) == (16, 4)
    if name == "deepseek-moe-16b":
        assert (cfg.num_experts, cfg.num_shared_experts, cfg.top_k) \
            == (64, 2, 6)
    if name == "zamba2-1.2b":
        assert cfg.ssm_state == 64 and cfg.family == "hybrid"
    if name == "mamba2-2.7b":
        assert cfg.ssm_state == 128 and cfg.family == "ssm"
    if name.startswith("gemma3"):
        assert cfg.global_every == 6 and cfg.sliding_window == 1024
    if name == "musicgen-large":
        assert cfg.num_codebooks == 4
    if name == "paligemma-3b":
        assert cfg.num_patches == 256


@pytest.mark.parametrize("name", ["gemma3-27b", "zamba2-1.2b",
                                  "deepseek-moe-16b", "musicgen-large",
                                  "paligemma-3b"])
def test_decode_matches_forward(name):
    cfg = SMOKE_REGISTRY[name]().replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    B, S, PRE = 2, 12, 8
    batch = make_batch(cfg, B, S)
    logits_full, _ = m.forward(params, batch, DENSE)
    if cfg.family == "audio":
        pre = {"embeds": batch["embeds"][:, :PRE]}
    elif cfg.family == "vlm":
        pre = {"patch_embeds": batch["patch_embeds"],
               "tokens": batch["tokens"][:, :PRE]}
    else:
        pre = {"tokens": batch["tokens"][:, :PRE]}
    cache = m.init_cache(B, 32)
    lg, cache = m.prefill(params, pre, cache, DENSE)
    off = cfg.num_patches if cfg.family == "vlm" else 0
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, off + PRE - 1]),
                               rtol=3e-4, atol=3e-4)
    for t in range(PRE, S):
        tok = (batch["embeds"][:, t:t + 1] if cfg.family == "audio"
               else batch["tokens"][:, t:t + 1])
        lg, cache = m.decode(params, tok, cache, DENSE)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, off + t]),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize(
    "name", ["qwen1.5-4b",
             pytest.param("dbrx-132b", marks=pytest.mark.slow),
             "mamba2-2.7b"])
def test_lut_mode_train_and_infer(name):
    cfg = SMOKE_REGISTRY[name]().replace(attn_impl="naive")
    m = Model(cfg)
    qc_t = QuantConfig(mode="lut_train", v=4, c=8)
    qc_i = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref")
    params = m.init(KEY, qc_t)
    batch = make_batch(cfg)
    loss, metrics = m.loss(params, batch, qc_t)
    assert bool(jnp.isfinite(loss)) and float(metrics["recon"]) > 0
    pi = precompute_model(params, qc_i)
    lt, _ = m.forward(params, batch, qc_t)
    li, _ = m.forward(pi, batch, qc_i)
    np.testing.assert_allclose(np.asarray(lt), np.asarray(li),
                               rtol=5e-3, atol=5e-3)


def test_param_count_sanity():
    cfg = get_config("gemma3-27b")
    n = cfg.param_count()
    assert 25e9 < n < 32e9, n       # ~27B
    cfg = get_config("dbrx-132b")
    assert 120e9 < cfg.param_count() < 140e9
    assert cfg.active_param_count() < 0.4 * cfg.param_count()  # top-4 of 16
    cfg = get_config("mamba2-2.7b")
    assert 2.0e9 < cfg.param_count() < 3.4e9


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-27b")
    pattern = [cfg.layer_is_global(i) for i in range(12)]
    assert pattern == [False] * 5 + [True] + [False] * 5 + [True]
    assert not cfg.pure_full_attention           # runs long_500k
    assert get_config("qwen1.5-4b").pure_full_attention
    assert not get_config("mamba2-2.7b").pure_full_attention
    assert not get_config("zamba2-1.2b").pure_full_attention
