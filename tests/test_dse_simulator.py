"""DSE analytical models (paper Eqs 1-5, Table I), search, PPA, simulator."""
import math

import pytest
from hypothesis_compat import given, settings, st

from repro.dse.models import (DataflowOrder, LutDlaPoint, compute_model,
                              dataflow_memory, imm_resources, memory_model,
                              parallelism_model)
from repro.dse.ppa import (PPA_TABLE, design_ppa, dpe_cost,
                           efficiency_curves, scale_to_node)
from repro.dse.search import SearchConstraints, co_design_search
from repro.simulator.cycle_sim import (BERT_BASE_LAYERS, LutDlaSim, PqaSim,
                                       RESNET18_LAYERS, simulate_network)

TABLE1_PT = LutDlaPoint(v=4, c=32, bits_lut=8, bits_out=8, tile_n=32)


class TestTableI:
    """Paper Table I (M=512, K=N=768, v=4, c=32): exact cell reproduction
    for the LS / KNM / KMN / MKN rows (int8 psums+LUT entries, T_n=32)."""

    def _row(self, order):
        return dataflow_memory(512, 768, 768, TABLE1_PT, order)

    def test_lut_stationary_total_17_3kb(self):
        r = self._row(DataflowOrder.LS)
        assert r["scratchpad_kb"] == pytest.approx(16.0)
        assert r["indices_kb"] == pytest.approx(0.3125, rel=1e-2)
        assert r["psum_lut_kb"] == pytest.approx(1.0)
        assert r["total_kb"] == pytest.approx(17.3, abs=0.1)

    def test_knm_385kb(self):
        r = self._row(DataflowOrder.KNM)
        assert r["total_kb"] == pytest.approx(385.3, abs=0.2)

    def test_kmn_408kb(self):
        r = self._row(DataflowOrder.KMN)
        assert r["scratchpad_kb"] == pytest.approx(384.0)
        assert r["psum_lut_kb"] == pytest.approx(24.0)

    def test_mkn_scratch(self):
        r = self._row(DataflowOrder.MKN)
        assert r["scratchpad_kb"] == pytest.approx(0.75)

    def test_ls_is_smallest(self):
        totals = {o: self._row(o)["total_kb"] for o in DataflowOrder}
        assert min(totals, key=totals.get) == DataflowOrder.LS
        # >100x smaller than the LUT-resident orders
        assert totals[DataflowOrder.MNK] / totals[DataflowOrder.LS] > 100


class TestAnalyticalModels:
    def test_compute_model_eq1(self):
        pt = LutDlaPoint(v=8, c=16, metric="l2")
        r = compute_model(512, 768, 768, pt)
        assert r["op_sim"] == 2 * 16 * 512 * 768          # alpha·c·M·K
        assert r["op_add"] == 512 * 768 * 96              # M·N·(K/v)
        assert r["speedup_ops"] > 1

    def test_l1_cheaper_than_l2(self):
        l2 = compute_model(512, 768, 768, LutDlaPoint(v=8, c=16, metric="l2"))
        l1 = compute_model(512, 768, 768, LutDlaPoint(v=8, c=16, metric="l1"))
        assert l1["op_sim"] < l2["op_sim"]

    def test_memory_model_eq2(self):
        pt = LutDlaPoint(v=8, c=16, bits_lut=8, bits_out=32)
        r = memory_model(512, 768, 768, pt)
        assert r["mem_lut"] == 768 * 16 * 96 * 8
        assert r["mem_idx"] == 96 * 512 * 4               # ceil(log2 16)=4

    def test_parallelism_model_eq5_bound_shifts(self):
        pt1 = LutDlaPoint(v=4, c=32, n_ccu=1, n_imm=1)
        r1 = parallelism_model(4096, 768, 768, pt1, 683.0)
        assert r1["bound"] == "lut"          # lookup dominates at n_imm=1
        pt2 = LutDlaPoint(v=4, c=32, n_ccu=1, n_imm=64)
        r2 = parallelism_model(4096, 768, 768, pt2, 683.0)
        assert r2["omega"] < r1["omega"]

    def test_imm_resources_table7_exact(self):
        """Paper Table VII SRAM: exact on all three designs."""
        for (v, c, tn, m), sram in [((3, 16, 128, 256), 36.1),
                                    ((4, 16, 256, 256), 72.1),
                                    ((3, 16, 768, 512), 408.2)]:
            r = imm_resources(v=v, c=c, tile_n=tn, m=m)
            assert r["sram_kb"] == pytest.approx(sram, rel=0.01), (v, c, tn)

    def test_design_ppa_reproduces_table8(self):
        """Calibrated PPA model: exact on the paper's three designs."""
        from repro.dse.models import LutDlaPoint as PT
        paper = [(PT(v=3, c=16, tile_n=128, n_imm=6), 0.755, 219.57, 460.8,
                  256),
                 (PT(v=4, c=16, tile_n=256, n_imm=8), 1.701, 314.975, 1228.8,
                  256),
                 (PT(v=3, c=16, tile_n=768, n_imm=6), 3.64, 496.4, 2764.8,
                  512)]
        for pt, area, power, gops, m_rows in paper:
            d = design_ppa(pt, m_rows=m_rows)
            assert d.perf_gops == pytest.approx(gops, rel=1e-3)
            assert d.area_mm2 == pytest.approx(area, rel=0.03), pt
            assert d.power_mw == pytest.approx(power, rel=0.03), pt


class TestSearch:
    def test_search_returns_feasible_point(self):
        best, stats = co_design_search(SearchConstraints())
        assert best is not None
        assert best.area_mm2 <= 4.0 and best.power_mw <= 500.0
        assert stats["total"] > 0
        assert stats["pruned_memory"] + stats["pruned_compute"] > 0

    def test_tighter_area_never_improves_omega(self):
        loose, _ = co_design_search(SearchConstraints(max_area_mm2=4.0))
        tight, _ = co_design_search(SearchConstraints(max_area_mm2=1.0))
        if tight is not None:
            assert tight.omega >= loose.omega - 1e-6

    @settings(max_examples=10, deadline=None)
    @given(area=st.floats(0.5, 8.0), power=st.floats(100.0, 900.0))
    def test_search_respects_constraints(self, area, power):
        best, _ = co_design_search(SearchConstraints(
            max_area_mm2=area, max_power_mw=power))
        if best is not None:
            assert best.area_mm2 <= area + 1e-9
            assert best.power_mw <= power + 1e-9


class TestPPA:
    def test_dpe_cost_ordering(self):
        """Paper Fig 9: chebyshev < l1 < l2 in area and energy."""
        for field in ("area_um2", "energy_pj"):
            l2 = dpe_cost(8, "l2")[field]
            l1 = dpe_cost(8, "l1")[field]
            ch = dpe_cost(8, "chebyshev")[field]
            assert ch < l1 < l2

    def test_dpe_cost_grows_with_v(self):
        a = [dpe_cost(v, "l2")["area_um2"] for v in (2, 4, 8, 16)]
        assert a == sorted(a)

    def test_lut_dla_beats_alu_efficiency(self):
        """Paper Fig 1: LUT-based points beat the int8 ALU on both axes for
        aggressive (v, c)."""
        rows = efficiency_curves()
        alu_int8 = next(r for r in rows if r["name"] == "int8")
        best_lut = max((r for r in rows if r["kind"] == "lut"),
                       key=lambda r: r["ops_per_um2"])
        assert best_lut["ops_per_um2"] > alu_int8["ops_per_um2"]

    def test_paper_designs_efficiency(self):
        """Table VIII: LUT-DLA designs dominate NVDLA in area efficiency."""
        d3 = PPA_TABLE["LUT-DLA-3"]
        nv = PPA_TABLE["NVDLA-Large"]
        assert (d3["gops"] / d3["area"]) / (nv["gops"] / nv["area"]) > 1.5

    def test_scale_to_node(self):
        a100 = scale_to_node(PPA_TABLE["A100"], 28)
        assert a100.area_mm2 > PPA_TABLE["A100"]["area"]   # 7nm -> 28nm grows


class TestSimulator:
    def test_calibration_table9(self):
        pt = LutDlaPoint(v=4, c=32, tile_n=128, bits_lut=8)
        r = LutDlaSim(pt).gemm_cycles(512, 768, 768)
        assert r["cycles"] == pytest.approx(4743e3, rel=0.02)
        assert r["onchip_kb"] == pytest.approx(10.5, rel=0.1)
        rp = PqaSim(pt).gemm_cycles(512, 768, 768)
        assert rp["cycles"] / r["cycles"] == pytest.approx(1.66, rel=0.15)
        assert rp["onchip_kb"] > 100 * r["onchip_kb"]

    def test_ls_hides_loads_at_adequate_bandwidth(self):
        pt = LutDlaPoint(v=4, c=32, tile_n=128)
        r = LutDlaSim(pt, bw_gbs=25.6).gemm_cycles(512, 768, 768)
        assert r["stall_cycles"] == 0.0
        r_slow = LutDlaSim(pt, bw_gbs=0.05).gemm_cycles(512, 768, 768)
        assert r_slow["stall_cycles"] > 0

    def test_network_sims_run(self):
        pt = LutDlaPoint(v=4, c=16, tile_n=128, n_imm=4)
        for layers in (RESNET18_LAYERS, BERT_BASE_LAYERS):
            r = simulate_network(layers, pt)
            assert r["time_s"] > 0 and r["gops"] > 0
