"""Distributed tests (subprocess with N host devices): sharded train/serve,
pipeline parallelism, hlo_cost collective accounting, dry-run cell."""
import pytest

from conftest import run_in_devices


@pytest.mark.slow
def test_sharded_train_step_all_families():
    out = run_in_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import SMOKE_REGISTRY
from repro.models.model import Model
from repro.core.lut import QuantConfig
from repro.parallel.sharding import param_pspecs, batch_pspecs
from repro.train.trainer import TrainConfig, make_train_step, init_opt_state
from repro.data import SyntheticDataset
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2), ("data", "model"))
shard = lambda t: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), t, is_leaf=lambda s: isinstance(s, P))
qc = QuantConfig(mode="lut_train", v=4, c=8, impl="ref")
for name in ["qwen1.5-4b", "dbrx-132b", "mamba2-2.7b", "zamba2-1.2b"]:
    cfg = SMOKE_REGISTRY[name]()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0), qc)
    pspec = param_pspecs(params, cfg, model_axis_size=2)
    params = jax.device_put(params, shard(pspec))
    ds = SyntheticDataset(cfg, global_batch=4, seq_len=16)
    tc = TrainConfig()
    opt = init_opt_state(params, tc)
    step = jax.jit(make_train_step(m, qc, tc),
        in_shardings=(shard(pspec),
                      shard({"adam": {"m": pspec, "v": pspec, "count": P()}}),
                      shard(batch_pspecs(cfg, ("data",))),
                      NamedSharding(mesh, P())))
    p2, o2, met = step(params, opt, ds.batch(0), jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(met["loss"])), name
    print(name, "OK", float(met["loss"]))
""")
    assert out.count("OK") == 4


@pytest.mark.slow
def test_sharded_serve_batched_and_sp():
    out = run_in_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import SMOKE_REGISTRY
from repro.models.model import Model
from repro.core.lut import QuantConfig
from repro.core import precompute_model
from repro.parallel.sharding import param_pspecs, cache_pspecs
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2), ("data", "model"))
shard = lambda t: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), t, is_leaf=lambda s: isinstance(s, P))
qc = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref", lut_dtype="int8")
for name in ["gemma3-27b", "zamba2-1.2b"]:
    for B in [4, 1]:
        cfg = SMOKE_REGISTRY[name]()
        m = Model(cfg)
        params = precompute_model(m.init(jax.random.PRNGKey(0), qc), qc)
        pspec = param_pspecs(params, cfg, model_axis_size=2)
        params = jax.device_put(params, shard(pspec))
        cspec = cache_pspecs(cfg, B, mesh, ("data",))
        cache = jax.device_put(m.init_cache(B, 32), shard(cspec))
        batch = {"tokens": jnp.ones((B, 8), jnp.int32)}
        lg, cache = jax.jit(lambda p, b, c: m.prefill(p, b, c, qc),
                            in_shardings=(shard(pspec), None, shard(cspec)),
                            out_shardings=(None, shard(cspec)))(params, batch, cache)
        lg, cache = jax.jit(lambda p, t, c: m.decode(p, t, c, qc),
                            in_shardings=(shard(pspec), None, shard(cspec)),
                            out_shardings=(None, shard(cspec)))(params, jnp.ones((B,1), jnp.int32), cache)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
        print(name, B, "OK")
""")
    assert out.count("OK") == 4


def test_pipeline_parallelism_matches_sequential():
    out = run_in_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import run_pipeline
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4,), ("stage",))
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (4, 32, 32)) / 32**0.5
block = lambda w, x: jax.nn.gelu(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
ref = x
for i in range(4):
    ref = block(ws[i], ref)
out = run_pipeline(mesh, block, ws, x, n_micro=8)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("PIPE OK")
""")
    assert "PIPE OK" in out


def test_hlo_cost_counts_loop_collectives():
    out = run_in_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import module_cost
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4,), ("model",))
def g(x, ws):
    def body(c, w): return jnp.tanh(c @ w), None
    return jax.lax.scan(body, x, ws)[0]
X = jax.ShapeDtypeStruct((128, 512), jnp.float32)
WS = jax.ShapeDtypeStruct((6, 512, 512), jnp.float32)
c = jax.jit(g, in_shardings=(NamedSharding(mesh, P(None, "model")),
                             NamedSharding(mesh, P(None, "model", None))),
            out_shardings=NamedSharding(mesh, P(None, "model"))
            ).lower(X, WS).compile()
cost = module_cost(c.as_text())
# 6 all-reduces of 128x512 f32 = 1.572 MB total; flops = 6 sharded matmuls
assert abs(cost.coll["all-reduce"] - 6*128*512*4) < 1e-6, cost.coll
assert cost.coll_count == 6
assert abs(cost.flops - 6*2*128*128*512) / (6*2*128*128*512) < 0.01
print("HLOCOST OK")
""")
    assert "HLOCOST OK" in out


@pytest.mark.slow
def test_dryrun_single_cell_production_mesh():
    """One full-size cell on the 16x16 production mesh (the real thing)."""
    out = run_in_devices("""
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh
mesh = make_production_mesh()
res = run_cell("yi-9b", "decode_32k", mesh, "lut", verbose=False)
assert res["status"] == "ok", res
assert res["roofline"]["flops_per_device"] > 0
assert res["roofline"]["bottleneck"] in ("compute", "memory", "collective")
print("CELL OK", res["roofline"]["bottleneck"])
""", n_devices=512, timeout=900)
    assert "CELL OK" in out
