"""Tests for the `repro.analysis` passes (ISSUE 8).

Covers the AST lint rules on synthetic packages (seeded violations,
waivers, key stability), the jaxpr invariant checks on seeded jaxprs
(gather budget, f64, transfer, donation), the committed-baseline
workflow, and the `scripts/analyze.py` CLI exit codes the CI gate
relies on."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (Finding, check_donation, check_invariants,
                            diff_baseline, load_baseline, run_ast_lint,
                            run_jaxpr_checks, save_baseline)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mk_pkg(tmp_path, source, name="mod"):
    """Write a one-module `repro` package under tmp and return its src
    root (what run_ast_lint / analyze.py --src take)."""
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True, exist_ok=True)
    (pkg / "__init__.py").write_text("")
    (pkg / f"{name}.py").write_text(textwrap.dedent(source))
    return str(tmp_path / "src")


def _gating(findings):
    return [f for f in findings if f.severity != "info"]


# ---------------------------------------------------------------------------
# AST lint rules
# ---------------------------------------------------------------------------

def test_item_in_jitted_fn_is_hot_path_error(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """)
    fs = _gating(run_ast_lint(src)[0])
    assert [f.rule for f in fs] == ["host-sync"]
    assert fs[0].severity == "error"
    assert "item" in fs[0].detail


def test_sync_reachable_through_helper_is_flagged(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax
        import numpy as np

        def helper(x):
            return np.asarray(x)

        @jax.jit
        def f(x):
            return helper(x)
    """)
    fs = _gating(run_ast_lint(src)[0])
    assert [f.rule for f in fs] == ["host-sync"]
    assert fs[0].symbol.endswith("helper")


def test_waiver_comment_suppresses(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.item()  # analysis: ok(host-sync)
    """)
    assert _gating(run_ast_lint(src)[0]) == []


def test_host_sync_outside_trace_is_info_only(tmp_path):
    src = _mk_pkg(tmp_path, """
        import numpy as np

        def host_fn(x):
            return np.asarray(x)
    """)
    fs, _ = run_ast_lint(src)
    assert [f.rule for f in fs] == ["sync-site"]
    assert fs[0].severity == "info"


def test_host_rng_and_time_under_trace(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax
        import random
        import time

        @jax.jit
        def f(x):
            return x * random.random() + time.time()
    """)
    fs = _gating(run_ast_lint(src)[0])
    assert sorted(f.rule for f in fs) == ["host-rng-under-trace"] * 2


def test_jax_random_is_not_host_rng(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax

        @jax.jit
        def f(key, x):
            return x + jax.random.normal(key, x.shape)
    """)
    assert _gating(run_ast_lint(src)[0]) == []


def test_mutable_default_error_and_call_default_warn(tmp_path):
    src = _mk_pkg(tmp_path, """
        def f(x, acc=[]):
            acc.append(x)
            return acc

        def g(x, policy=dict()):
            return policy
    """)
    fs = _gating(run_ast_lint(src)[0])
    assert {f.rule for f in fs} == {"mutable-default"}
    assert sorted(f.severity for f in fs) == ["error", "warn"]


def test_allocator_free_flagged_decref_ok(tmp_path):
    src = _mk_pkg(tmp_path, """
        def release(table, page):
            table.allocator.free(page)

        def release_ok(table, page):
            table.allocator.decref(page)
    """)
    fs = _gating(run_ast_lint(src)[0])
    assert [f.rule for f in fs] == ["allocator-free"]
    assert fs[0].symbol.endswith("release")


def test_jit_static_args_literal_call(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax

        def run(x):
            f = jax.jit(lambda v, mode: v)
            return f(x, "fast")
    """)
    fs = _gating(run_ast_lint(src)[0])
    assert [f.rule for f in fs] == ["jit-static-args"]


def test_finding_keys_stable_across_line_churn(tmp_path):
    body = """
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """
    keys1 = {f.key for f in _gating(run_ast_lint(_mk_pkg(tmp_path, body))[0])}
    churned = "\n\n\n# a comment\n" + textwrap.dedent(body)
    (tmp_path / "src" / "repro" / "mod.py").write_text(churned)
    keys2 = {f.key for f in _gating(run_ast_lint(str(tmp_path / "src"))[0])}
    assert keys1 == keys2


# ---------------------------------------------------------------------------
# baseline workflow
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_and_diff(tmp_path):
    old = Finding("step-sync", "a.py", 3, "Engine.step", "np.asarray#0",
                  "m", "warn")
    gone = Finding("step-sync", "b.py", 9, "old_fn", "item#0", "m", "warn")
    path = str(tmp_path / "baseline.json")
    save_baseline(path, [old, gone])
    bl = load_baseline(path)
    assert set(bl) == {old.key, gone.key}
    fresh = Finding("host-sync", "c.py", 1, "f", "item#0", "m", "error")
    new, grand, fixed = diff_baseline([old, fresh], bl)
    assert [f.key for f in new] == [fresh.key]
    assert [f.key for f in grand] == [old.key]
    assert fixed == [gone.key]


def test_baseline_missing_file_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "nope.json")) == {}


def test_baseline_version_mismatch_raises(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 999, "findings": {}}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(str(p))


def test_info_findings_never_baselined(tmp_path):
    info = Finding("sync-site", "a.py", 1, "f", "np.asarray#0", "m", "info")
    path = str(tmp_path / "b.json")
    save_baseline(path, [info])
    assert load_baseline(path) == {}


# ---------------------------------------------------------------------------
# jaxpr invariants (seeded violations)
# ---------------------------------------------------------------------------

def test_seeded_gather_over_budget():
    def f(x, idx):
        return x[idx] + x[idx * 2]           # two gathers

    closed = jax.make_jaxpr(f)(jnp.ones((8, 4)), jnp.asarray([1, 2]))
    fs = check_invariants(closed, name="fixture", gather_budget=1)
    assert [f.rule for f in fs] == ["jaxpr-gather-budget"]
    assert check_invariants(closed, name="fixture", gather_budget=2) == []


def test_seeded_f64_detected():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(jnp.ones((4,)))
    fs = check_invariants(closed, name="fixture")
    assert "jaxpr-f64" in {f.rule for f in fs}


def test_seeded_transfer_detected():
    closed = jax.make_jaxpr(
        lambda x: jax.device_put(x) + 1.0)(jnp.ones((4,)))
    fs = check_invariants(closed, name="fixture")
    assert "jaxpr-transfer" in {f.rule for f in fs}


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donation_drop_detected():
    # output shape differs from the donated buffer: XLA cannot alias
    bad = jax.jit(lambda x: x[:2], donate_argnums=(0,))
    fs = check_donation(bad, (jnp.ones((4,)),), name="fix", min_aliases=1)
    assert [f.rule for f in fs] == ["jaxpr-donation"]
    good = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    assert check_donation(good, (jnp.ones((4,)),), name="fix",
                          min_aliases=1) == []


def test_registered_entry_points_clean():
    """The real serving/kernel entry points satisfy every invariant —
    budgets in jaxpr_check's docstring, donation of the KV pool."""
    assert run_jaxpr_checks() == []


# ---------------------------------------------------------------------------
# repo tree + CLI gate
# ---------------------------------------------------------------------------

def test_repo_tree_has_no_new_findings():
    findings, graph = run_ast_lint(os.path.join(ROOT, "src"))
    assert not [f for f in findings if f.severity == "error"]
    baseline = load_baseline(os.path.join(ROOT, "analysis/baseline.json"))
    new, _grand, _fixed = diff_baseline(findings, baseline)
    assert new == []
    # the serving entry points must actually be in the traced set —
    # an import-graph regression would silently blind the linter
    assert any(q.endswith("Model.decode_paged") for q in graph.traced)
    assert any(q.endswith("Engine._decode_step") for q in graph.step_loop)


def _run_cli(args, cwd=ROOT):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts/analyze.py"),
         "--no-jaxpr", *args],
        capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_exit_zero_on_committed_baseline():
    r = _run_cli(["--baseline", os.path.join(ROOT,
                                             "analysis/baseline.json")])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_cli_exit_nonzero_on_seeded_violation(tmp_path):
    src = _mk_pkg(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """)
    r = _run_cli(["--src", src,
                  "--baseline", str(tmp_path / "empty.json")])
    assert r.returncode == 1, r.stdout + r.stderr
    assert "NEW finding" in r.stdout
    assert "host-sync" in r.stdout
