import os
import subprocess
import sys

import jax
import pytest

# Tests run on the default 1-device CPU backend. Distributed tests spawn
# subprocesses with XLA_FLAGS set (never set globally here — see dryrun.py).

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def run_in_devices(code: str, n_devices: int = 4, timeout: int = 600):
    """Run a python snippet in a subprocess with N host CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout
