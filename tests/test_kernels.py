"""Pallas kernel tests: shape/dtype sweeps + allclose vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.assign import vq_assign_pallas
from repro.kernels.lut_gemm import lut_gemm_pallas
from repro.kernels.ops import lut_matmul, vq_assign

METRICS = ["l2", "l1", "chebyshev"]


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("m,nc,v,c", [
    (32, 8, 4, 16), (64, 12, 8, 8), (128, 4, 16, 32), (16, 3, 5, 7),
])
def test_assign_kernel_matches_ref(metric, m, nc, v, c):
    key = jax.random.PRNGKey(m * nc + v)
    x = jax.random.normal(key, (m, nc, v))
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v))
    i_ref = ref.assign_ref(x, z, metric)
    i_pl = vq_assign_pallas(x, z, metric, block_m=16, block_k=4,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_pl))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_assign_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (24, 6, 8)).astype(dtype)
    z = jax.random.normal(jax.random.fold_in(key, 1), (6, 16, 8)).astype(dtype)
    i_pl = vq_assign_pallas(x, z, "l2", interpret=True)
    i_ref = ref.assign_ref(x.astype(jnp.float32), z.astype(jnp.float32), "l2")
    # bf16 may flip ties/near-ties: allow tiny disagreement rate
    agree = np.mean(np.asarray(i_pl) == np.asarray(i_ref))
    assert agree > 0.97, agree


@pytest.mark.parametrize("m,nc,c,n", [
    (32, 8, 16, 64), (64, 12, 8, 96), (17, 5, 7, 33), (128, 16, 32, 256),
])
def test_lut_gemm_kernel_matches_ref(m, nc, c, n):
    key = jax.random.PRNGKey(m + n)
    idx = jax.random.randint(key, (m, nc), 0, c, jnp.int32)
    lut = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, n))
    o_ref = ref.lut_gemm_ref(idx, lut)
    o_pl = lut_gemm_pallas(idx, lut, block_m=16, block_n=32, block_k=4,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-5, atol=1e-5)
    o_oh = ref.lut_gemm_onehot(idx, lut)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_oh),
                               rtol=1e-5, atol=1e-5)


def test_lut_gemm_int8_scale_path():
    key = jax.random.PRNGKey(2)
    m, nc, c, n = 48, 6, 16, 80
    idx = jax.random.randint(key, (m, nc), 0, c, jnp.int32)
    lut = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, n))
    scale = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n,))) + .05
    lut8 = jnp.clip(jnp.round(lut / scale * 16), -127, 127).astype(jnp.int8)
    o_ref = ref.lut_gemm_onehot(idx, lut8, scale / 16)
    o_pl = lut_gemm_pallas(idx, lut8, scale / 16, block_m=16, block_n=16,
                           block_k=3, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), nc=st.integers(1, 10), c=st.integers(2, 20),
       n=st.integers(1, 70), seed=st.integers(0, 999))
def test_lut_gemm_property_random_shapes(m, nc, c, n, seed):
    key = jax.random.PRNGKey(seed)
    idx = jax.random.randint(key, (m, nc), 0, c, jnp.int32)
    lut = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, n))
    o_ref = ref.lut_gemm_ref(idx, lut)
    o_pl = lut_gemm_pallas(idx, lut, block_m=8, block_n=32, block_k=4,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=2e-5, atol=2e-5)


def test_ops_dispatch_ref_on_cpu():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 4, 4))
    z = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 4))
    idx = vq_assign(x, z, "l2")                 # auto -> ref on CPU
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.asarray(ref.assign_ref(x, z, "l2")))
    lut = jax.random.normal(key, (4, 8, 16))
    out = lut_matmul(idx, lut)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.lut_gemm_ref(idx, lut)),
                               rtol=1e-5, atol=1e-5)


def test_assign_then_lookup_equals_quantized_matmul():
    """System identity: assign+lookup == (quantized activations) @ W."""
    key = jax.random.PRNGKey(1)
    m, k, n, v, c = 32, 24, 40, 4, 8
    nc = k // v
    x = jax.random.normal(key, (m, k))
    z = jax.random.normal(jax.random.fold_in(key, 1), (nc, c, v))
    w = jax.random.normal(jax.random.fold_in(key, 2), (k, n))
    from repro.core.lut import build_lut
    from repro.core.similarity import ste_quantize_subspaces
    lut = build_lut(w, z)
    idx = vq_assign(x.reshape(m, nc, v), z, "l2")
    out_lut = lut_matmul(idx, lut)
    x_hat = ste_quantize_subspaces(x.reshape(m, nc, v), z, "l2")
    out_dense = x_hat.reshape(m, k) @ w
    np.testing.assert_allclose(np.asarray(out_lut), np.asarray(out_dense),
                               rtol=2e-4, atol=2e-4)
