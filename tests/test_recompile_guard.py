"""Recompile guard over the serving hot path (ISSUE 8, slow job).

Asserts the property the engine's static-shape design promises:
steady-state decode, speculative draft/verify rounds, and chunked
prefill each compile EXACTLY once per (entry point, shape class) —
a warmup workload pays every compile, an identically-shaped steady
workload must pay none — and that the `_device_read` funnel keeps
host transfers at one per decode step / at most two per spec round."""
import jax
import pytest

from repro.analysis.recompile import CompileLog, run_recompile_guard
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.models.model import Model
from repro.serve.engine import Engine, Request
from repro.serve.speculative import SpecConfig

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(jax.random.PRNGKey(0), DENSE)


def _mk_engine(m, params, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, DENSE, batch_size=2, **kw)


def _mixed(base):
    """Chunked prefill (6-token prompt over 4-wide chunks), short and
    long greedy decodes, and a temperature slot — one instance of every
    shape class the plain engine can hit."""
    return [Request(tokens=[base, base + 1, base + 2], max_new_tokens=4),
            Request(tokens=[base + 3] * 6, max_new_tokens=3),
            Request(tokens=[base + 4, base + 5], max_new_tokens=2,
                    temperature=0.7)]


def test_plain_engine_one_compile_per_shape_class(qwen):
    m, params = qwen
    eng = _mk_engine(m, params)
    report = run_recompile_guard(
        eng, _mixed(3), _mixed(11),
        # greedy + temperature sampling batches are two pytree classes
        # of the sample jit; verify never runs without spec_decode
        expected_counts={"prefill": 1, "decode": 1, "verify": 0,
                         "sample": 2})
    assert report.ok, "\n".join(f.render() for f in report.findings)
    assert report.steady_events == []
    assert report.warmup_events       # warmup really did the compiling


def test_spec_engine_one_compile_per_shape_class(qwen):
    m, params = qwen

    def reqs(base):
        return [Request(tokens=[base, base + 1, base + 2],
                        max_new_tokens=5),
                Request(tokens=[base + 3, base + 4], max_new_tokens=4)]

    eng = _mk_engine(m, params, spec_decode=SpecConfig(k=3))
    report = run_recompile_guard(
        eng, reqs(3), reqs(9),
        # all-greedy: the probs draft head and rejection sampling never
        # trace; verify + greedy draft compile exactly once
        expected_counts={"prefill": 1, "decode": 0, "verify": 1,
                         "sample": 0, "draft_greedy": 1,
                         "draft_probs": 0})
    assert report.ok, "\n".join(f.render() for f in report.findings)
    assert eng.spec_rounds > 0


def test_decode_step_is_one_device_read(qwen):
    """A greedy request costs exactly one host transfer per emitted
    token: the final prefill chunk's sample plus one per decode step."""
    m, params = qwen
    eng = _mk_engine(m, params)
    eng.run([Request(tokens=[3, 4, 5], max_new_tokens=6)])
    assert eng.device_reads == 6


def test_spec_round_is_at_most_two_device_reads(qwen):
    """One batched propose fetch + one batched verify fetch per round
    (all-greedy: the verify fetch is argmax ids only), plus one read per
    request for its final prefill chunk."""
    m, params = qwen
    eng = _mk_engine(m, params, spec_decode=SpecConfig(k=3))
    reqs = [Request(tokens=[3, 4, 5], max_new_tokens=5),
            Request(tokens=[6, 7], max_new_tokens=4)]
    eng.run(reqs)
    assert eng.spec_rounds > 0
    assert eng.device_reads == len(reqs) + 2 * eng.spec_rounds


def test_compile_log_captures_fresh_shapes():
    """CompileLog sees eager-op churn, not just jit retraces."""
    import jax.numpy as jnp
    with CompileLog() as warm:
        (jnp.ones((3, 3)) * 2.0).block_until_ready()
    with CompileLog() as steady:
        (jnp.ones((3, 3)) * 4.0).block_until_ready()   # same shape: cached
    with CompileLog() as churn:
        (jnp.ones((5, 5)) * 2.0).block_until_ready()   # fresh shape
    assert steady.events == []
    assert warm.events or churn.events
