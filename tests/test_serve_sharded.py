"""Sharded serving parity (subprocess with 4 host devices): the
tensor-parallel continuous engine and the DP replica router must be
token-identical to the single-device engine — dense and lut_infer — and
capacity errors / preemption must behave identically per replica."""
import pytest

from conftest import run_in_devices

pytestmark = pytest.mark.slow


def test_sharded_engine_parity_dense_and_lut():
    """2×2 (data, model) mesh: paged prefill logits match the single-device
    forward, and engine token streams match the single-device engine for
    dense and lut_infer operating points."""
    out = run_in_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.serve import Engine, Request

mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
m = Model(cfg)
qc_t = QuantConfig(mode="lut_train", v=4, c=8)
qc_i = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref")
dense_params = m.init(jax.random.PRNGKey(0), DENSE)
lut_params = precompute_model(m.init(jax.random.PRNGKey(0), qc_t), qc_i)

def mk_reqs():
    return [Request(tokens=[i + 2, i + 3, i + 4], max_new_tokens=5 + i)
            for i in range(3)]

for tag, params, qc in [("dense", dense_params, DENSE),
                        ("lut_infer", lut_params, qc_i)]:
    ref, sh = mk_reqs(), mk_reqs()
    kw = dict(batch_size=2, max_seq=32, page_size=8, prefill_chunk=4)
    Engine(m, params, qc, **kw).run(ref)
    eng = Engine(m, params, qc, mesh=mesh, **kw)
    eng.run(sh)
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in sh], tag

    # logits parity through the sharded compiled prefill step itself
    toks = np.zeros((1, 4), np.int32); toks[0] = [3, 4, 5, 6]
    full, _ = m.forward(params, {"tokens": jnp.asarray(toks)}, qc)
    eng.kv.ensure(0, 4)
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    lg, eng.kv.data = eng._jit_prefill(
        eng.params, jnp.asarray(toks), eng.kv.data,
        eng.kv.table_device(eng._table_sharding), i32(0), i32(0), i32(4))
    np.testing.assert_allclose(np.asarray(lg)[0],
                               np.asarray(full)[0, -1],
                               rtol=5e-3, atol=5e-3)
    print(tag, "OK")

# hot sampling under TP: exercises the mesh-sharded temps device_put and
# categorical sampling over mesh-committed logits (token parity with the
# single-device engine is NOT asserted — all-reduce summation order may
# legitimately flip a draw near a probability boundary)
hot = [Request(tokens=[3, 4, 5], max_new_tokens=6, temperature=1.0),
       Request(tokens=[6, 7, 8], max_new_tokens=6)]
eng = Engine(m, dense_params, DENSE, mesh=mesh, batch_size=2, max_seq=32,
             page_size=8, prefill_chunk=4)
eng.run(hot)
assert all(r.done and len(r.out_tokens) == 6 for r in hot)
assert eng.temps_uploads >= 1          # the sharded temps path executed
print("HOT-TP OK")
""")
    assert out.count("OK") == 3


def test_sharded_engine_parity_ssm_and_hybrid():
    """Slot-indexed recurrent state (mamba2) and the hybrid slot-dense
    shared-attn cache shard over the model axis without changing tokens."""
    out = run_in_devices("""
import jax
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.serve import Engine, Request

mesh = make_test_mesh((2, 2), ("data", "model"))
for name in ["mamba2-2.7b", "zamba2-1.2b"]:
    cfg = get_smoke_config(name).replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0), DENSE)
    mk = lambda: [Request(tokens=[3, 4, 5], max_new_tokens=6),
                  Request(tokens=list(range(2, 12)), max_new_tokens=4)]
    ref, sh = mk(), mk()
    kw = dict(batch_size=2, max_seq=32, page_size=8, prefill_chunk=4)
    Engine(m, params, DENSE, **kw).run(ref)
    Engine(m, params, DENSE, mesh=mesh, **kw).run(sh)
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in sh], name
    print(name, "OK")
""")
    assert out.count("OK") == 2


def test_sharded_capacity_errors_and_preemption_parity():
    """PagePoolExhausted and recompute-preemption must behave identically
    on the sharded engine and on every router replica."""
    out = run_in_devices("""
import jax
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.serve import Engine, PagePoolExhausted, ReplicaRouter, Request

mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0), DENSE)
kw = dict(batch_size=2, max_seq=32, page_size=8, prefill_chunk=4)

# oversized request refused at submit, sharded and routed alike
eng = Engine(m, params, DENSE, mesh=mesh, **kw)
try:
    eng.submit(Request(tokens=list(range(40)), max_new_tokens=2))
    raise SystemExit("sharded engine accepted an oversized request")
except PagePoolExhausted:
    print("EXHAUST-TP OK")
router = ReplicaRouter.from_mesh(m, params, DENSE, mesh=mesh, **kw)
try:
    router.submit(Request(tokens=list(range(40)), max_new_tokens=2))
    raise SystemExit("router accepted an oversized request")
except PagePoolExhausted:
    print("EXHAUST-DP OK")

# oversubscribed pool (preemption path) under TP == single-device tokens
mk = lambda: [Request(tokens=[3, 4, 5], max_new_tokens=20),
              Request(tokens=[6, 7, 8], max_new_tokens=20)]
ref, sh = mk(), mk()
Engine(m, params, DENSE, num_pages=5, **kw).run(ref)
Engine(m, params, DENSE, num_pages=5, mesh=mesh, **kw).run(sh)
assert all(r.done and len(r.out_tokens) == 20 for r in sh)
assert [r.out_tokens for r in ref] == [r.out_tokens for r in sh]
print("PREEMPT-TP OK")
""")
    assert out.count("OK") == 3


def test_replica_router_tp_dp_from_one_mesh():
    """from_mesh carves (2, 2) into 2 replicas × TP-2; routed greedy
    outputs match solo runs and both replicas receive work."""
    out = run_in_devices("""
import jax
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.serve import Engine, ReplicaRouter, Request

mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0), DENSE)
kw = dict(batch_size=1, max_seq=32, page_size=8, prefill_chunk=4)
solo = [Request(tokens=[i + 2, i + 3], max_new_tokens=5) for i in range(4)]
for r in solo:
    Engine(m, params, DENSE, **kw).run([r])
router = ReplicaRouter.from_mesh(m, params, DENSE, mesh=mesh, **kw)
assert len(router.engines) == 2
routed = [Request(tokens=[i + 2, i + 3], max_new_tokens=5) for i in range(4)]
served = {id(router.submit(r)) for r in routed}
assert len(served) == 2          # least-loaded dispatch used both replicas
router.run_until_idle()
assert all(a.out_tokens == b.out_tokens for a, b in zip(solo, routed))
print("ROUTER OK")
""")
    assert "ROUTER OK" in out
