"""Shared-prefix KV reuse: refcount invariants, CoW, parity, routing."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.models.model import Model
from repro.serve import (Engine, PageAllocator, PagePoolExhausted, PageTable,
                         ReplicaRouter, Request)

KEY = jax.random.PRNGKey(0)

# a 16-token "system prompt" shared across requests (2 pages at page_size 8)
SYS = [(3 * j) % 40 + 2 for j in range(16)]


# ---------------------------------------------------------------------------
# allocator refcount invariants (host-side, no device compute)
# ---------------------------------------------------------------------------

def test_allocator_double_free_rejected():
    a = PageAllocator(4)
    (p,) = a.alloc(1)
    a.free([p])
    with pytest.raises(ValueError, match="double-free"):
        a.free([p])
    assert a.available == 4          # the failed free corrupted nothing


def test_decref_to_zero_frees_exactly_once():
    a = PageAllocator(4)
    (p,) = a.alloc(1)
    assert a.refcount(p) == 1
    a.incref(p)                      # shared by a second slot
    assert a.refcount(p) == 2
    a.free([p])                      # first holder evicts
    assert a.refcount(p) == 1
    assert a.available == 3          # still referenced: NOT freed
    a.free([p])                      # last holder evicts
    assert a.refcount(p) == 0
    assert a.available == 4          # freed exactly once, exactly now
    with pytest.raises(ValueError):
        a.incref(p)                  # refcount-0 pages cannot be increfed
    with pytest.raises(ValueError):
        a.decref(p)


def test_revive_and_restore_guard_refcounts():
    a = PageAllocator(2)
    (p,) = a.alloc(1)
    with pytest.raises(ValueError):
        a.revive(p)                  # live page: revive is invalid
    with pytest.raises(ValueError):
        a.restore(p)                 # live page: restore is invalid
    a.decref(p)                      # parked (caller kept it off the list)
    a.revive(p)
    assert a.refcount(p) == 1


# ---------------------------------------------------------------------------
# page-table prefix index (host-side)
# ---------------------------------------------------------------------------

def test_register_match_park_and_lru_reclaim():
    pt = PageTable(num_slots=2, max_seq=32, page_size=8, num_pages=4)
    pt.ensure(0, 16)                       # 2 pages
    pt.register_prefix(0, SYS, 16)
    assert pt.cached_pages == 2
    # longer prompt sharing the 2-page prefix: both pages match
    m = pt.match_prefix(SYS + [77, 78, 79])
    assert m.tokens == 16 and m.reused_pages == 2 and m.cow_page is None
    pt.release(0)                          # unreferenced but indexed: parked
    assert pt.live_pages == 0
    assert pt.available_pages == 4         # 2 free + 2 reclaimable
    assert pt.allocator.available == 2     # ...but NOT on the free list
    m = pt.match_prefix(SYS + [77])        # parked pages still match
    assert m.tokens == 16
    pt.ensure(1, 32)                       # needs all 4 pages: reclaims LRU
    assert pt.allocator.available == 0 and pt.cached_pages == 0
    assert pt.match_prefix(SYS + [77]).tokens == 0   # index dropped


def test_full_prompt_match_becomes_cow_fork():
    pt = PageTable(num_slots=2, max_seq=32, page_size=8, num_pages=4)
    pt.ensure(0, 16)
    pt.register_prefix(0, SYS, 16)
    m = pt.match_prefix(list(SYS))         # identical prompt, page-aligned
    assert m.tokens == 15                  # last token must run prefill
    assert m.reused_pages == 1 and m.cow_page is not None
    pair = pt.adopt_prefix(1, m)
    assert pair is not None
    src, dst = pair
    assert src == m.cow_page and dst not in (m.pages + [src])
    # slot 1 row: shared page + private fork; donor page still live via slot 0
    assert pt.table[1, 0] == m.pages[0] and pt.table[1, 1] == dst
    assert pt.allocator.refcount(m.pages[0]) == 2
    assert pt.allocator.refcount(src) == 1         # only slot 0 holds it now
    pt.release(1)
    assert pt.allocator.refcount(m.pages[0]) == 1  # shared decref, not free


def test_eviction_never_frees_pages_shared_with_another_slot():
    pt = PageTable(num_slots=2, max_seq=32, page_size=8, num_pages=4)
    pt.ensure(0, 16)
    pt.register_prefix(0, SYS, 16)
    m = pt.match_prefix(SYS + [77, 78])
    pt.adopt_prefix(1, m)                  # slot 1 shares both pages
    pt.ensure(1, 18)                       # + its own tail page
    free_before = pt.allocator.available
    pt.release(0)                          # "preempted" donor evicts
    # the shared pages are still referenced by slot 1: nothing hit the
    # free list, and slot 1's row still points at live pages
    assert pt.allocator.available == free_before
    for lp in range(2):
        assert pt.allocator.refcount(pt.table[1, lp]) == 1
    pt.release(1)                          # now they park (indexed), tail frees
    assert pt.live_pages == 0
    assert pt.allocator.available == free_before + 1   # tail page only:
    assert pt.cached_pages == 2            # the indexed pair parked instead
    assert pt.available_pages == 4         # but counts as capacity


def test_adopt_rolls_back_when_cow_fork_cannot_allocate():
    pt = PageTable(num_slots=3, max_seq=32, page_size=8, num_pages=3)
    pt.ensure(0, 16)
    pt.register_prefix(0, SYS, 16)
    pt.ensure(2, 8)                        # burn the last free page
    m = pt.match_prefix(list(SYS))         # needs 1 fresh page for the fork
    assert m.cow_page is not None
    with pytest.raises(PagePoolExhausted):
        pt.adopt_prefix(1, m)
    assert pt.table[1, 0] == -1            # row rolled back
    assert pt.allocator.refcount(m.pages[0]) == 1    # retain undone


# ---------------------------------------------------------------------------
# engine-level: warm == cold (token-identical), CoW content, stats
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


def _mk_engine(m, params, qc=DENSE, slots=2, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, qc, batch_size=slots, **kw)


def _serve_sequence(eng, reqs):
    """Submit + drain one at a time (so earlier requests warm the cache)."""
    for r in reqs:
        eng.submit(r)
        eng.run_until_idle()
    return reqs


def _shared_prefix_reqs(n=3, new=4):
    return [Request(tokens=SYS + [50 + i], max_new_tokens=new)
            for i in range(n)]


def test_warm_matches_cold_dense(qwen):
    m, params = qwen
    cold = _serve_sequence(_mk_engine(m, params, prefix_cache=False),
                           _shared_prefix_reqs())
    eng = _mk_engine(m, params)
    warm = _serve_sequence(eng, _shared_prefix_reqs())
    for c, w in zip(cold, warm):
        assert w.out_tokens == c.out_tokens
    assert warm[0].cached_tokens == 0          # first request seeds the cache
    assert all(r.cached_tokens == 16 for r in warm[1:])
    assert eng.cached_tokens == 32
    assert eng.prefilled_tokens == eng.prompt_tokens - eng.cached_tokens
    assert 0.5 < eng.prefix_hit_rate < 1.0
    assert eng.kv.live_pages == 0              # everything evicted or parked


def test_warm_matches_cold_lut_infer(qwen):
    m, _ = qwen
    qc_t = QuantConfig(mode="lut_train", v=4, c=8)
    qc_i = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref")
    params = precompute_model(m.init(KEY, qc_t), qc_i)
    cold = _serve_sequence(
        _mk_engine(m, params, qc=qc_i, prefix_cache=False),
        _shared_prefix_reqs(n=2, new=3))
    eng = _mk_engine(m, params, qc=qc_i)
    warm = _serve_sequence(eng, _shared_prefix_reqs(n=2, new=3))
    for c, w in zip(cold, warm):
        assert w.out_tokens == c.out_tokens
    assert warm[1].cached_tokens == 16


def test_cow_fork_preserves_donor_page_contents(qwen):
    """Identical page-aligned prompts: the second request forks the last
    shared page, and the fork must carry the donor's KV rows verbatim."""
    m, params = qwen
    eng = _mk_engine(m, params)
    a = Request(tokens=list(SYS), max_new_tokens=3)
    eng.run([a])
    match = eng.kv.match_prefix(list(SYS))
    assert match.cow_page is not None
    src = match.cow_page
    before = np.asarray(eng.kv.data["k"])[:, src].copy()
    eng.kv.adopt_prefix(1, match)              # slot 1 is free
    dst = int(eng.kv.table.table[1, 1])
    after = np.asarray(eng.kv.data["k"])
    np.testing.assert_array_equal(after[:, dst], before)
    np.testing.assert_array_equal(after[:, src], before)   # donor untouched
    assert eng.kv.cow_forks == 1
    eng.kv.release(1)
    # and end-to-end: the forked path generates the same tokens
    b = Request(tokens=list(SYS), max_new_tokens=3)
    eng.run([b])
    assert b.out_tokens == a.out_tokens
    assert b.cached_tokens == 15               # all but the final token


def test_oversubscribed_shared_prefix_completes_with_parity(qwen):
    """Preemption under pool pressure decrefs shared pages (never a
    double-free) and re-admission may rejoin via the cache — outputs must
    still match solo runs."""
    m, params = qwen
    reqs = [Request(tokens=SYS + [60 + i], max_new_tokens=10)
            for i in range(2)]
    _mk_engine(m, params, num_pages=5).run(reqs)
    for r in reqs:
        assert r.done and len(r.out_tokens) == 10
        solo = Request(tokens=list(r.tokens), max_new_tokens=10)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens


def test_prefix_cache_disabled_knob(qwen):
    m, params = qwen
    eng = _mk_engine(m, params, prefix_cache=False)
    assert eng.kv.table.prefix is None
    _serve_sequence(eng, _shared_prefix_reqs())
    assert eng.cached_tokens == 0 and eng.prefix_hit_rate == 0.0


# ---------------------------------------------------------------------------
# non-paged families must cleanly report zero reusable prefix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mamba2-2.7b", "zamba2-1.2b"])
def test_recurrent_families_bypass_reuse(name):
    cfg = get_smoke_config(name).replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    eng = _mk_engine(m, params)
    assert eng.kv.match_prefix(list(SYS)).tokens == 0
    reqs = _shared_prefix_reqs(n=2, new=4)
    _serve_sequence(eng, reqs)
    assert eng.cached_tokens == 0              # no reuse, no corruption:
    for r in reqs:                             # parity with solo runs
        assert r.cached_tokens == 0
        solo = Request(tokens=list(r.tokens), max_new_tokens=4)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens


# ---------------------------------------------------------------------------
# router prefix affinity
# ---------------------------------------------------------------------------

def test_router_routes_to_replica_with_longest_prefix(qwen):
    m, params = qwen
    router = ReplicaRouter([_mk_engine(m, params), _mk_engine(m, params)])
    warmup = Request(tokens=SYS + [50], max_new_tokens=2)
    assert router.submit(warmup) is router.engines[0]    # load tie: lowest
    router.run_until_idle()
    # replica 0 now caches SYS; make it BUSIER than replica 1, then show
    # affinity overrides least-loaded for a shared-prefix request...
    router.engines[0].submit(Request(tokens=[9, 9], max_new_tokens=2))
    hot = Request(tokens=SYS + [51], max_new_tokens=2)
    assert router.submit(hot) is router.engines[0]
    # ...while a request with no cached prefix falls back to least-loaded
    cold = Request(tokens=[30, 31, 32], max_new_tokens=2)
    assert router.submit(cold) is router.engines[1]
    router.run_until_idle()
    assert hot.cached_tokens == 16
    # affinity is load-bounded: a replica far busier than the least-
    # loaded one loses its hit, so hot shared-prefix traffic spills to
    # idle replicas instead of serializing onto the warm one
    for k in range(router.affinity_load_slack + 1):
        router.engines[0].submit(Request(tokens=[9, 9 + k],
                                         max_new_tokens=2))
    spilled = Request(tokens=SYS + [53], max_new_tokens=2)
    assert router.submit(spilled) is router.engines[1]
    router.run_until_idle()
    assert spilled.cached_tokens == 0          # replica 1 served it cold...
    assert router.engines[1].kv.match_prefix(SYS + [54]).tokens == 16
    # ...and is now warm itself (future hits can land on either replica)
    # affinity off: pure least-loaded dispatch
    plain = ReplicaRouter([_mk_engine(m, params), _mk_engine(m, params)],
                          prefix_affinity=False)
    plain.engines[0].submit(Request(tokens=[9, 9], max_new_tokens=2))
    assert plain.submit(Request(tokens=SYS + [52], max_new_tokens=2)) \
        is plain.engines[1]
