"""Serving engine + end-to-end system test (train → LUTBoost → serve)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.core.lutboost import LutBoostSchedule, convert
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.serve import Engine, Request
from repro.train import TrainConfig, Trainer

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_engine_greedy_matches_manual_decode():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    prompt = [3, 4, 5, 6]
    eng = Engine(m, params, DENSE, batch_size=2, max_seq=64)
    req = Request(tokens=prompt, max_new_tokens=8)
    eng.run([req])
    # manual greedy
    cache = m.init_cache(2, 64)
    toks = np.zeros((2, 4), np.int32)
    toks[0] = prompt
    lg, cache = m.prefill(params, {"tokens": jnp.asarray(toks)}, cache, DENSE)
    outs = []
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(8):
        outs.append(int(nxt[0]))
        lg, cache = m.decode(params, nxt[:, None], cache, DENSE)
        nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    assert req.out_tokens == outs


def test_engine_batching_isolates_requests():
    cfg = get_smoke_config("yi-9b").replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    r_alone = Request(tokens=[7, 8, 9], max_new_tokens=5)
    Engine(m, params, DENSE, batch_size=1, max_seq=64).run([r_alone])
    r_batched = Request(tokens=[7, 8, 9], max_new_tokens=5)
    other = Request(tokens=[1, 2, 3], max_new_tokens=5)
    Engine(m, params, DENSE, batch_size=2, max_seq=64).run(
        [r_batched, other])
    assert r_alone.out_tokens == r_batched.out_tokens


@pytest.mark.slow
def test_engine_per_request_temperature():
    """A greedy (T=0) request must stay deterministic even when batched
    behind a stochastic one (the engine used to apply reqs[0].temperature
    to the whole batch)."""
    cfg = get_smoke_config("yi-9b").replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    r_alone = Request(tokens=[7, 8, 9], max_new_tokens=6)
    Engine(m, params, DENSE, batch_size=1, max_seq=64).run([r_alone])
    hot = Request(tokens=[1, 2, 3], max_new_tokens=6, temperature=2.0)
    r_batched = Request(tokens=[7, 8, 9], max_new_tokens=6)
    Engine(m, params, DENSE, batch_size=2, max_seq=64).run([hot, r_batched])
    assert r_batched.out_tokens == r_alone.out_tokens
    # and the hot request actually sampled: same engine seed, T=0 vs T=2
    hot_greedy = Request(tokens=[1, 2, 3], max_new_tokens=6)
    Engine(m, params, DENSE, batch_size=1, max_seq=64).run([hot_greedy])
    assert len(hot.out_tokens) == 6
    assert hot.out_tokens != hot_greedy.out_tokens


@pytest.mark.slow
def test_end_to_end_lutboost_pipeline():
    """The paper's full workflow: dense train → stage① convert → stage②/③
    fine-tune → precompute LUTs → serve. Accuracy of the LUT model must
    approach the dense model's on the synthetic task."""
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)

    # 1) dense training
    params = m.init(KEY, DENSE)
    tc = TrainConfig(total_steps=120, lr=3e-3, warmup=10, log_every=1000)
    params, _, hist = Trainer(m, ds, DENSE, tc).run(params)
    dense_loss = float(np.mean(hist["loss"][-10:]))

    # 2) LUTBoost stage ①: swap operators + k-means init from calibration
    qc = QuantConfig(mode="lut_train", v=4, c=16, recon_weight=0.05)
    calib = ds.batch(0)
    lut_params = convert(
        lambda p, b: m.forward(p, b, DENSE)[0], params, calib, qc)
    loss_after_convert = float(m.loss(lut_params, ds.batch(1), qc)[0])

    # 3) stages ②+③
    sched = LutBoostSchedule(stage2_steps=30, stage3_steps=60)
    tc2 = TrainConfig(total_steps=90, lr=1e-3, warmup=0, log_every=1000)
    lut_params, _, hist2 = Trainer(m, ds, qc, tc2, lutboost=sched).run(
        lut_params)
    lut_loss = float(np.mean(hist2["loss"][-10:]))
    assert lut_loss < loss_after_convert          # fine-tuning recovers

    # 4) deploy: precompute LUT tables (int8) and serve
    qi = qc.replace(mode="lut_infer", lut_dtype="int8", impl="ref")
    infer_params = precompute_model(lut_params, qi)
    eng = Engine(m, infer_params, qi, batch_size=2, max_seq=96)
    req = Request(tokens=[5, 6, 7, 8], max_new_tokens=6)
    eng.run([req])
    assert len(req.out_tokens) == 6
    # the synthetic task is successor-prediction: a trained model should
    # mostly continue the +1 chain
    hits = sum(1 for a, b in zip([8] + req.out_tokens, req.out_tokens)
               if b == (a + 1) % cfg.vocab_size)
    assert hits >= 3, (req.out_tokens, hits)
