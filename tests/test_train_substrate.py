"""Optimizer, data pipeline, checkpointing, compression, trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.data import SyntheticDataset
from repro.models.model import Model
from repro.train import TrainConfig, Trainer, adamw_init, adamw_update, \
    clip_by_global_norm, cosine_lr
from repro.train.compression import ef_compress
from repro.train.trainer import init_opt_state, make_train_step

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- optimizer
def test_adamw_minimises_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        g = {"x": 2 * (params["x"] - target)}
        params, state = adamw_update(g, state, params, lr=0.05)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_mask_freezes_leaves():
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    state = adamw_init(params)
    g = {"a": jnp.ones(3), "b": jnp.ones(3)}
    mask = {"a": True, "b": False}
    p2, s2 = adamw_update(g, state, params, lr=0.1, mask=mask)
    assert float(jnp.sum(jnp.abs(p2["b"] - params["b"]))) == 0.0
    assert float(jnp.sum(jnp.abs(p2["a"] - params["a"]))) > 0
    assert float(jnp.sum(jnp.abs(s2["m"]["b"]))) == 0.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_cosine_lr_schedule():
    assert float(cosine_lr(0, 1.0, 10, 100)) == pytest.approx(0.0)
    assert float(cosine_lr(10, 1.0, 10, 100)) == pytest.approx(1.0)
    assert float(cosine_lr(100, 1.0, 10, 100)) == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------- data
def test_data_deterministic_and_host_sharded():
    cfg = get_smoke_config("qwen1.5-4b")
    ds = SyntheticDataset(cfg, global_batch=8, seq_len=32, seed=7)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # two hosts see disjoint shards that differ
    h0 = SyntheticDataset(cfg, global_batch=8, seq_len=32, seed=7,
                          num_hosts=2, host_index=0)
    h1 = SyntheticDataset(cfg, global_batch=8, seq_len=32, seed=7,
                          num_hosts=2, host_index=1)
    assert h0.batch(0)["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(h0.batch(0)["tokens"]),
                              np.asarray(h1.batch(0)["tokens"]))


def test_data_structure_is_learnable():
    cfg = get_smoke_config("qwen1.5-4b")
    ds = SyntheticDataset(cfg, global_batch=4, seq_len=64)
    toks = np.asarray(ds.batch(0)["tokens"])
    succ = (toks[:, 1:] == (toks[:, :-1] + 1) % cfg.vocab_size).mean()
    assert 0.8 < succ < 0.98        # ~90% successor structure


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.int32)}}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in [10, 20, 30]:
        mgr.save(step, tree, extra={"step": step})
    assert mgr.all_steps() == [20, 30]          # retention
    restored, step, extra = mgr.restore(tree)
    assert step == 30 and extra["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.ones((8, 8))}
    path = str(tmp_path / "ck")
    save_pytree(path, tree)
    # corrupt the array file
    import numpy as _np
    data = dict(_np.load(os.path.join(path, "arrays.npz")))
    key = list(data)[0]
    data[key] = data[key] + 1.0
    _np.savez(os.path.join(path, "arrays.npz"), **data)
    with pytest.raises(IOError, match="corruption"):
        load_pytree(path, tree)


def test_checkpoint_shape_mismatch_detected(tmp_path):
    path = str(tmp_path / "ck")
    save_pytree(path, {"w": jnp.ones((4, 4))})
    with pytest.raises(ValueError, match="shape mismatch"):
        load_pytree(path, {"w": jnp.ones((2, 2))})


# ---------------------------------------------------------------- compression
def test_error_feedback_removes_bias():
    key = jax.random.PRNGKey(3)
    g = {"w": jax.random.normal(key, (1000,)) * 1e-3}
    ef = None
    acc_comp = jnp.zeros_like(g["w"], dtype=jnp.float32)
    for _ in range(64):
        comp, ef = ef_compress(g, ef)
        acc_comp = acc_comp + comp["w"].astype(jnp.float32)
    acc_true = g["w"] * 64
    # without EF, bf16 rounding bias accumulates; with EF the sums track
    rel = float(jnp.linalg.norm(acc_comp - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel


# ---------------------------------------------------------------- trainer
@pytest.mark.slow
def test_trainer_learns_checkpoints_and_resumes(tmp_path):
    cfg = get_smoke_config("qwen1.5-4b")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    ds = SyntheticDataset(cfg, global_batch=16, seq_len=64)
    tc = TrainConfig(total_steps=60, lr=3e-3, warmup=5, checkpoint_every=20,
                     log_every=1000)
    tr = Trainer(m, ds, DENSE, tc, checkpoint_dir=str(tmp_path))
    p2, o2, hist = tr.run(params)
    assert min(hist["loss"]) < hist["loss"][0] - 0.3          # learns
    # crash-resume: trainer restores step 60 checkpoint and continues
    tr2 = Trainer(m, ds, DENSE,
                  TrainConfig(total_steps=70, lr=3e-3, warmup=5,
                              log_every=1000),
                  checkpoint_dir=str(tmp_path))
    _, _, hist2 = tr2.run(params)
    assert len(hist2["loss"]) <= 12             # only the remaining steps


@pytest.mark.slow
def test_train_step_microbatch_equivalence():
    """Grad accumulation over microbatches == full-batch gradients.

    Compared at the GRADIENT level: Adam's first step is sign-like
    (m/(sqrt(v)+eps) ≈ sign(g)), so comparing post-update params would
    amplify fp32 noise on near-zero gradients into O(lr) differences."""
    cfg = get_smoke_config("qwen1.5-4b")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    ds = SyntheticDataset(cfg, global_batch=8, seq_len=16)
    batch = ds.batch(0)

    g_full = jax.grad(lambda p: m.loss(p, batch, DENSE)[0])(params)
    micro = jax.tree_util.tree_map(
        lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    g_acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    for i in range(4):
        mb = jax.tree_util.tree_map(lambda x: x[i], micro)
        g_i = jax.grad(lambda p: m.loss(p, mb, DENSE)[0])(params)
        g_acc = jax.tree_util.tree_map(lambda a, b: a + b / 4, g_acc, g_i)
    # relative comparison per leaf (norm-scaled)
    for a, b in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        na = float(jnp.linalg.norm(a))
        diff = float(jnp.linalg.norm(a - b))
        assert diff <= 1e-4 * max(na, 1e-3), (diff, na)

    # and the step function's microbatch path runs + returns finite loss
    tc4 = TrainConfig(microbatches=4, lr=1e-3, warmup=0)
    opt = init_opt_state(params, tc4)
    s4 = make_train_step(m, DENSE, tc4)
    _, _, metrics = s4(params, opt, batch, jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
