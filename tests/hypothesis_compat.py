"""Optional-hypothesis shim.

``hypothesis`` is a test-only dependency (declared in pyproject's ``test``
extra) that may be absent in minimal environments. A bare
``pytest.importorskip("hypothesis")`` at module level would skip every test
in the importing module — including the non-property ones — so instead this
shim exposes real ``given``/``settings``/``st`` when hypothesis is
installed, and skip-decorators that disable only the property-based tests
when it is not.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_a, **_kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategy construction at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _StrategyStub()
