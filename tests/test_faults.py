"""Fault tolerance: deadlines, shedding, degradation, chaos harness.

The device tests here are the acceptance gate for the serving stack's
robustness layer: a deterministic chaos schedule (replica crash
mid-decode + forced pool exhaustion + injected step failure) must
complete every non-shed greedy request token-identically to a
fault-free run, and shed overflow must come back as clean
``FinishReason.LOAD_SHED`` results, never exceptions.
"""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.models.model import Model
from repro.serve import (DegradationPolicy, Engine, Fault, FaultInjector,
                         FaultSchedule, FinishReason, MODE_NO_SPEC,
                         MODE_NORMAL, MODE_SHRINK_PREFILL, MODE_STOP_ADMIT,
                         PagePoolExhausted, ReplicaHealth, ReplicaRouter,
                         Request, SlotScheduler)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# host-side: schedule validation, degradation policy, bounded queue
# ---------------------------------------------------------------------------

def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(step=1, kind="meteor")
    with pytest.raises(ValueError, match="step must be >= 1"):
        Fault(step=0, kind="crash")
    with pytest.raises(ValueError, match="duration"):
        Fault(step=1, kind="slow", duration=0)
    f = Fault(step=3, kind="slow", duration=2)
    assert [f.active(c) for c in range(1, 7)] == [
        False, False, True, True, False, False]
    crash = Fault(step=3, kind="crash")
    assert [crash.active(c) for c in (2, 3, 99)] == [False, True, True]


def test_fault_schedule_random_is_deterministic():
    a = FaultSchedule.random(7, replicas=3, n_faults=8)
    b = FaultSchedule.random(7, replicas=3, n_faults=8)
    assert a.faults == b.faults
    assert a.faults != FaultSchedule.random(8, replicas=3, n_faults=8).faults
    # crash budget respected so a fuzzed schedule can't kill every replica
    assert sum(f.kind == "crash" for f in a.faults) <= 1
    assert all(f.replica < 3 for f in a.faults)


def test_degradation_mode_monotone_in_pressure():
    p = DegradationPolicy()
    for current in range(4):
        modes = [p.mode_for(x / 1000.0, current) for x in range(1001)]
        assert all(a <= b for a, b in zip(modes, modes[1:]))
        assert set(modes) <= {0, 1, 2, 3}
    # escalation crosses each rung exactly at its threshold, in order:
    # spec off -> prefill shrink -> admission stop
    assert p.mode_for(0.79, MODE_NORMAL) == MODE_NORMAL
    assert p.mode_for(0.80, MODE_NORMAL) == MODE_NO_SPEC
    assert p.mode_for(0.90, MODE_NORMAL) == MODE_SHRINK_PREFILL
    assert p.mode_for(0.97, MODE_NORMAL) == MODE_STOP_ADMIT


def test_degradation_hysteresis():
    p = DegradationPolicy()     # thresholds .80/.90/.97, hysteresis .10
    # each rung re-enables only once pressure drops `hysteresis` BELOW
    # the threshold that engaged it — no flapping at the boundary
    assert p.mode_for(0.80, MODE_NO_SPEC) == MODE_NO_SPEC
    assert p.mode_for(0.75, MODE_NO_SPEC) == MODE_NO_SPEC
    assert p.mode_for(0.699, MODE_NO_SPEC) == MODE_NORMAL
    assert p.mode_for(0.88, MODE_STOP_ADMIT) == MODE_STOP_ADMIT
    assert p.mode_for(0.869, MODE_STOP_ADMIT) == MODE_SHRINK_PREFILL
    assert p.mode_for(0.5, MODE_STOP_ADMIT) == MODE_NORMAL
    with pytest.raises(ValueError, match="thresholds"):
        DegradationPolicy(spec_off=0.9, chunk_shrink=0.8)


def test_bounded_queue_sheds_lowest_priority_newest_first():
    sched = SlotScheduler(2, max_queue=2)
    reqs = [Request(tokens=[i], priority=pr)
            for i, pr in enumerate([0, 1, 2, 3, 0, 5])]
    victims = [sched.submit(r) for r in reqs]
    # r0/r1 fill the queue; each later submit sheds the lowest-priority
    # (ties: newest) of queue+newcomer — r4 is shed on arrival
    assert victims[:2] == [None, None]
    assert [v is reqs[i] for v, i in zip(victims[2:], (0, 1, 4, 2))] == \
        [True] * 4
    assert [r.shed for r in reqs] == [True, True, True, False, True, False]
    assert all(r.done and r.finish_reason is FinishReason.LOAD_SHED
               for r in reqs if r.shed)
    assert sched.shed_count == 4
    assert [r.tokens for r in sched.waiting] == [[5], [3]]  # priority order


def test_requeue_is_exempt_from_queue_bound():
    sched = SlotScheduler(1, max_queue=1)
    sched.submit(Request(tokens=[1]))
    preempted = Request(tokens=[2])
    sched.requeue(preempted)            # over the bound, but never shed
    assert len(sched.waiting) == 2
    assert sched.waiting[0] is preempted and preempted.retries == 1
    assert sched.shed_count == 0


# ---------------------------------------------------------------------------
# device tests (smoke model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


def _mk_engine(m, params, slots=2, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, DENSE, batch_size=slots, **kw)


def _mk_router(m, params, replicas=2, router_kw=None, **kw):
    return ReplicaRouter([_mk_engine(m, params, **kw)
                          for _ in range(replicas)], **(router_kw or {}))


def test_deadline_evicts_slot_but_keeps_partial_output(qwen):
    m, params = qwen
    eng = _mk_engine(m, params)
    slow = Request(tokens=[2, 3], max_new_tokens=20)
    dl = Request(tokens=[4, 5], max_new_tokens=20, deadline_steps=4)
    eng.submit(slow)
    eng.submit(dl)
    eng.run_until_idle()
    assert dl.done and dl.finish_reason is FinishReason.DEADLINE
    assert 1 <= len(dl.out_tokens) < 20      # partial output survives
    assert slow.done and len(slow.out_tokens) == 20  # neighbour unharmed
    assert eng.scheduler.expired_count == 1
    assert eng.kv.live_pages == 0


def test_deadline_expires_queued_request_without_a_slot(qwen):
    m, params = qwen
    eng = _mk_engine(m, params)
    hogs = [Request(tokens=[i + 2, i + 3], max_new_tokens=12)
            for i in range(2)]
    dl = Request(tokens=[9, 9], max_new_tokens=4, deadline_steps=2)
    for r in hogs + [dl]:
        eng.submit(r)
    eng.run_until_idle()
    assert dl.done and dl.finish_reason is FinishReason.DEADLINE
    assert dl.out_tokens == []
    assert all(len(r.out_tokens) == 12 for r in hogs)


def test_engine_load_shedding_is_a_result_not_an_exception(qwen):
    m, params = qwen
    eng = _mk_engine(m, params, max_queue=1)
    reqs = [Request(tokens=[i + 2, i + 3], max_new_tokens=3, priority=pr)
            for i, pr in enumerate([0, 1, 2])]
    for r in reqs:
        eng.submit(r)                   # burst before any step
    eng.run_until_idle()
    # queue of 1: each overflow sheds the lowest-priority holder, so only
    # the highest-priority request of the burst survives
    assert [r.shed for r in reqs] == [True, True, False]
    assert all(r.finish_reason is FinishReason.LOAD_SHED and
               r.out_tokens == [] for r in reqs[:2])
    assert eng.scheduler.shed_count == 2
    assert reqs[2].finish_reason is FinishReason.COMPLETED
    assert len(reqs[2].out_tokens) == 3


def test_degradation_ladder_under_forced_pool_exhaustion(qwen):
    """A pool squeeze drives pressure to 1.0: the engine must ride the
    ladder up to admission-stop, keep every request alive (preempt, not
    truncate), and come back down to normal once pages free up."""
    m, params = qwen
    eng = _mk_engine(m, params)
    inj = FaultInjector(FaultSchedule(
        [Fault(step=3, kind="pool_exhaust", duration=6)])).attach(eng)
    reqs = [Request(tokens=[i + 2, i + 3], max_new_tokens=10)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    modes = []
    while eng.scheduler.has_work:
        eng.step()
        modes.append(eng.mode)
    assert max(modes) == MODE_STOP_ADMIT     # full ladder engaged
    assert modes[-1] == MODE_NORMAL          # and released after the fault
    assert inj.report()["by_kind"]["pool_exhaust"] >= 1
    assert all(r.finish_reason is FinishReason.COMPLETED and
               len(r.out_tokens) == 10 for r in reqs)
    # the engine spent real steps at the top of the ladder
    assert eng.mode_steps[MODE_STOP_ADMIT] > 0


def test_router_submit_falls_back_when_a_replica_refuses(qwen):
    m, params = qwen
    router = _mk_router(m, params)
    inj = FaultInjector(FaultSchedule(
        [Fault(step=1, kind="submit_error", replica=0,
               duration=5)])).attach(router)
    router.step()                       # advance the fault clock into the window
    req = Request(tokens=[2, 3], max_new_tokens=3)
    eng = router.submit(req)            # replica 0 refuses -> falls through
    assert eng is router.engines[1]
    router.run_until_idle()
    assert req.finish_reason is FinishReason.COMPLETED
    assert inj.report()["by_kind"]["submit_error"] >= 1


def test_router_submit_raises_only_when_every_replica_refuses(qwen):
    m, params = qwen
    router = _mk_router(m, params)
    FaultInjector(FaultSchedule(
        [Fault(step=1, kind="submit_error", replica=0, duration=5),
         Fault(step=1, kind="submit_error", replica=1,
               duration=5)])).attach(router)
    router.step()
    with pytest.raises(PagePoolExhausted, match="injected"):
        router.submit(Request(tokens=[2, 3], max_new_tokens=2))


def test_router_queues_cross_replica_and_sheds_only_when_all_full(qwen):
    m, params = qwen
    router = _mk_router(m, params, max_queue=1)
    reqs = [Request(tokens=[i + 2, i + 3], max_new_tokens=3)
            for i in range(3)]
    assert router.submit(reqs[0]) is router.engines[0]
    # replica 0's queue is full -> queue-room preference routes to 1
    assert router.submit(reqs[1]) is router.engines[1]
    # every queue full -> clean shed (newest, equal priority), no raise
    router.submit(reqs[2])
    assert reqs[2].finish_reason is FinishReason.LOAD_SHED
    router.run_until_idle()
    assert all(len(r.out_tokens) == 3 for r in reqs[:2])


def test_graceful_drain_and_undrain(qwen):
    m, params = qwen
    router = _mk_router(m, params)
    first = [Request(tokens=[i + 2, i + 3], max_new_tokens=6)
             for i in range(2)]
    for r in first:
        router.submit(r)
    router.drain(0)
    assert router.health(0) is ReplicaHealth.DRAINING
    late = [Request(tokens=[i + 7, i + 8], max_new_tokens=4)
            for i in range(2)]
    # a draining replica admits nothing, even as the less-loaded choice
    assert all(router.submit(r) is router.engines[1] for r in late)
    router.run_until_idle()
    assert router.drained(0)            # in-flight work was finished
    assert all(r.done and r.out_tokens for r in first + late)
    router.undrain(0)
    assert router.health(0) is ReplicaHealth.HEALTHY
    assert router.submit(Request(tokens=[2], max_new_tokens=1)) \
        is router.engines[0]
    router.run_until_idle()


def test_stall_watchdog_kills_replica_and_recovers_its_work(qwen):
    m, params = qwen
    baseline = [Request(tokens=[i + 2, i + 3], max_new_tokens=8)
                for i in range(4)]
    _mk_router(m, params).run([Request(tokens=list(r.tokens),
                                       max_new_tokens=8)
                               for r in baseline])  # warm compile only
    fault_free = [Request(tokens=list(r.tokens), max_new_tokens=8)
                  for r in baseline]
    _mk_router(m, params).run(fault_free)

    reqs = [Request(tokens=list(r.tokens), max_new_tokens=8)
            for r in baseline]
    router = _mk_router(m, params,
                        router_kw=dict(stall_steps=4, retry_backoff=1))
    FaultInjector(FaultSchedule(
        [Fault(step=2, kind="slow", replica=0, duration=40)])).attach(router)
    router.run(reqs)
    assert router.status[0].health is ReplicaHealth.DEAD
    assert "stalled" in router.status[0].death_reason
    assert router.status[0].recovered_requests > 0
    assert router.retried_requests > 0
    for got, want in zip(reqs, fault_free):
        assert got.finish_reason is FinishReason.COMPLETED
        assert got.out_tokens == want.out_tokens   # recovery is exact


def test_step_error_degrades_then_recovers_token_identical(qwen):
    m, params = qwen
    fault_free = [Request(tokens=[i + 2, i + 3], max_new_tokens=10)
                  for i in range(2)]
    _mk_router(m, params).run(fault_free)

    reqs = [Request(tokens=list(r.tokens), max_new_tokens=10)
            for r in fault_free]
    router = _mk_router(m, params)
    FaultInjector(FaultSchedule(
        [Fault(step=3, kind="step_error", replica=0)])).attach(router)
    router.run(reqs)
    assert router.status[0].total_failures == 1
    assert router.status[0].health is ReplicaHealth.HEALTHY  # recovered
    for got, want in zip(reqs, fault_free):
        assert got.out_tokens == want.out_tokens


def test_crash_recovery_never_sheds_recovered_requests(qwen):
    """Rescuing a request off a dead replica must bypass the queue bound:
    the cluster already accepted it, so recovery may queue it over the
    limit but never convert it into a LOAD_SHED."""
    m, params = qwen
    fault_free = [Request(tokens=[i + 2, i + 3], max_new_tokens=8)
                  for i in range(4)]
    _mk_router(m, params).run(fault_free)

    reqs = [Request(tokens=list(r.tokens), max_new_tokens=8)
            for r in fault_free]
    router = _mk_router(m, params, max_queue=1)
    FaultInjector(FaultSchedule(
        [Fault(step=4, kind="crash", replica=1)])).attach(router)
    for r in reqs[:2]:
        router.submit(r)
    router.step()                       # into slots, queues empty again
    for r in reqs[2:]:
        router.submit(r)
    router.run_until_idle()
    assert router.status[1].recovered_requests > 0
    assert not any(r.shed for r in reqs)
    for got, want in zip(reqs, fault_free):
        assert got.finish_reason is FinishReason.COMPLETED
        assert got.out_tokens == want.out_tokens


def test_chaos_canned_schedule_token_identity_and_zero_lost(qwen):
    """The acceptance scenario: pool squeeze + one-shot decode failure on
    replica 0, a stall window then a hard crash of replica 1 mid-decode.
    Every request must finish (zero lost), greedy outputs must match the
    fault-free run token for token, and recovery must not duplicate or
    drop tokens across the replica move."""
    m, params = qwen
    prompts = [[i + 2, i + 3, i + 4] for i in range(6)]
    fault_free = [Request(tokens=list(p), max_new_tokens=12)
                  for p in prompts]
    _mk_router(m, params).run(fault_free)

    reqs = [Request(tokens=list(p), max_new_tokens=12) for p in prompts]
    router = _mk_router(m, params)
    inj = FaultInjector(FaultSchedule.canned(replicas=2)).attach(router)
    for r in reqs:
        router.submit(r)
    router.run_until_idle()

    assert all(r.done for r in reqs)                 # zero lost
    assert not any(r.shed for r in reqs)             # unbounded queues
    for got, want in zip(reqs, fault_free):
        assert got.finish_reason is FinishReason.COMPLETED
        assert got.out_tokens == want.out_tokens     # token identity
        assert len(got.out_tokens) == 12             # no duplicated tokens
        assert got.arrival is not None               # stamps preserved
    assert router.status[1].health is ReplicaHealth.DEAD
    assert router.status[1].recovered_requests > 0   # crash recovery ran
    assert any(r.retries > 0 for r in reqs)
    fired = inj.report()["by_kind"]
    assert fired.get("pool_exhaust", 0) >= 1
    assert fired.get("crash", 0) >= 1
    assert fired.get("step_error", 0) >= 1
    assert router.stats()["replicas"][1]["death_reason"]
