"""Attention implementations + Mamba2 SSD correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa, mask_tile
from repro.models.mamba2 import ssd_chunked

KEY = jax.random.PRNGKey(0)


def test_chunked_equals_naive_causal():
    b, s, h, kvh, d = 2, 37, 8, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, d))
    out_n = _sdpa(q, k, v, 0, 0, 0, impl="naive")
    out_c = _sdpa(q, k, v, 0, 0, 0, impl="chunked", chunk=8)
    np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_c),
                               rtol=2e-4, atol=2e-4)


def test_chunked_equals_naive_sliding_window():
    b, s, h, d = 1, 50, 4, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, h, d))
    for window in [4, 16]:
        out_n = _sdpa(q, k, v, 0, window, 0, impl="naive")
        out_c = _sdpa(q, k, v, 0, window, 0, impl="chunked", chunk=16)
        np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_c),
                                   rtol=2e-4, atol=2e-4)


def test_mask_tile_semantics():
    qi = jnp.arange(4) + 10
    kj = jnp.arange(16)
    m = np.asarray(mask_tile(qi, kj, 0, 0))
    assert m[0, 10] and not m[0, 11]          # causal at q_offset
    mw = np.asarray(mask_tile(qi, kj, 4, 0))
    assert mw[0, 10] and not mw[0, 6]          # window of 4: j in (6, 10]
    mp = np.asarray(mask_tile(jnp.arange(4), jnp.arange(16), 0, 3))
    assert mp[0, 2] and mp[1, 2]               # prefix bidirectional
    assert not mp[1, 5]


def test_decode_query_sees_only_past():
    b, h, d, t = 1, 2, 8, 24
    q = jax.random.normal(KEY, (b, 1, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (b, t, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (b, t, h, d))
    pos = 9
    out = _sdpa(q, k, v, pos, 0, 0, impl="naive")
    # zeroing future keys must not change the output
    k2 = k.at[:, pos + 1:].set(99.0)
    v2 = v.at[:, pos + 1:].set(-99.0)
    out2 = _sdpa(q, k2, v2, pos, 0, 0, impl="naive")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5)


def _naive_ssm(x, dt, a_log, bm, cm, D, h0=None):
    B, S, H, P = x.shape
    G, N = bm.shape[2], bm.shape[3]
    A = -jnp.exp(a_log)
    rep = H // G
    bmr = jnp.repeat(bm, rep, axis=2)
    cmr = jnp.repeat(cm, rep, axis=2)
    h = jnp.zeros((B, H, P, N)) if h0 is None else h0
    ys = []
    for t in range(S):
        a = jnp.exp(A[None] * dt[:, t])
        h = a[..., None, None] * h + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], bmr[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, cmr[:, t])
                  + x[:, t] * D[None, :, None])
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("chunk,s", [(8, 32), (8, 37), (16, 16), (4, 50)])
def test_ssd_chunked_matches_recurrence(chunk, s):
    B, H, P, G, N = 2, 4, 8, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    a_log = jnp.log(jnp.linspace(0.5, 4.0, H))
    bm = jax.random.normal(ks[2], (B, s, G, N)) * 0.3
    cm = jax.random.normal(ks[3], (B, s, G, N)) * 0.3
    D = jnp.ones((H,)) * 0.5
    h0 = jax.random.normal(ks[4], (B, H, P, N)) * 0.1
    y_ref, h_ref = _naive_ssm(x, dt, a_log, bm, cm, D, h0)
    y_chk, h_chk = ssd_chunked(x, dt, a_log, bm, cm, D, chunk=chunk, h0=h0)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_chk),
                               rtol=3e-4, atol=3e-4)


def test_ssd_state_decay_property():
    """With large dt·|A|, the state forgets h0 (decay → 0)."""
    B, s, H, P, G, N = 1, 8, 2, 4, 1, 4
    x = jnp.zeros((B, s, H, P))
    dt = jnp.full((B, s, H), 50.0)
    a_log = jnp.zeros((H,))                     # A = -1, exp(-50·8) ≈ 0
    bm = jnp.zeros((B, s, G, N))
    cm = jnp.zeros((B, s, G, N))
    D = jnp.zeros((H,))
    h0 = jnp.ones((B, H, P, N)) * 100.0
    _, h_final = ssd_chunked(x, dt, a_log, bm, cm, D, chunk=4, h0=h0)
    assert float(jnp.max(jnp.abs(h_final))) < 1e-6


# ---------------------------------------------------------------------------
# speculative-verify / paged-decode numerics backfill
# ---------------------------------------------------------------------------

def _softmax_rows(q_row, keys, vals, scale):
    """Dense per-query oracle: q_row (d,), keys/vals (n, d) -> (d,)."""
    sc = keys @ q_row * scale
    p = np.exp(sc - sc.max())
    p /= p.sum()
    return p @ vals


def _verify_oracle(q, kc, vc, kn, vn, pos, window):
    """Loop-built ground truth for ``_sdpa_verify`` (live rows only)."""
    b, s, h, d = q.shape
    t, kvh = kc.shape[1], kc.shape[2]
    g = h // kvh
    scale = d ** -0.5
    out = np.zeros((b, s, h * d), np.float32)
    for bi in range(b):
        for ti in range(s):
            q_abs = pos[bi] + ti
            cache_js = [j for j in range(t)
                        if j < pos[bi]
                        and (window <= 0 or j > q_abs - window)]
            new_js = [j for j in range(ti + 1)
                      if window <= 0 or (pos[bi] + j) > q_abs - window]
            for kh in range(kvh):
                keys = np.concatenate(
                    [kc[bi, cache_js, kh], kn[bi, new_js, kh]], 0)
                vals = np.concatenate(
                    [vc[bi, cache_js, kh], vn[bi, new_js, kh]], 0)
                for gi in range(g):
                    hi = kh * g + gi
                    out[bi, ti, hi * d:(hi + 1) * d] = _softmax_rows(
                        q[bi, ti, hi], keys, vals, scale)
    return out


def test_verify_windowed_masks_with_dead_columns():
    """_sdpa_verify with per-slot positions, a sliding window, AND dead
    (trash-redirected) cache columns in the same case: stale rows >= pos
    hold violent garbage that must never leak into live outputs, and an
    inactive (-1) lane rides along."""
    from repro.models.layers import _sdpa_verify
    b, s, h, kvh, d, t = 3, 4, 4, 2, 8, 12
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, d))
    kc = jax.random.normal(ks[1], (b, t, kvh, d))
    vc = jax.random.normal(ks[2], (b, t, kvh, d))
    kn = jax.random.normal(ks[3], (b, s, kvh, d))
    vn = jax.random.normal(ks[4], (b, s, kvh, d))
    pos = np.array([-1, 3, 7])
    window = 5
    # poison every cache row >= pos[b] (stale draft KV / trash columns)
    poison = np.ones((b, t), bool)
    for bi, p_ in enumerate(pos):
        poison[bi, :max(p_, 0)] = False
    kc_a = jnp.where(jnp.asarray(poison)[..., None, None], 1e3, kc)
    vc_a = jnp.where(jnp.asarray(poison)[..., None, None], -1e3, vc)
    kc_b = jnp.where(jnp.asarray(poison)[..., None, None], -2e3, kc)
    vc_b = jnp.where(jnp.asarray(poison)[..., None, None], 3e3, vc)
    out_a = np.asarray(_sdpa_verify(q, kc_a, vc_a, kn, vn,
                                    jnp.asarray(pos), window))
    out_b = np.asarray(_sdpa_verify(q, kc_b, vc_b, kn, vn,
                                    jnp.asarray(pos), window))
    live = pos >= 0
    # dead columns must be invisible: garbage flavour cannot matter
    np.testing.assert_array_equal(out_a[live], out_b[live])
    oracle = _verify_oracle(np.asarray(q), np.asarray(kc), np.asarray(vc),
                            np.asarray(kn), np.asarray(vn), pos, window)
    np.testing.assert_allclose(out_a[live], oracle[live],
                               rtol=2e-4, atol=2e-4)
    # window=0 (global) flavour over the same masks
    out_g = np.asarray(_sdpa_verify(q, kc_a, vc_a, kn, vn,
                                    jnp.asarray(pos), 0))
    oracle_g = _verify_oracle(np.asarray(q), np.asarray(kc),
                              np.asarray(vc), np.asarray(kn),
                              np.asarray(vn), pos, 0)
    np.testing.assert_allclose(out_g[live], oracle_g[live],
                               rtol=2e-4, atol=2e-4)


def test_decode_combine_window_kv_start_dead_columns():
    """_sdpa_decode_combine with per-row positions, window AND kv_start
    in one case, plus poisoned masked rows (before kv_start, beyond pos):
    output must equal the dense oracle and ignore the garbage."""
    from repro.models.layers import _sdpa_decode_combine
    b, h, kvh, d, t = 3, 4, 2, 8, 16
    ks = jax.random.split(jax.random.fold_in(KEY, 42), 5)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, t, kvh, d))
    vc = jax.random.normal(ks[2], (b, t, kvh, d))
    kn = jax.random.normal(ks[3], (b, 1, kvh, d))
    vn = jax.random.normal(ks[4], (b, 1, kvh, d))
    pos = np.array([14, 9, -1])
    window, kv_start = 6, np.array([2, 0, 0])
    live_mask = np.zeros((b, t), bool)
    for bi, p_ in enumerate(pos):
        for j in range(t):
            live_mask[bi, j] = (j < p_ and j >= kv_start[bi]
                                and j > p_ - window)
    kc_p = jnp.where(jnp.asarray(~live_mask)[..., None, None], 5e2, kc)
    vc_p = jnp.where(jnp.asarray(~live_mask)[..., None, None], -5e2, vc)
    out = np.asarray(_sdpa_decode_combine(
        q, kc_p, vc_p, kn, vn, jnp.asarray(pos), window,
        kv_start=jnp.asarray(kv_start)))
    # dense oracle: live cache rows + the always-live self term
    scale = d ** -0.5
    g = h // kvh
    qn, kcn, vcn = np.asarray(q), np.asarray(kc), np.asarray(vc)
    knn, vnn = np.asarray(kn), np.asarray(vn)
    want = np.zeros((b, 1, h * d), np.float32)
    for bi in range(b):
        js = [j for j in range(t) if live_mask[bi, j]]
        for kh in range(kvh):
            keys = np.concatenate([kcn[bi, js, kh], knn[bi, :, kh]], 0)
            vals = np.concatenate([vcn[bi, js, kh], vnn[bi, :, kh]], 0)
            for gi in range(g):
                hi = kh * g + gi
                want[bi, 0, hi * d:(hi + 1) * d] = _softmax_rows(
                    qn[bi, 0, hi], keys, vals, scale)
    liverows = pos >= 0
    np.testing.assert_allclose(out[liverows], want[liverows],
                               rtol=2e-4, atol=2e-4)
    # inactive lane (-1): output is exactly the fresh value row (each kv
    # head's value repeated across its g query heads)
    want_dead = np.repeat(np.asarray(vn)[2, 0][:, None, :], g,
                          axis=1).reshape(-1)
    np.testing.assert_allclose(out[2, 0], want_dead, rtol=1e-5, atol=1e-5)
