"""Attention implementations + Mamba2 SSD correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa, mask_tile
from repro.models.mamba2 import ssd_chunked

KEY = jax.random.PRNGKey(0)


def test_chunked_equals_naive_causal():
    b, s, h, kvh, d = 2, 37, 8, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, d))
    out_n = _sdpa(q, k, v, 0, 0, 0, impl="naive")
    out_c = _sdpa(q, k, v, 0, 0, 0, impl="chunked", chunk=8)
    np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_c),
                               rtol=2e-4, atol=2e-4)


def test_chunked_equals_naive_sliding_window():
    b, s, h, d = 1, 50, 4, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, h, d))
    for window in [4, 16]:
        out_n = _sdpa(q, k, v, 0, window, 0, impl="naive")
        out_c = _sdpa(q, k, v, 0, window, 0, impl="chunked", chunk=16)
        np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_c),
                                   rtol=2e-4, atol=2e-4)


def test_mask_tile_semantics():
    qi = jnp.arange(4) + 10
    kj = jnp.arange(16)
    m = np.asarray(mask_tile(qi, kj, 0, 0))
    assert m[0, 10] and not m[0, 11]          # causal at q_offset
    mw = np.asarray(mask_tile(qi, kj, 4, 0))
    assert mw[0, 10] and not mw[0, 6]          # window of 4: j in (6, 10]
    mp = np.asarray(mask_tile(jnp.arange(4), jnp.arange(16), 0, 3))
    assert mp[0, 2] and mp[1, 2]               # prefix bidirectional
    assert not mp[1, 5]


def test_decode_query_sees_only_past():
    b, h, d, t = 1, 2, 8, 24
    q = jax.random.normal(KEY, (b, 1, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (b, t, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (b, t, h, d))
    pos = 9
    out = _sdpa(q, k, v, pos, 0, 0, impl="naive")
    # zeroing future keys must not change the output
    k2 = k.at[:, pos + 1:].set(99.0)
    v2 = v.at[:, pos + 1:].set(-99.0)
    out2 = _sdpa(q, k2, v2, pos, 0, 0, impl="naive")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5)


def _naive_ssm(x, dt, a_log, bm, cm, D, h0=None):
    B, S, H, P = x.shape
    G, N = bm.shape[2], bm.shape[3]
    A = -jnp.exp(a_log)
    rep = H // G
    bmr = jnp.repeat(bm, rep, axis=2)
    cmr = jnp.repeat(cm, rep, axis=2)
    h = jnp.zeros((B, H, P, N)) if h0 is None else h0
    ys = []
    for t in range(S):
        a = jnp.exp(A[None] * dt[:, t])
        h = a[..., None, None] * h + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], bmr[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, cmr[:, t])
                  + x[:, t] * D[None, :, None])
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("chunk,s", [(8, 32), (8, 37), (16, 16), (4, 50)])
def test_ssd_chunked_matches_recurrence(chunk, s):
    B, H, P, G, N = 2, 4, 8, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    a_log = jnp.log(jnp.linspace(0.5, 4.0, H))
    bm = jax.random.normal(ks[2], (B, s, G, N)) * 0.3
    cm = jax.random.normal(ks[3], (B, s, G, N)) * 0.3
    D = jnp.ones((H,)) * 0.5
    h0 = jax.random.normal(ks[4], (B, H, P, N)) * 0.1
    y_ref, h_ref = _naive_ssm(x, dt, a_log, bm, cm, D, h0)
    y_chk, h_chk = ssd_chunked(x, dt, a_log, bm, cm, D, chunk=chunk, h0=h0)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_chk),
                               rtol=3e-4, atol=3e-4)


def test_ssd_state_decay_property():
    """With large dt·|A|, the state forgets h0 (decay → 0)."""
    B, s, H, P, G, N = 1, 8, 2, 4, 1, 4
    x = jnp.zeros((B, s, H, P))
    dt = jnp.full((B, s, H), 50.0)
    a_log = jnp.zeros((H,))                     # A = -1, exp(-50·8) ≈ 0
    bm = jnp.zeros((B, s, G, N))
    cm = jnp.zeros((B, s, G, N))
    D = jnp.zeros((H,))
    h0 = jnp.ones((B, H, P, N)) * 100.0
    _, h_final = ssd_chunked(x, dt, a_log, bm, cm, D, chunk=4, h0=h0)
    assert float(jnp.max(jnp.abs(h_final))) < 1e-6
