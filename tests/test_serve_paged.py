"""Continuous-batching engine: scheduler, paged cache, parity, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.models.model import Model
from repro.serve import (Engine, PageAllocator, PagePoolExhausted,
                         PagedKVCache, PageTable, ReplicaRouter, Request,
                         SlotScheduler)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# host-side units: allocator + page table (no model, no device compute)
# ---------------------------------------------------------------------------

def test_page_allocator_exhaustion_is_clean():
    a = PageAllocator(3)
    got = a.alloc(2)
    assert len(got) == 2 and a.available == 1
    with pytest.raises(PagePoolExhausted) as ei:
        a.alloc(2)
    assert "2 page(s)" in str(ei.value) and "1 of 3" in str(ei.value)
    assert a.available == 1          # failed alloc took nothing
    a.free(got)
    assert a.available == 3


def test_page_table_grow_release_reuse():
    pt = PageTable(num_slots=2, max_seq=32, page_size=8)   # 4 pages/slot
    pt.ensure(0, 9)                  # 2 pages
    pt.ensure(1, 1)                  # 1 page
    assert pt.live_pages == 3
    assert (pt.table[0, :2] >= 0).all() and pt.table[0, 2] == -1
    dev = np.asarray(pt.device())
    assert dev.shape == (2, 4)
    pt.ensure(0, 9)                  # idempotent
    assert pt.live_pages == 3
    pt.release(0)
    assert pt.live_pages == 1 and (pt.table[0] == -1).all()
    pt.ensure(0, 32)                 # freed pages are reusable
    assert pt.live_pages == 5
    with pytest.raises(PagePoolExhausted):
        pt.ensure(1, 33)             # beyond max_seq


def test_scheduler_admission_is_fifo_and_page_aware():
    cfg = get_smoke_config("qwen1.5-4b")
    m = Model(cfg)
    kv = PagedKVCache(m, num_slots=2, max_seq=32, page_size=8, num_pages=3)
    sched = SlotScheduler(2)
    sched.submit(Request(tokens=list(range(16))))   # 2 pages
    sched.submit(Request(tokens=list(range(8))))    # 1 page
    sched.submit(Request(tokens=list(range(8))))    # must wait
    admitted = sched.admit(kv)
    assert [s.idx for s in admitted] == [0, 1]
    assert kv.live_pages == 3 and len(sched.waiting) == 1
    assert sched.admit(kv) == []                    # pool full -> deferred
    sched.evict(admitted[1], kv)                    # slot frees mid-flight
    again = sched.admit(kv)                         # admitted immediately
    assert [s.idx for s in again] == [1]
    assert len(sched.waiting) == 0


# ---------------------------------------------------------------------------
# engine-level behaviour (smoke model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


def _mk_engine(m, params, qc=DENSE, slots=2, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, qc, batch_size=slots, **kw)


def test_admission_mid_decode_and_isolation(qwen):
    """5 requests through 2 slots with mixed budgets: late requests are
    admitted as earlier ones finish mid-decode, and every request's greedy
    output matches its solo run."""
    m, params = qwen
    budgets = [2, 9, 3, 2, 4]
    reqs = [Request(tokens=[i + 2, i + 3], max_new_tokens=n)
            for i, n in enumerate(budgets)]
    _mk_engine(m, params).run(reqs)
    assert all(r.done and len(r.out_tokens) == r.max_new_tokens
               for r in reqs)
    for i, n in enumerate(budgets):
        solo = Request(tokens=[i + 2, i + 3], max_new_tokens=n)
        _mk_engine(m, params, slots=1).run([solo])
        assert reqs[i].out_tokens == solo.out_tokens


def test_eviction_on_eos_frees_slot(qwen):
    m, params = qwen
    probe = Request(tokens=[5, 6, 7], max_new_tokens=8)
    _mk_engine(m, params).run([probe])
    eos = probe.out_tokens[2]
    req = Request(tokens=[5, 6, 7], max_new_tokens=8)
    eng = _mk_engine(m, params, eos_id=eos)
    eng.run([req])
    stop = probe.out_tokens.index(eos)
    assert req.out_tokens == probe.out_tokens[:stop + 1]
    assert req.done
    assert eng.kv.live_pages == 0            # pages returned on eviction
    assert all(s.free for s in eng.scheduler.slots)


def test_impossible_request_raises_cleanly(qwen):
    m, params = qwen
    eng = _mk_engine(m, params)
    with pytest.raises(PagePoolExhausted) as ei:
        eng.run([Request(tokens=list(range(40)), max_new_tokens=2)])
    assert "max_seq" in str(ei.value)


def test_oversized_request_rejected_at_submit_not_mid_run(qwen):
    """An unservable request is refused at submit() — it must not abort a
    run with valid requests already queued."""
    m, params = qwen
    eng = _mk_engine(m, params)
    good = Request(tokens=[2, 3], max_new_tokens=3)
    eng.submit(good)
    with pytest.raises(PagePoolExhausted):
        eng.submit(Request(tokens=list(range(40)), max_new_tokens=2))
    eng.run_until_idle()
    assert good.done and len(good.out_tokens) == 3


def test_oversubscribed_pool_defers_then_completes(qwen):
    """Pool holds ~1.5 sequences for 2 slots: the engine preempts/defers
    but still completes everything, identical to solo runs."""
    m, params = qwen
    reqs = [Request(tokens=[3, 4, 5], max_new_tokens=20),
            Request(tokens=[6, 7, 8], max_new_tokens=20)]
    _mk_engine(m, params, num_pages=5).run(reqs)
    assert all(r.done and len(r.out_tokens) == 20 for r in reqs)
    for r in reqs:
        solo = Request(tokens=list(r.tokens), max_new_tokens=20)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens


def test_decode_can_preempt_prefilling_neighbour(qwen):
    """Exhaustion while the only other occupied slot is still PREFILLING
    must preempt it (not crash): slot A decodes across a page boundary
    with zero free pages while slot B holds 3 pages mid-prefill."""
    m, params = qwen
    a = Request(tokens=[2, 3, 4, 5, 6, 7], max_new_tokens=20)
    b = Request(tokens=list(range(2, 26)), max_new_tokens=4)   # 24-tok prompt
    eng = _mk_engine(m, params, num_pages=5)
    eng.run([a, b])
    assert a.done and len(a.out_tokens) == 20
    assert b.done and len(b.out_tokens) == 4
    for r in (a, b):
        solo = Request(tokens=list(r.tokens),
                       max_new_tokens=r.max_new_tokens)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens


def test_mamba2_long_prefill_next_to_decode_is_isolated():
    """ssm: decode steps must not clobber the recurrent state of a slot
    that is mid-prefill (states of non-decoding lanes are kept)."""
    cfg = get_smoke_config("mamba2-2.7b").replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    a = Request(tokens=[2, 3, 4], max_new_tokens=12)
    b = Request(tokens=list(range(2, 22)), max_new_tokens=4)  # 5 chunks
    _mk_engine(m, params).run([a, b])
    for r in (a, b):
        solo = Request(tokens=list(r.tokens),
                       max_new_tokens=r.max_new_tokens)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens


def test_hybrid_long_prefill_next_to_decode_is_isolated():
    """zamba2 (hybrid): a multi-chunk prefill running next to a decoding
    slot must not be corrupted by the decode steps (non-decoding lanes
    write to the trash row of the slot-dense shared-attn cache)."""
    cfg = get_smoke_config("zamba2-1.2b").replace(attn_impl="naive")
    m = Model(cfg)
    params = m.init(KEY, DENSE)
    a = Request(tokens=[2, 3, 4], max_new_tokens=12)
    b = Request(tokens=list(range(2, 22)), max_new_tokens=4)  # 5 chunks
    _mk_engine(m, params).run([a, b])
    for r in (a, b):
        solo = Request(tokens=list(r.tokens),
                       max_new_tokens=r.max_new_tokens)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens


def test_full_length_prompt_truncates_instead_of_crashing(qwen):
    """A prompt of exactly max_seq is servable: one token is generated and
    the request is evicted as truncated (the re-admission path after a
    preemption can legitimately present this boundary)."""
    m, params = qwen
    req = Request(tokens=list(range(2, 34)), max_new_tokens=8)   # 32 == max_seq
    eng = _mk_engine(m, params)
    eng.run([req])
    assert req.done and len(req.out_tokens) == 1
    assert eng.kv.live_pages == 0


def test_pool_outgrowth_truncates_without_aborting_run(qwen):
    """A request whose generation outgrows an undersized pool (2 pages =
    16 tokens, prompt 16, no preemptable neighbour) finishes as truncated
    — it must not abort the run or lose the other request."""
    m, params = qwen
    a = Request(tokens=list(range(2, 18)), max_new_tokens=12)  # 2 full pages
    b = Request(tokens=[3, 4], max_new_tokens=3)
    eng = _mk_engine(m, params, num_pages=2)
    eng.run([a, b])
    assert a.done and 1 <= len(a.out_tokens) < 12   # truncated at capacity
    assert b.done and len(b.out_tokens) == 3
    assert eng.kv.live_pages == 0


def test_batch_engine_truncates_at_max_seq(qwen):
    """BatchToCompletionEngine must stop decoding when the cache is full
    instead of letting clamped writes corrupt the last row: the tokens it
    does emit match a run with ample cache."""
    from repro.serve import BatchToCompletionEngine
    m, params = qwen
    big = Request(tokens=list(range(2, 14)), max_new_tokens=12)
    BatchToCompletionEngine(m, params, DENSE, batch_size=1,
                            max_seq=64).run([big])
    small = Request(tokens=list(range(2, 14)), max_new_tokens=12)
    BatchToCompletionEngine(m, params, DENSE, batch_size=1,
                            max_seq=16).run([small])
    n = len(small.out_tokens)
    assert 0 < n < 12                      # truncated
    assert small.out_tokens == big.out_tokens[:n]


def test_identical_hot_requests_diverge(qwen):
    """Per-slot PRNG keys: two identical temperature>0 requests sharing a
    decode batch must not sample identical sequences."""
    m, params = qwen
    a = Request(tokens=[4, 5, 6], max_new_tokens=12, temperature=1.5)
    b = Request(tokens=[4, 5, 6], max_new_tokens=12, temperature=1.5)
    _mk_engine(m, params).run([a, b])
    assert len(a.out_tokens) == len(b.out_tokens) == 12
    assert a.out_tokens != b.out_tokens


def test_no_per_step_temperature_upload(qwen):
    """The decode loop must NOT rebuild and re-upload the per-slot temps
    array every step: the device buffer is refreshed only on admission /
    eviction (regression for the host->device churn the batch engine
    already avoided)."""
    m, params = qwen
    reqs = [Request(tokens=[4, 5, 6], max_new_tokens=12, temperature=1.2),
            Request(tokens=[5, 6, 7], max_new_tokens=12, temperature=0.8)]
    eng = _mk_engine(m, params)
    eng.run(reqs)
    assert all(len(r.out_tokens) == 12 for r in reqs)
    decode_steps = max(len(r.out_tokens) for r in reqs)
    # one upload after the admissions; evictions only zero the buffer
    assert eng.temps_uploads <= 2 < decode_steps
    # and after eviction the buffer is all-greedy again (no stale temps
    # forcing the PRNG path for the next occupant)
    assert not (eng._temps_h > 0).any()


def test_batch_engine_stamps_latency_fields(qwen):
    """BatchToCompletionEngine must stamp first_token_step / finish_step so
    A/B latency comparisons against the continuous engine don't crash on
    None (the fields Request documents)."""
    from repro.serve import BatchToCompletionEngine
    m, params = qwen
    reqs = [Request(tokens=[3, 4, 5], max_new_tokens=2, arrival=0),
            Request(tokens=[6, 7], max_new_tokens=6, arrival=0)]
    eng = BatchToCompletionEngine(m, params, DENSE, batch_size=2, max_seq=32)
    eng.run(reqs)
    for r in reqs:
        assert r.first_token_step is not None and r.finish_step is not None
        # TTFT/latency arithmetic like serve_demo's report() must work
        assert r.finish_step - r.arrival >= r.first_token_step - r.arrival > 0
    # head-of-line blocking is visible in the stamps: the short request
    # finishes earlier than the long one, both monotone in the step clock
    assert reqs[0].finish_step <= reqs[1].finish_step
    # truncation path stamps too
    trunc = Request(tokens=list(range(2, 14)), max_new_tokens=30)
    BatchToCompletionEngine(m, params, DENSE, batch_size=1,
                            max_seq=16).run([trunc])
    assert trunc.done and trunc.finish_step is not None


def test_replica_router_least_loaded_dispatch_and_parity(qwen):
    """Host-level DP: two single-device replicas serve interleaved requests
    with per-request outputs identical to solo runs, and the oversized /
    oversubscription behaviour matches a single engine per replica."""
    m, params = qwen
    router = ReplicaRouter([_mk_engine(m, params, slots=1),
                            _mk_engine(m, params, slots=1)])
    reqs = [Request(tokens=[i + 2, i + 3], max_new_tokens=4)
            for i in range(4)]
    used = {id(router.submit(r)) for r in reqs}
    assert len(used) == 2                      # least-loaded spreads work
    router.run_until_idle()
    for r in reqs:
        solo = Request(tokens=list(r.tokens), max_new_tokens=4)
        _mk_engine(m, params, slots=1).run([solo])
        assert r.out_tokens == solo.out_tokens
    with pytest.raises(PagePoolExhausted):     # per-replica admissibility
        router.submit(Request(tokens=list(range(40)), max_new_tokens=2))


def test_greedy_unaffected_by_hot_neighbour(qwen):
    m, params = qwen
    solo = Request(tokens=[7, 8, 9], max_new_tokens=6)
    _mk_engine(m, params, slots=1).run([solo])
    hot = Request(tokens=[1, 2, 3], max_new_tokens=6, temperature=2.0)
    greedy = Request(tokens=[7, 8, 9], max_new_tokens=6)
    _mk_engine(m, params).run([hot, greedy])
    assert greedy.out_tokens == solo.out_tokens


# ---------------------------------------------------------------------------
# decode == forward parity through the paged cache
# ---------------------------------------------------------------------------

def _paged_parity(name, qc, params_fn):
    """Chunked paged prefill + per-slot paged decode must reproduce the
    full-sequence forward logits."""
    cfg = get_smoke_config(name).replace(attn_impl="naive")
    m = Model(cfg)
    params = params_fn(m)
    B, S, PRE = 1, 12, 7
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    logits_full, _ = m.forward(params, {"tokens": toks}, qc)

    eng = Engine(m, params, qc, batch_size=2, max_seq=32, page_size=8,
                 prefill_chunk=4)
    eng.kv.ensure(0, PRE)
    pt = eng.kv.table_device()
    kv = eng.kv.data
    i32 = lambda v: jnp.asarray(v, jnp.int32)  # noqa: E731
    for lo in range(0, PRE, 4):
        hi = min(lo + 4, PRE)
        t = np.zeros((1, 4), np.int32)
        t[0, :hi - lo] = np.asarray(toks)[0, lo:hi]
        lg, kv = m.prefill_paged(params, jnp.asarray(t), kv, pt,
                                 i32(0), i32(lo), i32(hi - lo), qc)
    np.testing.assert_allclose(np.asarray(lg)[0],
                               np.asarray(logits_full)[0, PRE - 1],
                               rtol=5e-3, atol=5e-3)
    for t_i in range(PRE, S):
        eng.kv.ensure(0, t_i + 1)
        pt = eng.kv.table_device()
        tk = np.zeros((2, 1), np.int32)
        tk[0, 0] = int(np.asarray(toks)[0, t_i])
        pos = np.zeros((2,), np.int32)
        pos[0] = t_i
        lg, kv = m.decode_paged(params, jnp.asarray(tk), kv, pt,
                                jnp.asarray(pos), qc)
        np.testing.assert_allclose(np.asarray(lg)[0],
                                   np.asarray(logits_full)[0, t_i],
                                   rtol=5e-3, atol=5e-3)


def test_paged_parity_dense_attention():
    _paged_parity("qwen1.5-4b", DENSE, lambda m: m.init(KEY, DENSE))


@pytest.mark.slow
def test_paged_parity_mamba2():
    _paged_parity("mamba2-2.7b", DENSE, lambda m: m.init(KEY, DENSE))


@pytest.mark.slow
def test_paged_parity_lut_infer():
    qc_t = QuantConfig(mode="lut_train", v=4, c=8)
    qc_i = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref")

    def mk(m):
        return precompute_model(m.init(KEY, qc_t), qc_i)
    _paged_parity("qwen1.5-4b", qc_i, mk)


def test_lut_infer_engine_matches_dense_cache_engine():
    """End-to-end: continuous engine (paged) == batch engine (dense cache)
    for greedy decoding on the lut_infer path."""
    from repro.serve import BatchToCompletionEngine
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    qc_t = QuantConfig(mode="lut_train", v=4, c=8)
    qc_i = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref")
    params = precompute_model(m.init(KEY, qc_t), qc_i)
    a = Request(tokens=[3, 4, 5, 6], max_new_tokens=6)
    b = Request(tokens=[3, 4, 5, 6], max_new_tokens=6)
    Engine(m, params, qc_i, batch_size=2, max_seq=32,
           prefill_chunk=4, page_size=8).run([a])
    BatchToCompletionEngine(m, params, qc_i, batch_size=2,
                            max_seq=32).run([b])
    assert a.out_tokens == b.out_tokens
