"""Tests for the serving observability stack (ISSUE 10).

Covers the metrics registry (streaming-histogram accuracy bounds,
snapshot round-trip, guarded ratios), the tracer (Perfetto JSON
validity, ring-buffer bound, disabled no-op), the snapshot schema
(write / merge / legacy normalization), the perf gate (direction and
tolerance rules, the CLI's exit-1 on a seeded regression), the astlint
``SYNC_FREE_PATHS`` knob, and the instrumented engine's hot-path
contract — one device read per step and steady-state recompile-freedom
with obs fully on, plus fault/degradation annotations in a chaos trace.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import run_ast_lint
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.models.model import Model
from repro.obs import (Obs, Registry, Tracer, compare, gate, make_row,
                       load_snapshot, merge_snapshot, normalize_row,
                       safe_ratio, validate_trace, write_snapshot, NULL_CTX)
from repro.serve import (Engine, FaultInjector, FaultSchedule, ReplicaRouter,
                         Request, SlotScheduler)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


def _mk_engine(m, params, slots=2, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return Engine(m, params, DENSE, batch_size=slots, **kw)


# ---------------------------------------------------------------------------
# metrics: histogram accuracy, registry round-trip, guarded ratios
# ---------------------------------------------------------------------------

def test_histogram_percentile_error_bound():
    """Quantile estimates stay within the documented growth-1 relative
    error of the exact sample quantiles, across a wide dynamic range."""
    r = Registry()
    h = r.histogram("lat", growth=1.25)
    samples = np.exp(
        np.random.default_rng(0).normal(loc=-5.0, scale=2.0, size=4000))
    for v in samples:
        h.observe(float(v))
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        est = h.percentile(q)
        assert abs(est - exact) / exact <= 0.25 + 1e-9, (q, est, exact)
        # the documented per-value bound is growth-1; quantile rank
        # discretisation adds at most one bucket, hence the 2x slack
        assert abs(est - exact) / exact <= 2 * (h.growth - 1.0)
    assert h.count == len(samples)
    assert np.isclose(h.total, samples.sum())
    assert np.isclose(h.mean, samples.mean())


def test_histogram_under_overflow_and_empty():
    h = Registry().histogram("x", lo=1e-3, hi=1e3)
    assert h.percentile(0.5) == 0.0          # empty: defined, not NaN
    h.observe(1e-9)                          # underflow -> exact min
    h.observe(5e8)                           # overflow  -> exact max
    assert h.percentile(0.0) == 1e-9
    assert h.percentile(1.0) == 5e8
    assert h.count == 2 and h.min == 1e-9 and h.max == 5e8


def test_registry_snapshot_round_trip():
    r = Registry()
    r.counter("a.b", unit="tokens").inc(7)
    r.gauge("g", unit="B").set(3.5)
    h = r.histogram("h", unit="s")
    for v in (1e-4, 2e-2, 5.0, 1e-8, 1e9):
        h.observe(v)
    r2 = Registry.from_snapshot(r.snapshot())
    assert r2.snapshot() == r.snapshot()
    assert r2.get_histogram("h").percentile(0.5) == h.percentile(0.5)
    prom = r.prometheus()
    assert "# TYPE a_b counter" in prom and "a_b 7" in prom
    assert '{quantile="0.99"}' in prom and "h_count 5" in prom


def test_ratios_guard_empty_denominators(qwen):
    assert safe_ratio(3.0, 0.0) == 0.0
    assert safe_ratio(3.0, 0.0, default=1.0) == 1.0
    r = Registry()
    assert r.ratio("nope", "nothing") == 0.0
    r.counter("num").inc(4)
    assert r.ratio("num", "nothing") == 0.0   # zero denominator, no raise
    # engine/scheduler rates are well-defined before any work
    m, params = qwen
    eng = _mk_engine(m, params)
    assert eng.prefix_hit_rate == 0.0
    assert eng.acceptance_rate == 0.0
    assert eng.tokens_per_verify == 0.0
    sched = SlotScheduler(2)
    assert (sched.shed_count, sched.expired_count, sched.preemptions) \
        == (0, 0, 0)


# ---------------------------------------------------------------------------
# tracer: JSON validity, ring bound, disabled no-op
# ---------------------------------------------------------------------------

def test_tracer_export_is_valid_and_nested(tmp_path):
    tr = Tracer(enabled=True)
    tr.name_process(0, "replica 0")
    with tr.span("step", pid=0):
        with tr.span("decode", pid=0):
            tr.instant("degradation", pid=0, args={"to": "no_spec"})
    tr.request_begin(1, "req 1", {"prompt": 3})
    tr.request_instant(1, "req 1", "requeued")
    tr.request_end(1, "req 1", {"reason": "COMPLETED"})
    tr.counter("pressure", 0.5, pid=0)
    path = tmp_path / "t.json"
    doc = tr.export(str(path))
    assert validate_trace(doc) == []
    on_disk = json.loads(path.read_text())
    assert validate_trace(on_disk) == []
    names = {e["name"] for e in on_disk["traceEvents"]}
    assert {"step", "decode", "degradation", "req 1", "pressure",
            "process_name"} <= names
    labels = {e["args"]["name"] for e in on_disk["traceEvents"]
              if e.get("ph") == "M"}
    assert {"replica 0", "requests"} <= labels


def test_validate_trace_catches_breakage():
    assert validate_trace({}) == ["missing traceEvents"]
    # async end with no begin
    bad = {"traceEvents": [{"ph": "e", "cat": "request", "id": 9,
                            "name": "r", "ts": 1.0, "pid": 999, "tid": 0}]}
    assert any("without begin" in p for p in validate_trace(bad))
    # sibling span overlapping its parent's end
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "ts": 0.0, "dur": 10.0, "pid": 0, "tid": 0},
        {"ph": "X", "name": "b", "ts": 5.0, "dur": 10.0, "pid": 0, "tid": 0},
    ]}
    assert any("overlaps" in p for p in validate_trace(bad))


def test_tracer_ring_buffer_is_bounded():
    tr = Tracer(enabled=True, capacity=16)
    for i in range(500):
        tr.instant(f"e{i}")
    assert len(tr) == 16


def test_disabled_obs_is_noop():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_CTX
    tr.instant("x")
    tr.request_begin(1, "r")
    assert len(tr) == 0
    obs = Obs.disabled()
    assert obs.phase("decode") is NULL_CTX        # no allocation, no timing
    assert not obs.active
    obs.annotate("degradation", to="no_spec")
    obs.track("pressure", 1.0)
    assert len(obs.tracer) == 0
    assert obs.metrics.snapshot()["histograms"] == {}
    # counters stay live even when "disabled" — they are engine state
    obs.metrics.counter("c").inc()
    assert obs.metrics.counters()["c"] == 1


# ---------------------------------------------------------------------------
# snapshot schema: write / merge / legacy normalization
# ---------------------------------------------------------------------------

def test_snapshot_write_merge_load(tmp_path):
    path = str(tmp_path / "BENCH.json")
    write_snapshot(path, [make_row("serve.a.us_per_tok", 10.0),
                          make_row("kvacc.x", 1.0, unit="", direction="up")],
                   bench="serve")
    doc = load_snapshot(path)
    assert doc["bench"] == "serve" and doc["schema"] == 2
    assert doc["host"]                        # fingerprint present
    merge_snapshot(path, [make_row("kvacc.y", 2.0, unit="",
                                   direction="up", tol=0.5)],
                   prefix="kvacc.")
    doc = load_snapshot(path)
    names = [r["name"] for r in doc["rows"]]
    assert names == ["serve.a.us_per_tok", "kvacc.y"]   # kvacc.x replaced
    assert doc["rows"][1]["tol"] == 0.5
    assert doc["bench"] == "serve"            # non-prefix meta preserved


def test_legacy_rows_are_normalized():
    legacy = normalize_row({"name": "serve.chaos.goodput_pct",
                            "value": "93.0"})
    assert legacy["direction"] == "up" and legacy["unit"] == "%"
    assert legacy["value"] == 93.0
    timer = normalize_row({"name": "micro/fused_amm_512", "value": 12.5,
                           "derived": ""})
    assert timer["direction"] == "down"
    # legacy kernels_micro rows carry no unit hint in the name — the
    # micro/ prefix marks them as us timers so the gate applies the
    # ±25% timer tolerance, not the exact-ratio rule
    assert timer["unit"] == "us"


# ---------------------------------------------------------------------------
# perf gate: direction/tolerance rules + CLI exit codes
# ---------------------------------------------------------------------------

def _doc(rows, host="h1"):
    return {"host": host, "rows": [normalize_row(r) for r in rows]}


def test_perfgate_timer_tolerance_and_direction():
    base = _doc([make_row("t.us_per_x", 100.0),
                 make_row("good.rate", 0.9, unit="", direction="up")])
    # +20% timer is inside ±25%; rate identical: passes
    regs, _ = compare(base, _doc([make_row("t.us_per_x", 120.0),
                                  make_row("good.rate", 0.9, unit="",
                                           direction="up")]))
    assert regs == []
    # +30% timer regresses; a *faster* timer never does
    regs, _ = compare(base, _doc([make_row("t.us_per_x", 130.0),
                                  make_row("good.rate", 0.9, unit="",
                                           direction="up")]))
    assert [d.name for d in regs] == ["t.us_per_x"]
    # up-direction row moving down regresses exactly (no timer slack)
    regs, _ = compare(base, _doc([make_row("t.us_per_x", 100.0),
                                  make_row("good.rate", 0.89, unit="",
                                           direction="up")]))
    assert [d.name for d in regs] == ["good.rate"]


def test_perfgate_cross_host_timers_and_one_sided_rows():
    base = _doc([make_row("t.us_per_x", 100.0)], host="h1")
    fresh = _doc([make_row("t.us_per_x", 900.0),
                  make_row("brand.new", 1.0)], host="h2")
    regs, deltas = compare(base, fresh, gate_timers="auto")
    assert regs == []                         # cross-host timer not gated
    assert any("cross-host" in d.note for d in deltas)
    assert any(d.base is None for d in deltas)   # new row reported
    regs, _ = compare(base, fresh, gate_timers="always")
    assert [d.name for d in regs] == ["t.us_per_x"]
    # per-row tol override beats the timer default
    base = _doc([make_row("t.us_per_x", 100.0, tol=0.01)])
    regs, _ = compare(base, _doc([make_row("t.us_per_x", 110.0, tol=0.01)]))
    assert [d.name for d in regs] == ["t.us_per_x"]
    code, lines = gate([(base, base, "self")])
    assert code == 0 and lines[-1].startswith("perf gate: OK")


def test_perf_gate_cli_exits_1_on_seeded_regression(tmp_path):
    """The CI entry point must demonstrably fail on a regression."""
    base = str(tmp_path / "base.json")
    fresh = str(tmp_path / "fresh.json")
    write_snapshot(base, [make_row("serve.x.us_per_tok", 100.0),
                          make_row("serve.goodput_pct", 95.0, unit="%",
                                   direction="up")])
    write_snapshot(fresh, [make_row("serve.x.us_per_tok", 101.0),
                           make_row("serve.goodput_pct", 95.0, unit="%",
                                    direction="up")])
    cli = os.path.join(ROOT, "scripts", "perf_gate.py")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    ok = subprocess.run([sys.executable, cli, "--baseline", base,
                         "--fresh", fresh], env=env,
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    # seed a goodput regression
    write_snapshot(fresh, [make_row("serve.x.us_per_tok", 101.0),
                           make_row("serve.goodput_pct", 80.0, unit="%",
                                    direction="up")])
    bad = subprocess.run([sys.executable, cli, "--baseline", base,
                          "--fresh", fresh], env=env,
                         capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "REGRESSED" in bad.stdout and "serve.goodput_pct" in bad.stdout


def test_perfgate_reads_committed_bench_snapshots():
    """The committed BENCH_*.json baselines parse and self-compare clean
    (whatever schema vintage they are)."""
    pairs = []
    for rel in ("BENCH_serve.json", "BENCH_kernels.json"):
        p = os.path.join(ROOT, rel)
        if os.path.exists(p):
            doc = load_snapshot(p)
            pairs.append((doc, doc, rel))
    assert pairs, "no committed BENCH snapshots found"
    code, _ = gate(pairs)
    assert code == 0


# ---------------------------------------------------------------------------
# astlint: the SYNC_FREE_PATHS knob
# ---------------------------------------------------------------------------

def test_astlint_sync_free_paths_knob(tmp_path):
    """A step-loop-reachable sync read inside ``src/repro/obs`` is
    downgraded to info (the obs layer is declared sync-free); the same
    code anywhere else still warns."""
    from repro.analysis import astlint
    src_root = tmp_path / "src" / "repro"
    for sub in ("", "obs", "serve"):
        d = src_root / sub if sub else src_root
        d.mkdir(parents=True, exist_ok=True)
        (d / "__init__.py").write_text("")
    body = textwrap.dedent("""
        import numpy as np

        def record(x):
            return np.asarray(x)
    """)
    (src_root / "obs" / "rec.py").write_text(body)
    (src_root / "serve" / "rec2.py").write_text(body)
    (src_root / "serve" / "engine.py").write_text(textwrap.dedent("""
        from repro.obs.rec import record
        from repro.serve.rec2 import record as record2

        class Engine:
            def step(self):
                record(1)
                record2(1)
    """))
    findings, _ = run_ast_lint(str(tmp_path / "src"))
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f.path)
    assert any("rec2.py" in p for p in by_rule.get("step-sync", [])), \
        "sync read outside SYNC_FREE_PATHS must still warn"
    assert all("obs" not in p for p in by_rule.get("step-sync", [])), \
        "obs-layer sync read must not trip the step-sync rule"
    assert any("rec.py" in p for p in by_rule.get("sync-site", []))
    assert "src/repro/obs" in astlint.SYNC_FREE_PATHS


# ---------------------------------------------------------------------------
# instrumented engine: hot-path contract + request lifecycle
# ---------------------------------------------------------------------------

def test_engine_obs_on_one_device_read_per_step(qwen, tmp_path):
    """Full instrumentation (timers + tracer) must not add device reads:
    still exactly one ``_device_read`` per work step, and the request's
    latency families + finish tally land in the registry."""
    m, params = qwen
    obs = Obs(tracer=Tracer(enabled=True))
    eng = _mk_engine(m, params, obs=obs)
    req = Request(tokens=[3, 4, 5], max_new_tokens=6)
    eng.run([req])
    assert eng.device_reads == 6             # one fetch per step, obs on
    met = obs.metrics
    cs = met.counters()
    assert cs["engine.device_reads"] == 6
    assert cs["engine.emitted_tokens"] == 6
    assert cs["req.finish.completed"] == 1
    for fam in ("req.ttft_steps", "req.latency_steps", "req.ttft_s",
                "req.latency_s", "req.tpot_s"):
        h = met.get_histogram(fam)
        assert h is not None and h.count == 1, fam
    assert met.get_histogram("req.ttft_s").min > 0.0
    # phase spans recorded and balanced in the export
    for ph in ("admit", "prefill_chunk", "decode", "sample", "device_read"):
        h = met.get_histogram(f"engine.phase.{ph}_s")
        assert h is not None and h.count > 0, ph
    path = tmp_path / "eng.json"
    doc = obs.tracer.export(str(path))
    assert validate_trace(doc) == []
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert phs.count("b") == 1 and phs.count("e") == 1   # one request span


def test_engine_obs_disabled_same_tokens_and_counters(qwen):
    """Obs off vs on is behaviourally invisible: identical greedy tokens,
    identical counters; disabled timing records no phase histograms."""
    m, params = qwen
    outs = {}
    for tag, obs in (("off", Obs.disabled()), ("on", Obs())):
        req = Request(tokens=[5, 6, 7], max_new_tokens=5)
        eng = _mk_engine(m, params, obs=obs)
        eng.run([req])
        outs[tag] = req.out_tokens
        assert eng.device_reads == 5
    assert outs["on"] == outs["off"]
    off = Obs.disabled()
    eng = _mk_engine(m, params, obs=off)
    eng.run([Request(tokens=[5, 6, 7], max_new_tokens=5)])
    snap = off.metrics.snapshot()
    assert not any(n.startswith("engine.phase.")
                   for n in snap["histograms"])
    assert snap["counters"]["engine.emitted_tokens"]["value"] == 5


def test_chaos_trace_has_spans_and_annotations(qwen, tmp_path):
    """A faulted 2-replica run exports one merged, valid timeline:
    request spans survive cross-replica migration, and the injected
    faults + degradation/health flips appear as annotations."""
    m, params = qwen
    tracer = Tracer(enabled=True)
    router = ReplicaRouter(
        [_mk_engine(m, params, obs=Obs(tracer=tracer), num_pages=8)
         for _ in range(2)])
    FaultInjector(FaultSchedule.canned(replicas=2)).attach(router)
    reqs = [Request(tokens=[2 + i, 3 + i], max_new_tokens=6 + 4 * (i % 2))
            for i in range(6)]
    for r in reqs:
        router.submit(r)
    router.run_until_idle()
    assert all(r.done for r in reqs)
    doc = tracer.export(str(tmp_path / "chaos.json"))
    assert validate_trace(doc) == []
    evs = doc["traceEvents"]
    annot = {e["name"] for e in evs if e.get("ph") == "i"}
    assert any(a.startswith("fault.") for a in annot), annot
    assert "health" in annot                  # replica death flip
    begins = [e for e in evs if e.get("ph") == "b"]
    ends = [e for e in evs if e.get("ph") == "e"]
    assert {e["id"] for e in begins} == {e["id"] for e in ends}
    assert len(begins) == len(reqs)           # migration keeps ONE span
    pids = {e["pid"] for e in evs if e.get("ph") == "X"}
    assert pids == {0, 1}                     # both replica tracks present
    assert router.retried_requests > 0
    assert router.obs.metrics.counters()["router.health.to_dead"] == 1


def test_scheduler_preemption_annotates(qwen, tmp_path):
    """Decode-growth preemption under pool pressure lands a ``preempt``
    instant on the replica track."""
    m, params = qwen
    obs = Obs(tracer=Tracer(enabled=True))
    eng = _mk_engine(m, params, num_pages=5, obs=obs)   # tight pool
    # slot A decodes across a page boundary with zero free pages while
    # slot B holds 3 pages mid-prefill -> decode growth preempts B
    reqs = [Request(tokens=[2, 3, 4, 5, 6, 7], max_new_tokens=20),
            Request(tokens=list(range(2, 26)), max_new_tokens=4)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert eng.scheduler.preemptions > 0
    names = [ev[1] for ev in obs.tracer._events if ev[0] == "i"]
    assert "preempt" in names


@pytest.mark.slow
def test_recompile_guard_with_obs_on(qwen):
    """Steady-state decode stays recompile-free with full instrumentation
    — phase timers and tracer recording add zero trace-time effects."""
    from repro.analysis import run_recompile_guard
    m, params = qwen
    obs = Obs(tracer=Tracer(enabled=True))
    eng = _mk_engine(m, params, obs=obs)

    def _mixed(seed):
        # one temperature request: greedy + sampled batches are two
        # pytree classes of the sample jit (see test_recompile_guard.py)
        return [Request(tokens=[seed, seed + 1], max_new_tokens=3),
                Request(tokens=[seed + 2] * 3, max_new_tokens=4),
                Request(tokens=[seed + 4, seed + 5], max_new_tokens=2,
                        temperature=0.7)]

    report = run_recompile_guard(
        eng, _mixed(3), _mixed(11),
        expected_counts={"prefill": 1, "decode": 1, "verify": 0,
                         "sample": 2})
    assert report.ok, "\n".join(f.render() for f in report.findings)
    assert report.steady_events == []
    assert len(obs.tracer) > 0               # tracing really was on
