"""Self-speculative decoding: acceptance math, rollback invariants, parity.

The headline guarantee — greedy speculative output is TOKEN-IDENTICAL to
greedy non-speculative output — is asserted end-to-end for dense and
``lut_infer`` targets, including slots admitted mid-decode, prefix-cache
warm starts, adversarial (always-rejecting) drafters, and page pools
tight enough to force preemption. The rollback property tests drive the
engine step-by-step and check after EVERY step that each physical page's
refcount equals the number of slot rows mapping it (so a draft-reject
rollback can neither leak a page nor decref a prefix-shared page below
its pre-draft count).
"""
from collections import Counter

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import precompute_model
from repro.core.lut import DENSE, QuantConfig
from repro.models.model import Model
from repro.serve import (Drafter, Engine, ModelDrafter, NgramDrafter,
                         PagePoolExhausted, PageTable, Request, SpecConfig,
                         accept_tokens)
from repro.serve.engine import BatchToCompletionEngine, _sample_tokens

KEY = jax.random.PRNGKey(0)


def smoke_model():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    return m, m.init(KEY, DENSE)


def lut_model():
    cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
    m = Model(cfg)
    qc_i = QuantConfig(mode="lut_infer", v=4, c=8, impl="ref")
    params = precompute_model(
        m.init(KEY, QuantConfig(mode="lut_train", v=4, c=8)), qc_i)
    return m, params, qc_i


def mixed_requests(temperature: float = 0.0):
    """More requests than slots → admission mid-decode is exercised."""
    return [Request(tokens=[3, 4, 5, 6], max_new_tokens=18,
                    temperature=temperature),
            Request(tokens=[9, 8, 7], max_new_tokens=10,
                    temperature=temperature),
            Request(tokens=[1, 2], max_new_tokens=14,
                    temperature=temperature),
            Request(tokens=[4, 4, 4, 4, 4], max_new_tokens=6,
                    temperature=temperature)]


def streams(reqs):
    return [r.out_tokens for r in reqs]


# ---------------------------------------------------------------------------
# acceptance math (pure host-side units)
# ---------------------------------------------------------------------------

def _logits_for(targets, v=16):
    """(len(targets), v) logits whose argmax rows are ``targets``."""
    out = np.full((len(targets), v), -5.0, np.float32)
    for i, t in enumerate(targets):
        out[i, t] = 5.0
    return out


def test_accept_greedy_full_prefix_and_bonus():
    rng = np.random.default_rng(0)
    # target argmax chain: 7, 8, 9, bonus 3
    a, out = accept_tokens([7, 8, 9], _logits_for([7, 8, 9, 3]), 0.0, rng)
    assert (a, out) == (3, [7, 8, 9, 3])


def test_accept_greedy_mismatch_emits_correction():
    rng = np.random.default_rng(0)
    # second proposal diverges: keep [7], emit the target's 2 instead
    a, out = accept_tokens([7, 8, 9], _logits_for([7, 2, 9, 3]), 0.0, rng)
    assert (a, out) == (1, [7, 2])
    # immediate mismatch: pure correction, one token
    a, out = accept_tokens([5], _logits_for([7, 1]), 0.0, rng)
    assert (a, out) == (0, [7])


def test_accept_rejection_certain_cases():
    rng = np.random.default_rng(0)
    v = 8
    certain = np.full((2, v), -30.0, np.float32)
    certain[:, 3] = 30.0                       # target: all mass on 3
    # drafter proposed 3 with q(3)=1 → p(3)/q(3)=1 → always accepted,
    # bonus sampled from row 1 (also certain on 3)
    q = np.zeros(v); q[3] = 1.0
    a, out = accept_tokens([3], certain, 1.0, rng, [q])
    assert (a, out) == (1, [3, 3])
    # drafter proposed 5 where p(5)≈0 → always rejected; the residual
    # draw must come from p (token 3), never re-emit 5
    q5 = np.zeros(v); q5[5] = 1.0
    for _ in range(8):
        a, out = accept_tokens([5], certain, 1.0, rng, [q5])
        assert (a, out) == (0, [3])
    # one-hot drafter without q_rows behaves the same
    a, out = accept_tokens([5], certain, 1.0, rng, None)
    assert (a, out) == (0, [3])


def test_accept_rejection_preserves_target_distribution():
    """Draft-then-accept/resample must be distributed exactly as the
    target: empirical first-token frequencies match softmax(logits/T)."""
    rng = np.random.default_rng(1)
    v = 4
    logits = np.array([[1.0, 0.5, -0.5, 0.0],
                       [0.0, 0.0, 0.0, 0.0]], np.float32)
    temp = 0.7
    p = np.exp(logits[0] / temp); p /= p.sum()
    q = np.array([0.55, 0.05, 0.3, 0.1])       # deliberately miscalibrated
    counts = np.zeros(v)
    trials = 6000
    for _ in range(trials):
        g = int(rng.choice(v, p=q))
        _, out = accept_tokens([g], logits, temp, rng, [q])
        counts[out[0]] += 1
    np.testing.assert_allclose(counts / trials, p, atol=0.03)


def test_ngram_lookup():
    look = NgramDrafter._lookup
    hist = [1, 2, 3, 9, 1, 2, 3]
    assert look(hist, 3, 3) == [9, 1, 2]       # trigram [1,2,3] continues
    assert look(hist, 8, 3) == [9, 1, 2, 3]    # capped by history end
    assert look([1, 2, 3, 4], 4, 3) == []      # nothing repeats
    # earliest occurrence wins (longest continuation ahead of it)
    assert look([5, 1, 5, 2, 5], 2, 1) == [1, 5]
    # a constant run proposes the full lookahead, not one token
    assert look([7, 4, 4, 4, 4], 3, 3) == [4, 4, 4]
    with pytest.raises(ValueError):
        NgramDrafter(0)


# ---------------------------------------------------------------------------
# engine-level greedy parity (token-identical to non-speculative)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter_kw", [
    dict(drafter="ngram"),
    pytest.param(dict(drafter="model"), marks=pytest.mark.slow),
    pytest.param(dict(drafter="model", draft_layers=2),
                 marks=pytest.mark.slow),
])
def test_spec_greedy_identical_dense(drafter_kw):
    m, params = smoke_model()
    base = mixed_requests()
    Engine(m, params, DENSE, batch_size=2, max_seq=64, page_size=8,
           prefill_chunk=4).run(base)
    sp = mixed_requests()
    eng = Engine(m, params, DENSE, batch_size=2, max_seq=64, page_size=8,
                 prefill_chunk=4, spec_decode=SpecConfig(k=3, **drafter_kw))
    eng.run(sp)
    assert streams(sp) == streams(base)
    assert eng.spec_rounds > 0 and eng.spec_emitted > 0
    # the full-depth self-drafter proposes exactly the target argmax chain
    if drafter_kw == dict(drafter="model"):
        assert eng.acceptance_rate == 1.0
        assert eng.tokens_per_verify > 2.0


@pytest.mark.slow
def test_spec_greedy_identical_lut_infer_target():
    """lut_infer target with a same-point drafter, and the headline
    LUT-DLA pairing: dense target verified while the coarse LUT path
    drafts (same params, shared codebooks)."""
    m, params, qc_i = lut_model()
    for target_qc, spec in [
        (qc_i, SpecConfig(k=3)),
        (DENSE, SpecConfig(k=3, draft_qc=qc_i)),
    ]:
        base = mixed_requests()
        Engine(m, params, target_qc, batch_size=2, max_seq=64, page_size=8,
               prefill_chunk=4).run(base)
        sp = mixed_requests()
        eng = Engine(m, params, target_qc, batch_size=2, max_seq=64,
                     page_size=8, prefill_chunk=4, spec_decode=spec)
        eng.run(sp)
        assert streams(sp) == streams(base)


class WrongDrafter(Drafter):
    """Adversarial drafter: proposes a constant (almost always wrong)
    token so verify rejects nearly everything — the rollback stress case."""

    def __init__(self, tok: int = 1):
        self.tok = tok

    def propose(self, engine, dslots, k_slot, k):
        b = engine.num_slots
        g = np.full((b, k), self.tok, np.int32)
        n_prop = np.zeros((b,), np.int32)
        for s in dslots:
            n_prop[s.idx] = k_slot[s.idx]
        return g, n_prop, None


def spec_engine_with(drafter, m, params, qc=DENSE, **kw):
    eng = Engine(m, params, qc, spec_decode=SpecConfig(k=3), **kw)
    eng.drafter = drafter
    drafter.bind(eng)
    return eng


def test_verify_reject_rollback_identical_stream():
    """Verify-then-reject every round: the slot's decode output must stay
    token-identical to a never-speculated slot (rejected rows are rolled
    back, overwritten, never attended)."""
    m, params = smoke_model()
    base = mixed_requests()
    Engine(m, params, DENSE, batch_size=2, max_seq=64, page_size=8,
           prefill_chunk=4).run(base)
    sp = mixed_requests()
    eng = spec_engine_with(WrongDrafter(), m, params, batch_size=2,
                           max_seq=64, page_size=8, prefill_chunk=4)
    eng.run(sp)
    assert streams(sp) == streams(base)
    assert eng.spec_drafted > 0
    assert eng.spec_accepted < eng.spec_drafted   # rejections happened


# ---------------------------------------------------------------------------
# rollback invariants (property-style: checked after every engine step)
# ---------------------------------------------------------------------------

def _refcounts_match_rows(pt: PageTable):
    """Every physical page's refcount == number of slot rows mapping it.

    Parked prefix-cache pages are mapped by no row and hold refcount 0;
    any violation means a rollback leaked a page (count too high) or
    decrefed a shared page below its mapped count (too low)."""
    mapped = Counter(p for row in pt._slot_pages for p in row)
    for p in range(pt.allocator.num_pages):
        assert pt.allocator.refcount(p) == mapped.get(p, 0), \
            f"page {p}: refcount {pt.allocator.refcount(p)} != " \
            f"{mapped.get(p, 0)} mapping rows"


@pytest.mark.parametrize("drafter_factory", [
    WrongDrafter, NgramDrafter,
    pytest.param(ModelDrafter, marks=pytest.mark.slow)])
def test_rollback_never_corrupts_shared_page_refcounts(drafter_factory):
    """Two slots share a prefix (read-shared pages) while both speculate;
    after every step the refcount of EVERY page — shared prefix pages
    included — must equal the rows mapping it, and the shared pages'
    refcount must never drop below the pre-draft value while both slots
    hold them."""
    m, params = smoke_model()
    system = [(5 * j) % 60 + 2 for j in range(16)]      # 2 full pages
    eng = spec_engine_with(drafter_factory(), m, params, batch_size=2,
                           max_seq=64, page_size=8, prefill_chunk=8)
    warm = Request(tokens=system + [7], max_new_tokens=4)
    eng.run([warm])                     # indexes the system-prompt pages
    _refcounts_match_rows(eng.kv.table)

    a = Request(tokens=system + [11, 12], max_new_tokens=16)
    b = Request(tokens=system + [13, 14], max_new_tokens=16)
    eng.submit(a)
    eng.submit(b)
    shared = [eng.kv.table.prefix.lookup(key) for key in
              __import__("repro.serve.kv_cache", fromlist=["x"])
              ._chunk_keys(system, 8)]
    assert all(p is not None for p in shared)
    seen_both_live = False
    while eng.scheduler.has_work:
        eng.step()
        _refcounts_match_rows(eng.kv.table)
        rcs = [eng.kv.table.allocator.refcount(p) for p in shared]
        if all(rc == 2 for rc in rcs):
            seen_both_live = True       # both slots map the shared pages
    assert seen_both_live
    assert a.done and b.done
    assert len(a.out_tokens) == 16 and len(b.out_tokens) == 16
    # shared pages survive (parked or mapped), ready for the next hit
    assert all(eng.kv.table.prefix.is_registered(p) for p in shared)


def test_spec_prefix_warm_start_identical():
    """Prefix-cache warm start + speculation == cold non-speculative."""
    m, params = smoke_model()
    system = [(3 * j) % 50 + 2 for j in range(16)]

    def reqs():
        return [Request(tokens=system + [10 + i], max_new_tokens=10)
                for i in range(3)]

    base = reqs()
    Engine(m, params, DENSE, batch_size=2, max_seq=64, page_size=8,
           prefill_chunk=8, prefix_cache=False).run(base)
    warm = reqs()
    eng = Engine(m, params, DENSE, batch_size=2, max_seq=64, page_size=8,
                 prefill_chunk=8, spec_decode=SpecConfig(k=3))
    eng.run([warm[0]])                  # warms the index
    eng.submit(warm[1])
    eng.submit(warm[2])
    eng.run_until_idle()
    assert streams(warm) == streams(base)
    assert eng.cached_tokens > 0        # the warm starts actually hit


def test_trim_releases_only_tail_pages():
    pt = PageTable(num_slots=2, max_seq=64, page_size=8, num_pages=8,
                   prefix_cache=False)
    pt.ensure(0, 40)                    # 5 pages
    assert pt.live_pages == 5
    assert pt.trim(0, 18) == 2          # keep ceil(18/8) = 3
    assert pt.live_pages == 3 and pt.allocator.available == 5
    assert (pt.table[0, :3] >= 0).all() and (pt.table[0, 3:] == -1).all()
    assert pt.trim(0, 18) == 0          # idempotent
    pt.ensure(0, 40)                    # freed pages are reusable
    assert pt.live_pages == 5


def test_spec_config_validation():
    m_ssm = Model(get_smoke_config("mamba2-2.7b"))
    params = m_ssm.init(KEY, DENSE)
    with pytest.raises(ValueError, match="roll back"):
        Engine(m_ssm, params, DENSE, batch_size=2, max_seq=32,
               spec_decode=SpecConfig(k=2))
    m, p = smoke_model()
    with pytest.raises(ValueError, match="k must be"):
        Engine(m, p, DENSE, batch_size=2, max_seq=32,
               spec_decode=SpecConfig(k=0))
    with pytest.raises(ValueError, match="unknown drafter"):
        SpecConfig(drafter="oracle").build_drafter()
    with pytest.raises(ValueError, match="draft_layers"):
        Engine(m, p, DENSE, batch_size=2, max_seq=32,
               spec_decode=SpecConfig(k=2, draft_layers=99))


@pytest.mark.slow
def test_spec_sharded_parity():
    """Speculative decoding under a tensor-parallel mesh stays
    token-identical to the single-device non-speculative engine (the
    verify step and the fused draft scan compile with explicit
    shardings)."""
    from conftest import run_in_devices
    run_in_devices("""
import jax
from repro.configs import get_smoke_config
from repro.core.lut import DENSE
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.serve import Engine, Request, SpecConfig

cfg = get_smoke_config("qwen1.5-4b").replace(attn_impl="naive")
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0), DENSE)
def reqs():
    return [Request(tokens=[3, 4, 5, 6], max_new_tokens=12),
            Request(tokens=[9, 8, 7], max_new_tokens=8)]
kw = dict(batch_size=2, max_seq=64, page_size=8, prefill_chunk=4)
base = reqs()
Engine(m, params, DENSE, **kw).run(base)
mesh = make_test_mesh((1, 4), ("data", "model"))
for spec in [SpecConfig(k=3, drafter="ngram"),
             SpecConfig(k=3, draft_layers=2)]:
    sp = reqs()
    Engine(m, params, DENSE, mesh=mesh, spec_decode=spec, **kw).run(sp)
    assert [r.out_tokens for r in sp] == [r.out_tokens for r in base], spec
print("sharded spec OK")
""")


# ---------------------------------------------------------------------------
# satellites: shared sampling helper, occupancy-rich errors
# ---------------------------------------------------------------------------

def test_engines_share_sampling_helper(monkeypatch):
    """Both engines must route sampling through ``_sample_tokens`` so
    greedy/temperature semantics cannot drift between them."""
    import repro.serve.engine as eng_mod
    m, params = smoke_model()
    cont = Engine(m, params, DENSE, batch_size=2, max_seq=32)
    batch = BatchToCompletionEngine(m, params, DENSE, batch_size=2,
                                    max_seq=32)
    calls = []

    def spy(key, logits, temps, slot_ids):
        calls.append(list(slot_ids))
        return _sample_tokens(key, logits, temps, slot_ids)

    monkeypatch.setattr(eng_mod, "_sample_tokens", spy)
    logits = jax.numpy.asarray(np.linspace(0, 1, 2 * 17).reshape(2, 17))
    key = jax.random.PRNGKey(7)
    cont.key = key
    batch.key = key
    t_cont = cont._sample(logits, None, range(2))
    t_batch = batch._sample(logits, None)
    assert len(calls) == 2
    np.testing.assert_array_equal(np.asarray(t_cont), np.asarray(t_batch))
    # temperature path: same key + same slot ids → identical draws
    temps = jax.numpy.asarray(np.array([0.8, 0.0], np.float32))
    cont.key = key
    batch.key = key
    np.testing.assert_array_equal(
        np.asarray(cont._sample(logits, temps, range(2))),
        np.asarray(batch._sample(logits, temps)))


def test_pool_errors_and_preemption_log_carry_occupancy(caplog):
    m, params = smoke_model()
    eng = Engine(m, params, DENSE, batch_size=2, max_seq=64, page_size=8,
                 num_pages=4, prefill_chunk=8)
    with pytest.raises(PagePoolExhausted) as ei:
        eng.submit(Request(tokens=list(range(40)), max_new_tokens=2))
    msg = str(ei.value)
    assert "live" in msg and "free of" in msg and "cached-parked" in msg
    # preemption log: oversubscribe so decode must reclaim pages
    reqs = [Request(tokens=list(range(2, 12)), max_new_tokens=14)
            for _ in range(2)]
    import logging
    with caplog.at_level(logging.INFO, logger="repro.serve.scheduler"):
        eng.run(reqs)
    assert all(r.done for r in reqs)
    pre = [r for r in caplog.records if "preempting slot" in r.getMessage()]
    assert pre and "pool:" in pre[0].getMessage()
