"""Unit + property tests for similarity metrics and STE quantisation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.similarity import (assign, assign_subspaces,
                                   pairwise_distance,
                                   pairwise_distance_subspaces,
                                   soft_assignment, ste_quantize,
                                   ste_quantize_subspaces)

METRICS = ["l2", "l1", "chebyshev"]


def _brute(x, z, metric):
    diff = np.abs(x[:, None, :] - z[None])
    if metric == "l2":
        return (diff ** 2).sum(-1)
    if metric == "l1":
        return diff.sum(-1)
    return diff.max(-1)


@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_matches_bruteforce(metric, rng):
    x = jax.random.normal(rng, (17, 6))
    z = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    d = pairwise_distance(x, z, metric)
    np.testing.assert_allclose(np.asarray(d),
                               _brute(np.asarray(x), np.asarray(z), metric),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 24), c=st.integers(1, 12), v=st.integers(1, 9),
       metric=st.sampled_from(METRICS), seed=st.integers(0, 2**16))
def test_assign_is_argmin(m, c, v, metric, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, v))
    z = jax.random.normal(jax.random.fold_in(key, 1), (c, v))
    idx = np.asarray(assign(x, z, metric))
    brute = _brute(np.asarray(x), np.asarray(z), metric).argmin(-1)
    np.testing.assert_array_equal(idx, brute)


@pytest.mark.parametrize("metric", METRICS)
def test_distances_nonnegative_and_self_zero(metric, rng):
    z = jax.random.normal(rng, (5, 4))
    d = pairwise_distance(z, z, metric)
    assert float(jnp.min(d)) >= -1e-6
    np.testing.assert_allclose(np.asarray(jnp.diagonal(d)), 0.0, atol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_ste_forward_is_nearest_centroid(metric, rng):
    x = jax.random.normal(rng, (11, 4))
    z = jax.random.normal(jax.random.PRNGKey(3), (7, 4))
    xh = ste_quantize(x, z, metric)
    idx = assign(x, z, metric)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(z[idx]), rtol=1e-6)


@pytest.mark.parametrize("metric", METRICS)
def test_ste_gradient_straight_through(metric, rng):
    x = jax.random.normal(rng, (8, 4))
    z = jax.random.normal(jax.random.PRNGKey(4), (5, 4))
    g = jax.grad(lambda xx: jnp.sum(ste_quantize(xx, z, metric) ** 2))(x)
    # STE: dL/dx == dL/dx_hat = 2*x_hat
    xh = ste_quantize(x, z, metric)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * xh), rtol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_ste_centroid_gradient_is_scatter(metric, rng):
    x = jax.random.normal(rng, (16, 4))
    z = jax.random.normal(jax.random.PRNGKey(5), (6, 4))
    gz = jax.grad(lambda zz: jnp.sum(ste_quantize(x, zz, metric)))(z)
    # each centroid's grad = count of assigned points (for sum loss)
    idx = np.asarray(assign(x, z, metric))
    counts = np.bincount(idx, minlength=6).astype(np.float32)
    np.testing.assert_allclose(np.asarray(gz),
                               np.tile(counts[:, None], (1, 4)), rtol=1e-5)


def test_subspace_versions_match_per_subspace(rng):
    m, nc, v, c = 9, 5, 4, 8
    x = jax.random.normal(rng, (m, nc, v))
    z = jax.random.normal(jax.random.PRNGKey(6), (nc, c, v))
    d = pairwise_distance_subspaces(x, z, "l2")
    idx = assign_subspaces(x, z, "l2")
    for k in range(nc):
        dk = pairwise_distance(x[:, k], z[k], "l2")
        np.testing.assert_allclose(np.asarray(d[:, k]), np.asarray(dk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(idx[:, k]),
                                      np.asarray(jnp.argmin(dk, -1)))
    xh = ste_quantize_subspaces(x, z, "l2")
    assert xh.shape == x.shape


def test_soft_assignment_limits(rng):
    x = jax.random.normal(rng, (6, 3, 4))
    z = jax.random.normal(jax.random.PRNGKey(7), (3, 5, 4))
    probs = soft_assignment(x, z, "l2", temperature=1e-4)
    hard = assign_subspaces(x, z, "l2")
    np.testing.assert_array_equal(np.asarray(jnp.argmax(probs, -1)),
                                  np.asarray(hard))
    s = soft_assignment(x, z, "l2", temperature=1.0)
    np.testing.assert_allclose(np.asarray(jnp.sum(s, -1)), 1.0, rtol=1e-5)
