"""`select_blocks("flash_decode")` coverage: VMEM-fit halving boundary,
pages-per-split floor, and non-power-of-two kv-head counts at the kernel
boundary (ISSUE 8 satellite)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode_paged
from repro.kernels.ref import flash_decode_ref
from repro.kernels.tuning import _VMEM_BUDGET, select_blocks


# ---------------------------------------------------------------------------
# VMEM-fit halving of the kv-head tile (block_n)
# ---------------------------------------------------------------------------

def test_vmem_halving_boundary():
    # f32 pool, page=512, head_dim=256: the double-buffered K+V tile is
    # 4*c*bh*hd*itemsize bytes — 16 MiB at bh=8, so the tile halves
    # 8 -> 4 -> 2 and stops exactly at the 4 MiB budget.
    blk = select_blocks("flash_decode", 2, 4, 512, 256, 4)
    assert blk.block_n == 2
    tile = 4 * 512 * blk.block_n * 256 * 4
    assert tile <= _VMEM_BUDGET < tile * 2


def test_exact_budget_is_not_halved():
    # equality is "fits": a tile exactly at the budget keeps all 8 heads
    c, hd = 256, 128
    assert 4 * c * 8 * hd * 4 == _VMEM_BUDGET
    assert select_blocks("flash_decode", 2, 4, c, hd, 4).block_n == 8


def test_head_tile_floor_is_one():
    # a single head over budget still yields a legal (degenerate) tile
    blk = select_blocks("flash_decode", 2, 4, 4096, 1024, 4)
    assert blk.block_n == 1
    assert 4 * 4096 * 1 * 1024 * 4 > _VMEM_BUDGET


def test_int8_pool_keeps_wide_tile():
    # the quantized-KV direction (ROADMAP item 2): 1-byte pool entries
    # fit the full 8-head tile where the f32 pool halved to 2
    assert select_blocks("flash_decode", 2, 4, 512, 256, 1).block_n == 8
    assert select_blocks("flash_decode", 2, 4, 512, 256, 4).block_n == 2


# ---------------------------------------------------------------------------
# pages-per-split (block_k)
# ---------------------------------------------------------------------------

def test_pages_per_split_floor_is_one():
    # zero allocated pages (fresh slot) must still give a runnable
    # 1-page split, in every batch regime
    for m in (1, 8, 64, 512):
        assert select_blocks("flash_decode", m, 0, 16, 64, 4).block_k == 1


def test_pages_per_split_caps_at_table_and_pages():
    assert select_blocks("flash_decode", 2, 3, 16, 64, 4).block_k == 3
    assert select_blocks("flash_decode", 2, 64, 16, 64, 4).block_k == 4
    assert select_blocks("flash_decode", 512, 64, 16, 64, 4).block_k == 8


def test_slot_tile_always_one():
    # one grid row per slot regardless of batch size
    for m in (1, 8, 128, 512):
        assert select_blocks("flash_decode", m, 4, 16, 64, 4).block_m == 1


# ---------------------------------------------------------------------------
# non-power-of-two kv-head counts through the kernel boundary
# ---------------------------------------------------------------------------

def _case(seed, slots, np_, ps, kvh, g, d, positions):
    key = jax.random.PRNGKey(seed)
    p1 = slots * np_ + 1
    ks = jax.random.split(key, 5)
    k_pages = jax.random.normal(ks[0], (p1, ps, kvh, d), jnp.float32)
    v_pages = jax.random.normal(ks[1], (p1, ps, kvh, d), jnp.float32)
    k_pages = k_pages.at[-1].set(41.0)     # loud trash page
    v_pages = v_pages.at[-1].set(-59.0)
    phys = np.full((slots, np_), p1 - 1, np.int64)
    nxt = 0
    for b, pos in enumerate(positions):
        n_alloc = min(-(-(int(pos) + 1) // ps), np_) if pos >= 0 else 0
        phys[b, :n_alloc] = np.arange(nxt, nxt + n_alloc)
        nxt += n_alloc
    q = jax.random.normal(ks[2], (slots, 1, kvh * g, d), jnp.float32)
    k_new = jax.random.normal(ks[3], (slots, 1, kvh, d), jnp.float32)
    v_new = jax.random.normal(ks[4], (slots, 1, kvh, d), jnp.float32)
    return (q, k_pages, v_pages, k_new, v_new,
            jnp.asarray(phys, jnp.int32), jnp.asarray(positions, jnp.int32))


@pytest.mark.parametrize("kvh,g,block_heads", [
    (6, 2, 4),    # 4 does not divide 6: kernel degrades the tile to 3
    (7, 1, None), # prime head count: the full-kvh tile (7) divides
    (7, 1, 4),    # prime + non-divisor request: degrades to 1
])
def test_non_pow2_head_count_parity(kvh, g, block_heads):
    args = _case(3, 2, 4, 8, kvh, g, 16, [5, 13])
    out = flash_decode_paged(*args, impl="pallas", interpret=True,
                             block_heads=block_heads)
    oracle = flash_decode_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
