"""HLO cost model, roofline report math, sharding-rule validity,
block-local attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_REGISTRY, get_smoke_config
from repro.core.lut import QuantConfig
from repro.launch import roofline as rl
from repro.launch.hlo_cost import module_cost, parse_module
from repro.models.layers import _sdpa, _sdpa_local
from repro.models.model import Model
from repro.parallel.sharding import param_pspecs

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- hlo_cost
def test_scan_flops_counted_with_trip_multiplier():
    def g(a, bs):
        return jax.lax.scan(lambda c, b: (c @ b, None), a, bs)[0]
    A = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    BS = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    c = jax.jit(g).lower(A, BS).compile()
    cost = module_cost(c.as_text())
    expect = 7 * 2 * 64 * 32 * 32
    assert abs(cost.flops - expect) / expect < 0.01


def test_nested_scan_flops():
    def g(a, bs):
        def outer(c, b):
            def inner(ci, _):
                return ci @ b, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, a, bs)[0]
    A = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    BS = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    c = jax.jit(g).lower(A, BS).compile()
    cost = module_cost(c.as_text())
    expect = 5 * 3 * 2 * 16 ** 3
    assert abs(cost.flops - expect) / expect < 0.01


def test_parse_module_finds_computations():
    f = jax.jit(lambda x: jnp.tanh(x) @ x.T)
    c = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    comps = parse_module(c.as_text())
    assert any(n.startswith("main") for n in comps)


# ---------------------------------------------------------------- roofline
def test_roofline_report_terms_and_bottleneck():
    rep = rl.RooflineReport(
        flops=197e12, bytes_accessed=819e9 * 2,
        coll_bytes={"all-reduce": int(50e9 * 4 * 0.5)}, chips=4,
        model_flops=4 * 197e12 * 0.25, model_bytes=0.0)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.t_memory == pytest.approx(2.0)
    assert rep.t_collective == pytest.approx(0.5)
    assert rep.bottleneck == "memory"
    assert rep.roofline_fraction == pytest.approx(0.25 / 2.0)
    d = rep.to_dict()
    assert d["bottleneck"] == "memory"


def test_model_flops_and_bytes_for():
    cfg = get_smoke_config("qwen1.5-4b")
    n = cfg.active_param_count()
    assert rl.model_flops_for(cfg, "train", 4, 16) == 6.0 * n * 64
    assert rl.model_flops_for(cfg, "decode", 4, 16) == 2.0 * n * 4
    mb = rl.model_bytes_for(cfg, "decode", 4, 16, param_bytes=100.0,
                            cache_bytes=10.0)
    assert mb == 110.0


# ---------------------------------------------------------------- sharding
@pytest.mark.parametrize("name", list(SMOKE_REGISTRY))
def test_param_pspecs_rank_matches_leaves(name):
    cfg = SMOKE_REGISTRY[name]()
    m = Model(cfg)
    qc = QuantConfig(mode="lut_train", v=4, c=8)
    params = jax.eval_shape(lambda k: m.init(k, qc), KEY)
    specs = param_pspecs(params, cfg, model_axis_size=4)
    leaves = jax.tree_util.tree_leaves_with_path(params)
    spec_map = {jax.tree_util.keystr(p): s for p, s in
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda s: isinstance(
                        s, jax.sharding.PartitionSpec))}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        spec = spec_map[key]
        assert len(spec) <= leaf.ndim, (key, spec, leaf.shape)
        # any model-axis dim must divide
        for i, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[i] % 4 == 0, (key, spec, leaf.shape)


def test_vocab_fallback_replication():
    """mamba2's vocab (50280) doesn't divide 16 — embed must replicate."""
    cfg = SMOKE_REGISTRY["mamba2-2.7b"]().replace(vocab_size=50280)
    m = Model(cfg)
    params = jax.eval_shape(lambda k: m.init(k), KEY)
    specs = param_pspecs(params, cfg, model_axis_size=16)
    assert specs["embed"] == jax.sharding.PartitionSpec(None, None)
    specs4 = param_pspecs(params, cfg, model_axis_size=4)
    assert specs4["embed"] == jax.sharding.PartitionSpec("model", None)


# -------------------------------------------------------- local attention
@pytest.mark.parametrize("s,w", [(32, 8), (64, 16), (48, 8)])
def test_block_local_equals_naive_window(s, w):
    b, h, kvh, d = 2, 4, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, d))
    out_naive = _sdpa(q, k, v, 0, w, 0, impl="naive")
    out_local = _sdpa_local(q, k, v, w)
    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(out_local),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_grouped_scan_equals_flat_scan():
    """gemma3-style grouped forward == the same model's flat forward."""
    cfg = get_smoke_config("gemma3-27b").replace(
        attn_impl="naive", num_layers=8, global_every=3, sliding_window=8)
    m = Model(cfg)
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    out_grouped, _ = m.forward(params, {"tokens": toks})
    # flat path: disable grouping by zeroing sliding_window pattern via
    # global_every=0 but same per-layer windows through cfg trickery is
    # not possible; instead compare against layer-by-layer manual apply.
    from repro.models.layers import attention, mlp, rms_norm
    x = params["embed"][toks]
    for i in range(cfg.num_layers):
        p_l = jax.tree_util.tree_map(lambda t: t[i], params["blocks"])
        win = 0 if cfg.layer_is_global(i) else cfg.sliding_window
        a, _, _ = attention(p_l["attn"], x, cfg, m_qc(), window=win)
        x = x + a
        f, _ = mlp(p_l["mlp"], x, cfg, m_qc())
        x = x + f
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits_manual = x @ params["embed"].T
    np.testing.assert_allclose(np.asarray(out_grouped),
                               np.asarray(logits_manual),
                               rtol=3e-4, atol=3e-4)


def m_qc():
    from repro.core.lut import DENSE
    return DENSE
