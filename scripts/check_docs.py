"""Docs CI: markdown link check + doctest of fenced ``>>>`` examples.

Usage:  PYTHONPATH=src python scripts/check_docs.py [files...]

With no arguments, checks README.md and every ``docs/*.md``.

Two passes per file:
  1. **Links** — every inline markdown link/image target is validated:
     relative paths must exist on disk (anchors are stripped; pure
     ``#anchor`` links must match a heading in the same file); http(s)
     URLs are only sanity-checked for shape (no network in CI).
  2. **Doctests** — every fenced ```python block containing ``>>>`` is
     run through :mod:`doctest`, so the examples in the docs cannot rot.
"""
from __future__ import annotations

import doctest
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def check_links(path: str, text: str) -> list:
    errors = []
    anchors = {_slug(h) for h in HEADING_RE.findall(text)}
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://")):
            if "." not in target:
                errors.append(f"{path}: suspicious URL {target!r}")
            continue
        if target.startswith("#"):
            if target[1:] not in anchors:
                errors.append(f"{path}: broken anchor {target!r}")
            continue
        if target.startswith("mailto:"):
            continue
        rel, _, anchor = target.partition("#")
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(os.path.abspath(path)), rel))
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link {target!r} "
                          f"(no such file {resolved})")
    return errors


def check_doctests(path: str, text: str) -> list:
    errors = []
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(verbose=False,
                                   optionflags=doctest.ELLIPSIS)
    for i, block in enumerate(FENCE_RE.findall(text)):
        if ">>>" not in block:
            continue
        test = parser.get_doctest(block, {}, f"{path}[fence {i}]", path, 0)
        out = []
        runner.run(test, out=out.append)
        if runner.failures:
            errors.append(f"{path} fence {i}: doctest failed\n"
                          + "".join(out))
            runner = doctest.DocTestRunner(verbose=False,
                                           optionflags=doctest.ELLIPSIS)
    return errors


def main(argv) -> int:
    files = argv or (sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
                     + [os.path.join(ROOT, "README.md")])
    errors = []
    n_tests = 0
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        errors += check_links(path, text)
        blocks = [b for b in FENCE_RE.findall(text) if ">>>" in b]
        n_tests += len(blocks)
        errors += check_doctests(path, text)
        print(f"[check_docs] {os.path.relpath(path, ROOT)}: "
              f"{len(LINK_RE.findall(text))} links, "
              f"{len(blocks)} doctest fences")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"[check_docs] OK ({len(files)} files, {n_tests} doctest fences)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
