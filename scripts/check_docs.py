"""Docs CI: markdown link/anchor check, orphan detection, fenced doctests.

Usage:  PYTHONPATH=src python scripts/check_docs.py [files...]

With no arguments, checks README.md and every ``docs/*.md``.

Passes per file:
  1. **Links** — every inline markdown link/image target is validated:
     relative paths must exist on disk; pure ``#anchor`` links must match
     a heading in the same file; ``file.md#anchor`` links must match a
     heading in the *target* file (cross-file anchors); http(s) URLs are
     only sanity-checked for shape (no network in CI).
  2. **Doctests** — every fenced ```python block containing ``>>>`` is
     run through :mod:`doctest`, so the examples in the docs cannot rot.

One repo-wide pass (default full-set runs only, where the link graph is
complete):
  3. **Orphans** — every ``docs/*.md`` must be reachable: linked from
     README.md or from another doc. An unreferenced doc is dead weight
     nobody can discover; fail instead of letting it rot.
"""
from __future__ import annotations

import doctest
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors_of(path: str, cache: dict) -> set:
    """Heading anchors of a markdown file (read-on-demand, cached)."""
    if path not in cache:
        try:
            with open(path, encoding="utf-8") as f:
                text = f.read()
        except OSError:
            text = ""
        cache[path] = {_slug(h) for h in HEADING_RE.findall(text)}
    return cache[path]


def check_links(path: str, text: str, anchor_cache: dict,
                targets: set) -> list:
    """Validate one file's links; resolved relative targets land in
    ``targets`` (absolute paths) for the orphan pass."""
    errors = []
    anchors = {_slug(h) for h in HEADING_RE.findall(text)}
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://")):
            if "." not in target:
                errors.append(f"{path}: suspicious URL {target!r}")
            continue
        if target.startswith("#"):
            if target[1:] not in anchors:
                errors.append(f"{path}: broken anchor {target!r}")
            continue
        if target.startswith("mailto:"):
            continue
        rel, _, anchor = target.partition("#")
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(os.path.abspath(path)), rel))
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link {target!r} "
                          f"(no such file {resolved})")
            continue
        if resolved != os.path.abspath(path):
            targets.add(resolved)      # self-links don't de-orphan a doc
        if anchor and resolved.endswith(".md"):
            if _slug(anchor) not in _anchors_of(resolved, anchor_cache):
                errors.append(
                    f"{path}: dead anchor {target!r} (no heading "
                    f"'#{anchor}' in {os.path.relpath(resolved, ROOT)})")
    return errors


def check_orphans(checked_files, targets: set) -> list:
    """Every docs/*.md must be linked from README or another doc."""
    errors = []
    for path in checked_files:
        apath = os.path.abspath(path)
        rel = os.path.relpath(apath, ROOT)
        if os.path.dirname(rel) != "docs":
            continue                       # only docs/ pages need inbound links
        if apath not in targets:
            errors.append(
                f"{rel}: orphan doc — not linked from README.md or any "
                f"other doc (add it to the Documentation index)")
    return errors


def check_doctests(path: str, text: str) -> list:
    errors = []
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(verbose=False,
                                   optionflags=doctest.ELLIPSIS)
    for i, block in enumerate(FENCE_RE.findall(text)):
        if ">>>" not in block:
            continue
        test = parser.get_doctest(block, {}, f"{path}[fence {i}]", path, 0)
        out = []
        runner.run(test, out=out.append)
        if runner.failures:
            errors.append(f"{path} fence {i}: doctest failed\n"
                          + "".join(out))
            runner = doctest.DocTestRunner(verbose=False,
                                           optionflags=doctest.ELLIPSIS)
    return errors


def main(argv) -> int:
    full_set = not argv
    files = argv or (sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
                     + [os.path.join(ROOT, "README.md")])
    errors = []
    n_tests = 0
    anchor_cache: dict = {}
    link_targets: set = set()
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        errors += check_links(path, text, anchor_cache, link_targets)
        blocks = [b for b in FENCE_RE.findall(text) if ">>>" in b]
        n_tests += len(blocks)
        errors += check_doctests(path, text)
        print(f"[check_docs] {os.path.relpath(path, ROOT)}: "
              f"{len(LINK_RE.findall(text))} links, "
              f"{len(blocks)} doctest fences")
    if full_set:
        # the orphan check needs the complete link graph: skip it when an
        # explicit file subset was requested (inbound links may live in
        # files outside the subset)
        errors += check_orphans(files, link_targets)
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"[check_docs] OK ({len(files)} files, {n_tests} doctest fences"
          + (", orphan check on" if full_set else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
