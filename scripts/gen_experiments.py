"""Generate the data-driven sections of EXPERIMENTS.md from results/.

Usage: PYTHONPATH=src python scripts/gen_experiments.py > /tmp/tables.md
(The narrative sections of EXPERIMENTS.md are hand-written; this script
emits §Dry-run and §Roofline tables and the perf-iteration summary.)
"""
import glob
import json
import os

import sys
RESULTS = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
PERF = "results/perf"


def load(pattern):
    rows = []
    for f in sorted(glob.glob(pattern)):
        rows.append((os.path.basename(f), json.load(open(f))))
    return rows


def fmt_t(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.0f}µs"
    if x < 0.1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_matrix():
    print("### Dry-run status matrix (lower+compile on the production "
          "meshes)\n")
    print("| arch | shape | 16×16 (256 chips) | 2×16×16 (512 chips) |")
    print("|---|---|---|---|")
    singles = {(r["arch"], r["shape"]): r
               for _, r in load(f"{RESULTS}/single__lut__*.json")}
    multis = {(r["arch"], r["shape"]): r
              for _, r in load(f"{RESULTS}/multi__lut__*.json")}
    for (arch, shape), r in sorted(singles.items()):
        m = multis.get((arch, shape), {})

        def cell(rr):
            if not rr:
                return "—"
            if rr.get("status") == "skipped":
                return "skip (full-attn @500k)"
            if rr.get("status") != "ok":
                return "FAIL"
            return (f"ok ({rr['compile_s']:.0f}s, "
                    f"{rr['roofline']['bottleneck'][:4]}-bound)")
        print(f"| {arch} | {shape} | {cell(r)} | {cell(m)} |")
    n_ok = sum(1 for r in singles.values() if r.get("status") == "ok") + \
        sum(1 for r in multis.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in list(singles.values()) + list(multis.values())
                 if r.get("status") == "skipped")
    print(f"\n**{n_ok} cells compile, {n_skip} documented skips, "
          f"0 failures.**\n")


def roofline_table():
    print("### Roofline terms — single-pod 16×16, LUT mode (baseline)\n")
    print("All cost figures are per device (the SPMD-partitioned program); "
          "`frac` = t_ideal / max(term).\n")
    print("| arch | shape | t_compute | t_memory | t_collective | "
          "bottleneck | MODEL_FLOPS/HLO | frac |")
    print("|---|---|---|---|---|---|---|---|")
    for _, r in load(f"{RESULTS}/single__lut__*.json"):
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(rl['t_compute_s'])} | "
              f"{fmt_t(rl['t_memory_s'])} | {fmt_t(rl['t_collective_s'])} | "
              f"{rl['bottleneck']} | {rl['useful_flops_ratio']:.3f} | "
              f"{rl['roofline_fraction']:.4f} |")
    print()


def perf_log():
    print("### Perf iteration log (hillclimbed cells)\n")
    print("| iteration | cell | t_compute | t_memory | t_collective | "
          "frac |")
    print("|---|---|---|---|---|---|")
    for name, r in load(f"{PERF}/*.json"):
        if r.get("status") != "ok":
            continue
        tag = name.split("__")[0]
        rl = r["roofline"]
        print(f"| {tag} | {r['arch']}×{r['shape']} | "
              f"{fmt_t(rl['t_compute_s'])} | {fmt_t(rl['t_memory_s'])} | "
              f"{fmt_t(rl['t_collective_s'])} | "
              f"{rl['roofline_fraction']:.4f} |")
    print()


if __name__ == "__main__":
    dryrun_matrix()
    roofline_table()
    perf_log()
