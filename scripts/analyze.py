"""Analysis CI gate: AST lint + jaxpr invariants vs the committed baseline.

Usage:  PYTHONPATH=src python scripts/analyze.py \\
            [--baseline analysis/baseline.json] [--update] \\
            [--no-jaxpr] [--src src] [-v]

Runs the AST lint (:mod:`repro.analysis.astlint`) and — unless
``--no-jaxpr`` — the jaxpr/lowering invariant checks
(:mod:`repro.analysis.jaxpr_check`), then diffs the gating findings
against the committed baseline:

* a finding whose key is in the baseline is GRANDFATHERED (reported,
  exit 0);
* a NEW finding (key absent) fails with exit 1;
* a FIXED baselined key is reported so the baseline can be tightened.

``--update`` rewrites the baseline from the current findings (commit
the result; review the diff — shrinking is progress, growing needs a
reason). ``info``-severity findings are the host-sync classification
report (printed with ``-v``) and never gate.

The recompile guard (:mod:`repro.analysis.recompile`) is dynamic and
runs in the slow test job (``tests/test_recompile_guard.py``), not here.
"""
from __future__ import annotations

import argparse
import collections
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline",
                    default=os.path.join(ROOT, "analysis/baseline.json"))
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the (jax-importing) jaxpr invariant pass")
    ap.add_argument("--src", default=os.path.join(ROOT, "src"),
                    help="source root to lint")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-severity classification")
    args = ap.parse_args(argv)

    from repro.analysis import (diff_baseline, load_baseline,
                                run_ast_lint, save_baseline)

    findings, graph = run_ast_lint(args.src)
    if not args.no_jaxpr:
        from repro.analysis import run_jaxpr_checks
        findings = findings + run_jaxpr_checks()

    n_traced, n_step = len(graph.traced), len(graph.step_loop)
    by_sev = collections.Counter(f.severity for f in findings)
    print(f"analyze: {n_traced} traced fn(s), {n_step} step-loop fn(s); "
          f"{by_sev['error']} error / {by_sev['warn']} warn / "
          f"{by_sev['info']} info finding(s)")
    if args.verbose:
        for f in sorted(findings, key=lambda f: (f.path, f.line)):
            if f.severity == "info":
                print("  " + f.render())

    if args.update:
        save_baseline(args.baseline, findings)
        print(f"analyze: baseline rewritten -> {args.baseline} "
              f"({by_sev['error'] + by_sev['warn']} key(s))")
        return 0

    baseline = load_baseline(args.baseline)
    new, grandfathered, fixed = diff_baseline(findings, baseline)
    for f in grandfathered:
        print(f"grandfathered (baseline): {f.render()}")
    for k in fixed:
        print(f"fixed (tighten baseline with --update): {k}")
    if new:
        print(f"\nanalyze: {len(new)} NEW finding(s) not in "
              f"{os.path.relpath(args.baseline, ROOT)}:")
        for f in new:
            print(f.render())
        return 1
    print("analyze: OK (no new findings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
