#!/usr/bin/env python
"""Perf-regression gate over the committed BENCH_*.json snapshots.

Default mode (what the slow CI job runs *after* refreshing the
workspace snapshots): compare each workspace ``BENCH_*.json`` against
the copy committed at a git rev (``HEAD`` by default, read via
``git show`` so the freshly-rewritten workspace file never gates
itself), and exit 1 if any gated row regressed beyond its tolerance
(see ``repro.obs.perfgate`` for the direction/tolerance rules — ±25%
on same-host CPU timers, exact on ratio/accuracy rows, per-row ``tol``
overrides honoured).

Pair mode compares two explicit files — used by ``tests/test_obs.py``
to prove the gate actually exits non-zero on a seeded regression:

    python scripts/perf_gate.py --baseline old.json --fresh new.json

Timer rows measured on a different host than the baseline are reported
but not gated under ``--gate-timers auto`` (the default); ``always`` /
``never`` force it either way.

Run:  PYTHONPATH=src python scripts/perf_gate.py [--rev HEAD]
          [--snapshots BENCH_serve.json BENCH_kernels.json]
          [--gate-timers auto|always|never]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.perfgate import gate                      # noqa: E402
from repro.obs.snapshot import load_snapshot, loads_snapshot  # noqa: E402

DEFAULT_SNAPSHOTS = ("BENCH_serve.json", "BENCH_kernels.json")


def _committed(rev: str, relpath: str) -> dict | None:
    """The snapshot as committed at ``rev``, or None if absent there."""
    proc = subprocess.run(
        ["git", "show", f"{rev}:{relpath}"], cwd=REPO,
        capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return loads_snapshot(json.loads(proc.stdout))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rev", default="HEAD",
                    help="git rev holding the baseline snapshots "
                         "(default HEAD; ignored in pair mode)")
    ap.add_argument("--snapshots", nargs="+", default=list(DEFAULT_SNAPSHOTS),
                    help="repo-relative snapshot files to gate")
    ap.add_argument("--baseline", default="",
                    help="pair mode: explicit baseline snapshot file")
    ap.add_argument("--fresh", default="",
                    help="pair mode: explicit fresh snapshot file")
    ap.add_argument("--gate-timers", default="auto",
                    choices=("auto", "always", "never"),
                    help="gate us-unit rows: auto = only when host "
                         "fingerprints match (default)")
    args = ap.parse_args()
    if bool(args.baseline) != bool(args.fresh):
        ap.error("--baseline and --fresh must be given together")

    pairs = []
    if args.baseline:
        pairs.append((load_snapshot(args.baseline),
                      load_snapshot(args.fresh),
                      f"{args.baseline} -> {args.fresh}"))
    else:
        for rel in args.snapshots:
            workspace = os.path.join(REPO, rel)
            if not os.path.exists(workspace):
                print(f"{rel}: no fresh workspace snapshot — skipped "
                      f"(run the benchmarks with --snapshot auto first)")
                continue
            base = _committed(args.rev, rel)
            if base is None:
                print(f"{rel}: not committed at {args.rev} — skipped "
                      f"(first snapshot, nothing to gate against)")
                continue
            pairs.append((base, load_snapshot(workspace),
                          f"{rel} ({args.rev} -> workspace)"))

    if not pairs:
        print("perf gate: nothing to compare")
        return 0
    code, lines = gate(pairs, gate_timers=args.gate_timers)
    print("\n".join(lines))
    return code


if __name__ == "__main__":
    raise SystemExit(main())
