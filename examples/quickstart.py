"""Quickstart: VQ-AMM in 60 lines — the paper's Fig 2 pipeline.

Builds a codebook over activations (k-means), precomputes the LUT, and
compares LUT-based matmul against the dense result.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import CodebookSpec, build_lut, kmeans_codebook, \
    quantize_lut_int8
from repro.core.similarity import assign_subspaces
from repro.kernels.ops import lut_matmul, vq_amm, vq_assign

M, K, N = 256, 512, 384
V, C = 4, 32                     # equivalent bit-width: log2(32)/4 = 1.25 bit

key = jax.random.PRNGKey(0)
spec = CodebookSpec(v=V, c=C, metric="l2")

# activations with VQ-friendly structure (a few latent directions + noise)
basis = jax.random.normal(key, (4, K))
codes = jax.random.normal(jax.random.fold_in(key, 1), (M, 4))
A = codes @ basis + 0.05 * jax.random.normal(jax.random.fold_in(key, 2),
                                             (M, K))
W = jax.random.normal(jax.random.fold_in(key, 3), (K, N)) / K ** 0.5

# step 1 — cluster activations per subspace (paper step ①)
Z = kmeans_codebook(A, K, spec, iters=15)
print(f"codebook: {Z.shape}  (subspaces×centroids×v), "
      f"equivalent bits = {spec.equivalent_bits}")

# step 2 — precompute LUT = centroids · weights (paper step ②)
LUT = build_lut(W, Z)
LUT8, scale = quantize_lut_int8(LUT)
print(f"LUT: {LUT.shape}, int8 {LUT8.nbytes / 1e6:.2f} MB "
      f"vs bf16 weights {W.nbytes / 2 / 1e6:.2f} MB")

# step 3 — inference: assign + lookup (paper steps ③④)
idx = vq_assign(A.reshape(M, K // V, V), Z, "l2")
out_lut = lut_matmul(idx, LUT8, scale)

# same thing, fused: CCM pipelined into IMM, indices never leave VMEM
# (on TPU this is one Pallas kernel; "auto" picks it there)
out_fused = vq_amm(A.reshape(M, K // V, V), Z, LUT8, scale, "l2")
assert float(jnp.max(jnp.abs(out_fused - out_lut))) < 1e-3
print(f"fused assign+lookup matches two-pass "
      f"(idx tensor eliminated: {idx.nbytes / 1e3:.1f} KB)")

out_dense = A @ W
rel = float(jnp.linalg.norm(out_lut - out_dense) / jnp.linalg.norm(out_dense))
print(f"relative error vs dense GEMM: {rel:.4f}")

# the compute that remains: one index per (row, subspace) + table adds
ops_dense = 2 * M * K * N
ops_lut = 2 * C * M * K + M * N * (K // V)
print(f"dense ops {ops_dense / 1e6:.0f}M -> lut ops {ops_lut / 1e6:.0f}M "
      f"({ops_dense / ops_lut:.1f}x fewer)")
assert rel < 0.32, rel   # 1.25-bit AMM on random gaussians; 0.3053 on this seed
print("OK")
